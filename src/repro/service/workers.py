"""Threaded morsel worker pool for QuipService (docs/serving.md).

N daemon threads pull morsel steps from the service's MorselScheduler
through its checkout/checkin split: a worker takes the policy-chosen
session under the service lock (``MorselScheduler.next_session``), runs
exactly one ``session.step()`` **off** the lock, then checks it back in
(``checkin`` charges the tenant and requeues) and finalizes it if it
finished.  The policy layer already charges by per-step active time, so
wfq/deadline/quota semantics transfer unchanged — the pool only changes
*where* a step runs, never *which* step is charged what.  A checked-out
session is invisible to ``next_session``, so its generator is only ever
advanced by one thread at a time and per-session state needs no locks.

Intra-query parallelism: ``QuipExecutor`` fans order-independent sibling
morsels (join-free Select*(Scan) chains) through :meth:`map_morsels`.
The pool runs them as claimable units of a :class:`_TaskGroup`, and the
**owner helps**: the worker that opened the fan-out keeps claiming units
itself until none remain, then waits only for stragglers other workers
took — a pool of any size (including 1) can never deadlock on its own
sub-tasks.  Idle workers prefer units over checking out a new session,
so in-flight queries finish before new ones start consuming threads.

Lock discipline: everything the pool shares (scheduler queues, task
groups, the busy counter) lives under the service's single
RLock/Condition; stepping and unit execution happen outside it.  A
worker crash (a pool bug — ``session.step()`` already converts query
errors into FAILED sessions) is captured and re-raised by the next
``wait_idle``/``result`` instead of hanging the caller.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Deque, List, Optional, Sequence

__all__ = ["WorkerPool"]


class _TaskGroup:
    """One ``map_morsels`` fan-out: claimable units with ordered results."""

    __slots__ = ("fn", "items", "results", "next_unit", "done", "error")

    def __init__(self, fn: Callable, items: Sequence):
        self.fn = fn
        self.items = items
        self.results: List = [None] * len(items)
        self.next_unit = 0  # next unclaimed index (guarded by the pool cv)
        self.done = 0  # completed units (ditto)
        self.error: Optional[BaseException] = None  # first unit exception

    def claim(self) -> Optional[int]:
        """Take the next unclaimed unit index (call under the cv)."""
        if self.next_unit >= len(self.items):
            return None
        i = self.next_unit
        self.next_unit += 1
        return i

    @property
    def finished(self) -> bool:
        return self.done >= len(self.items)


class WorkerPool:
    """``size`` daemon threads stepping a QuipService's scheduler.

    Created by ``QuipService(..., workers=N)`` — not standalone: it
    drives the service's private checkout/checkin hooks and shares its
    RLock/Condition.  ``shutdown`` (via ``QuipService.close``) stops and
    joins the threads; drain first (``run_until_idle``) for a clean exit.
    """

    # cv.wait timeout: guards against lost wakeups without busy-spinning
    _POLL_S = 0.05

    def __init__(self, service, size: int):
        if size < 1:
            raise ValueError(f"worker pool size must be >= 1, got {size}")
        self._svc = service
        # the service's condition over the service RLock — the pool adds
        # no lock of its own, so lock-order cycles with service state are
        # impossible by construction
        self._cv: threading.Condition = service._cv
        self.size = int(size)
        self._groups: Deque[_TaskGroup] = deque()  # guarded-by: _cv
        self._busy = 0  # stepping/unit-running workers  # guarded-by: _cv
        self.steps_done = 0  # session steps run by the pool  # guarded-by: _cv
        self.units_done = 0  # fan-out units run by the pool  # guarded-by: _cv
        self._stop = False  # guarded-by: _cv
        self._crash: Optional[BaseException] = None  # guarded-by: _cv
        self._threads = [
            threading.Thread(target=self._worker, name=f"quip-worker-{i}",
                             daemon=True)
            for i in range(self.size)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ #
    # worker loop
    # ------------------------------------------------------------------ #
    def _worker(self) -> None:
        try:
            while True:
                unit = session = None
                with self._cv:
                    if self._stop:
                        return
                    unit = self._claim_unit()
                    if unit is None:
                        session = self._svc._checkout_session()
                        if session is None:
                            self._cv.wait(self._POLL_S)
                            continue
                    self._busy += 1
                try:
                    if unit is not None:
                        group, i = unit
                        self._run_unit(group, i)
                    else:
                        finished = session.step()  # OFF the lock
                        with self._cv:
                            self._svc._checkin_session(session, finished)
                finally:
                    with self._cv:
                        self._busy -= 1
                        if unit is not None:
                            self.units_done += 1
                        else:
                            self.steps_done += 1
                        self._cv.notify_all()
        except BaseException as e:  # pool bug: surface, don't hang callers
            with self._cv:
                self._crash = e
                self._cv.notify_all()

    def _claim_unit(self):  # requires: _cv
        """Next (group, index) unit, dropping fully-claimed groups (call
        under the cv)."""
        while self._groups:
            group = self._groups[0]
            i = group.claim()
            if i is None:
                self._groups.popleft()
                continue
            return group, i
        return None

    def _run_unit(self, group: _TaskGroup, i: int) -> None:
        try:
            result = group.fn(group.items[i])
            err = None
        except Exception as e:  # surfaced by the owner, once, in order
            result, err = None, e
        with self._cv:
            group.results[i] = result
            if err is not None and group.error is None:
                group.error = err
            group.done += 1
            self._cv.notify_all()

    # ------------------------------------------------------------------ #
    # intra-query fan-out (executor task_runner)
    # ------------------------------------------------------------------ #
    def map_morsels(self, fn: Callable, items: Sequence) -> List:
        """Run ``fn`` over ``items``, order-preserving, possibly on other
        workers.  Called from a worker mid-``session.step()`` (no lock
        held).  The caller — the group's owner — helps: it claims units
        until none remain, so progress never depends on a free worker.
        The first unit exception is re-raised (after all units settle),
        exactly like the serial ``[fn(x) for x in items]``."""
        items = list(items)
        if len(items) <= 1 or self.size <= 1:
            return [fn(x) for x in items]
        group = _TaskGroup(fn, items)
        with self._cv:
            self._groups.append(group)
            self._cv.notify_all()
        while True:  # owner helps
            with self._cv:
                i = group.claim()
            if i is None:
                break
            self._run_unit(group, i)
        with self._cv:
            while not group.finished:
                self._cv.wait(self._POLL_S)
            try:  # fully-claimed groups are usually popped lazily by
                self._groups.remove(group)  # _claim_unit; don't rely on it
            except ValueError:
                pass
        if group.error is not None:
            raise group.error
        return group.results

    # ------------------------------------------------------------------ #
    # caller-side synchronization
    # ------------------------------------------------------------------ #
    @property
    def busy(self) -> int:
        """Workers currently stepping a session or running a fan-out
        unit.  A point-in-time gauge for the metrics layer; the cv uses
        the service RLock, so reading under it from a metrics snapshot
        is re-entrant-safe."""
        return self._busy

    def check(self) -> None:
        """Raise if a worker thread crashed (call under the cv)."""
        if self._crash is not None:
            raise RuntimeError(
                "worker pool thread crashed — serving state is suspect"
            ) from self._crash

    def wait_idle(self) -> None:
        """Block until no admitted session remains (queued or checked
        out), the admission queue is empty, and every worker is idle."""
        with self._cv:
            while True:
                self.check()
                if (self._svc.scheduler.running == 0
                        and not self._svc._waiting
                        and not self._groups
                        and self._busy == 0):
                    return
                self._cv.wait(self._POLL_S)

    def shutdown(self) -> None:
        """Stop and join the workers.  In-flight steps complete (their
        checkin runs); nothing new is checked out afterwards."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=10.0)
