"""QuipService: concurrent query serving with shared state.

The serving layer the ROADMAP's "heavy traffic" north star needs on top of
the single-query engine: a submit/poll/result API over an epoch-versioned
:class:`TableRegistry`, admission control with a configurable in-flight
limit plus per-tenant quotas, a QoS morsel scheduler (round-robin,
weighted-fair, or deadline — see service/scheduler.py), an LRU plan cache,
an answer-level result cache keyed on table epochs, and (gated)
cross-query imputation sharing.  Registry mutations invalidate every
dependent cache (see docs/serving.md "Invalidation & result cache").

::

    registry = TableRegistry(tables)
    service = QuipService(registry, imputer_factory, max_inflight=4,
                          shared_impute=True)
    t1 = service.submit(q1); t2 = service.submit(q2, tenant=7)
    service.run_until_idle()
    res = service.result(t1)           # ExecutionResult
    registry.update_rows("R0", rows, {"R0.v": new_vals})  # epoch bump +
    service.submit(q1)                 # ... fresh plan, fresh answer
    print(service.summary())           # serving_* telemetry

Compound (§9.3) queries route through sessions too: ``submit_union`` /
``submit_minus`` submit both branches concurrently, ``submit_nested`` runs
the subquery session first and submits the rewritten outer query when it
completes; ``result`` on a compound ticket returns ``(answers, stats)``
with the branches' full merged counters, exactly like
``repro.core.extensions``.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.analysis.lockcheck import make_condition, make_lock, make_rlock
from repro.core.compiled import (
    CompiledPlan,
    CompileFallback,
    compile_plan,
    resolve_exec_impl,
)
from repro.core.executor import ExecutionResult
from repro.core.extensions import (
    merge_stats,
    minus_answers,
    nested_outer_query,
    union_answers,
)
from repro.core.plan import Query
from repro.core.relation import MaskedRelation
from repro.core.stats import ExecutionCounters, QueryRecord, ServingStats
from repro.imputers.base import ImputationService, Imputer
from repro.obs import (
    ProvenanceRecorder,
    build_service_metrics,
    render_explain,
    resolve_explain,
    resolve_tracer,
)
from repro.service.impute_store import SharedImputeStore, resolve_shared_impute
from repro.service.ivm import IvmMaintainer, make_record, resolve_ivm
from repro.service.plan_cache import PlanCache, query_signature
from repro.service.registry import TableRegistry
from repro.service.result_cache import ResultCache
from repro.service.scheduler import MorselScheduler
from repro.service.session import DONE, FAILED, QUEUED, RUNNING, QuerySession
from repro.service.workers import WorkerPool

__all__ = ["QuipService", "SUMMARY_KEYS", "expected_summary_keys"]


# --------------------------------------------------------------------------- #
# summary() schema — every key QuipService.summary() can emit, in one place.
# tests/test_obs.py pins the schema against this via expected_summary_keys();
# adding a key without documenting it here fails that test on purpose.
# --------------------------------------------------------------------------- #
SUMMARY_KEYS: Dict[str, str] = {
    # -- ServingStats.summary() -------------------------------------------- #
    "queries": "finished queries (failures included)",
    "failed": "finished queries that failed",
    "tenants": "distinct tenants across finished queries",
    "morsel_steps": "scheduler-granted morsel steps",
    "sched_cost": "total scheduler-charged cost (cost-model units)",
    "p50_latency_s": "median submit-to-result latency (s)",
    "p95_latency_s": "p95 submit-to-result latency (s)",
    "queue_wait_s": "total submit-to-admission wait (s)",
    "max_concurrent": "peak concurrently admitted sessions",
    "admission_queued": "submissions that had to wait for a slot",
    "queries_plan_cache_hit": "finished queries served a cached plan",
    "queries_result_cache_hit": "finished queries served a cached answer",
    "invalidation_events": "registry mutations observed",
    "plans_invalidated": "plan-cache entries evicted by mutations",
    "results_invalidated": "cached answers purged by mutations",
    "store_cells_invalidated": "shared-store cells dropped by mutations",
    "results_patched": "cached answers patched in place by IVM (QUIP_IVM)",
    "ivm_fallbacks": "IVM maintenance attempts that fell back to eviction",
    "imputations": "cells actually imputed (model evaluations)",
    "impute_batches": "deduplicated imputer invocations",
    "impute_cross_hits": "cells served from another query's store fill",
    "compiled_hits": "executions served by a compiled tensor plan",
    "compile_fallbacks": "compiled dispatch that fell back to the interpreter",
    # -- plan cache (LruCache.stats() + compiled artifacts) ---------------- #
    "plan_cache_size": "cached plan signatures",
    "plan_cache_hits": "plan-cache hits (unfinished queries included)",
    "plan_cache_misses": "plan-cache misses",
    "plan_cache_evictions": "plan-cache capacity evictions",
    "plan_cache_invalidations": "plan-cache entries evicted by mutations",
    "plan_cache_compiled": "live compiled artifacts on cached plans",
    # -- service configuration / registry ---------------------------------- #
    "exec_impl": "executor dispatch (interp | compiled)",
    "registry_epoch": "registry global mutation epoch",
    "shared_impute": "cross-query imputation sharing on (0/1)",
    "scheduler_policy": "morsel scheduling policy (rr | wfq | deadline)",
    "sched_clock": "scheduler cost clock (cost-model units)",
    # -- conditional: result cache on (result_cache_size > 0) -------------- #
    "result_cache_size": "cached answers (iff result cache enabled)",
    "result_cache_hits": "result-cache hits (iff enabled)",
    "result_cache_misses": "result-cache misses (iff enabled)",
    "result_cache_evictions": "result-cache capacity evictions (iff enabled)",
    "result_cache_invalidations": "cached answers purged (iff enabled)",
    # -- conditional: shared impute store on ------------------------------- #
    "store_filled_cells": "imputed cells resident in the shared store "
                          "(iff shared_impute)",
}

_RESULT_CACHE_KEYS = (
    "result_cache_size", "result_cache_hits", "result_cache_misses",
    "result_cache_evictions", "result_cache_invalidations",
)
_STORE_KEYS = ("store_filled_cells",)


def expected_summary_keys(*, result_cache: bool = True,
                          shared_store: bool = False) -> set:
    """The exact key set ``QuipService.summary()`` emits for a service
    configured with/without the result cache and the shared impute store."""
    keys = set(SUMMARY_KEYS)
    if not result_cache:
        keys -= set(_RESULT_CACHE_KEYS)
    if not shared_store:
        keys -= set(_STORE_KEYS)
    return keys


@dataclasses.dataclass
class _Compound:
    """A §9.3 compound query tracked across its branch sessions."""

    kind: str  # "union" | "minus" | "nested"
    tickets: List[int]  # branch tickets, in combination order
    # nested only: the outer query awaiting the subquery's result
    outer: Optional[Query] = None
    in_attr: Optional[str] = None
    strategy: Optional[str] = None
    tenant: Optional[int] = None
    result: Optional[Tuple[List[tuple], Dict]] = None


class QuipService:
    """Concurrent query-serving engine over an epoch-versioned registry.

    ``tables`` may be a plain dict (wrapped in a private
    :class:`TableRegistry`) or an existing registry, possibly shared with
    other services.  Mutations go through the registry's mutation API; the
    service subscribes to them and keeps every cache honest: dependent plan
    cache entries are evicted (their selectivity-driven join order is
    stale), cached answers are purged, and the shared impute store drops
    the mutated table's cells and fitted models.  Queries admitted after a
    mutation observe the new data; queries admitted before keep their
    point-in-time snapshot.

    The answer-level :class:`ResultCache` (``result_cache_size=0``
    disables) is keyed on (query signature, exec-knob signature, table
    epochs), so a repeated signature on unmutated tables skips planning and
    execution entirely and any mutation makes the stale key unreachable.
    """

    def __init__(
        self,
        tables: Dict[str, MaskedRelation],
        imputer_factory: Callable[[], Imputer],
        per_attr: Optional[Dict[str, Imputer]] = None,
        *,
        max_inflight: int = 4,
        plan_cache_size: int = 64,
        result_cache_size: int = 128,
        shared_impute: Optional[bool] = None,
        strategy: str = "adaptive",
        planner: str = "imputedb",
        morsel_rows: int = 8192,
        bloom_impl: Optional[str] = None,
        join_impl: Optional[str] = None,
        minmax_opt: bool = True,
        use_vf: bool = True,
        scheduler_policy: str = "rr",
        cost_model: str = "active",
        tenant_weights: Optional[Dict] = None,
        default_weight: float = 1.0,
        tenant_deadlines: Optional[Dict] = None,
        default_deadline: Optional[float] = None,
        tenant_quotas: Optional[Dict] = None,
        default_tenant_quota: Optional[int] = None,
        workers: int = 0,
        exec_impl: Optional[str] = None,
        compile_after_hits: int = 2,
        tracer=None,
        explain: Optional[bool] = None,
        ivm: Optional[bool] = None,
    ):
        assert max_inflight >= 1
        # compiled tensor plans (docs/compiled.md): with
        # exec_impl="compiled" (or QUIP_EXEC_IMPL=compiled) a signature is
        # lowered via compile_plan once its plan-cache hit count reaches
        # compile_after_hits; ineligible combinations (lazy/adaptive,
        # use_vf, active MIN/MAX pushdown) cache their CompileFallback and
        # keep running the morsel interpreter, bit-identically.
        self.exec_impl = resolve_exec_impl(exec_impl)
        if compile_after_hits < 1:
            raise ValueError(
                f"compile_after_hits must be >= 1, got {compile_after_hits}"
            )
        self.compile_after_hits = int(compile_after_hits)
        self.registry: TableRegistry = (
            tables if isinstance(tables, TableRegistry)
            else TableRegistry(tables)
        )
        # the registry is a Mapping — a drop-in for the old tables dict
        self.tables = self.registry
        self._factory = imputer_factory
        self._per_attr = dict(per_attr or {})
        self.max_inflight = int(max_inflight)
        self.default_strategy = strategy
        self.shared_impute = resolve_shared_impute(shared_impute)
        self.store: Optional[SharedImputeStore] = (
            SharedImputeStore(self.registry) if self.shared_impute else None
        )
        self.plan_cache = PlanCache(plan_cache_size, planner=planner)
        self.result_cache: Optional[ResultCache] = (
            ResultCache(result_cache_size) if result_cache_size else None
        )
        self.scheduler = MorselScheduler(
            scheduler_policy,
            weights=tenant_weights,
            default_weight=default_weight,
            deadlines=tenant_deadlines,
            default_deadline=default_deadline,
            cost_model=cost_model,
        )
        # observability (docs/observability.md): tracer accepts a Tracer
        # instance, a bool, or None (QUIP_TRACE env); disabled means the
        # shared zero-allocation NULL_TRACER everywhere.  explain gates
        # per-query impute provenance (QUIP_EXPLAIN env when None).
        self.tracer = resolve_tracer(tracer)
        self.scheduler.tracer = self.tracer
        self.explain_enabled = resolve_explain(explain)
        self._explains: Dict[int, Dict] = {}  # guarded-by: _lock|_cv
        # per-tenant admission quota: at most N concurrently *admitted*
        # sessions per tenant (None = unlimited); the global max_inflight
        # still caps the total.  Quota-blocked sessions are skipped, not
        # head-of-line blockers — later tenants admit past them.  A quota
        # below 1 could never admit — run_until_idle would spin forever.
        for t, q in (tenant_quotas or {}).items():
            if q < 1:
                raise ValueError(
                    f"tenant {t!r} quota must be >= 1, got {q}"
                )
        if default_tenant_quota is not None and default_tenant_quota < 1:
            raise ValueError(
                f"default_tenant_quota must be >= 1, got "
                f"{default_tenant_quota}"
            )
        self._tenant_quotas = dict(tenant_quotas or {})
        self._default_tenant_quota = default_tenant_quota
        # mutation-invalidation counters live on serving too; direct bumps
        # take the dedicated telemetry lock so the lint's lock pass covers
        # them (lock order: _lock -> _tel_lock, never the reverse)
        self._tel_lock = make_lock("QuipService._tel_lock")
        self.serving = ServingStats()  # guarded-by: _tel_lock
        self._exec_kwargs = {
            "morsel_rows": morsel_rows,
            "bloom_impl": bloom_impl,
            "join_impl": join_impl,
            "minmax_opt": minmax_opt,
            "use_vf": use_vf,
        }
        self._tickets = itertools.count(1)
        self._sessions: Dict[int, QuerySession] = {}  # guarded-by: _lock|_cv
        self._waiting: Deque[QuerySession] = deque()  # guarded-by: _lock|_cv
        self._compounds: Dict[int, _Compound] = {}  # guarded-by: _lock|_cv
        self._pending_compounds: set = set()  # step-scan set  # guarded-by: _lock|_cv
        # one reentrant lock guards ALL shared serving state (scheduler
        # queues, sessions, caches, telemetry); the condition signals
        # workers on admission and callers on completion — it *wraps the
        # same RLock*, so `with self._cv` and `with self._lock` are the
        # same critical section (one sanitizer node).  Serial mode
        # (workers=0) takes the same lock — uncontended, and it keeps the
        # registry's mutation hooks safe if a pool-mode service shares the
        # registry with a serial one.
        self._lock = make_rlock("QuipService._lock")
        self._cv = make_condition(self._lock)
        self._pool: Optional[WorkerPool] = None  # guarded-by: _lock|_cv
        # delta-driven cache maintenance (QUIP_IVM, docs/ivm.md): instead of
        # purging every dependent cached answer on mutation, patch the ones
        # the delta algebra can maintain exactly; needs the result cache and
        # per-query provenance (the imputed-table overlap rule reads it)
        self._ivm: Optional[IvmMaintainer] = (
            IvmMaintainer(self.registry, self.result_cache, self._factory,
                          self._per_attr)
            if resolve_ivm(ivm) and self.result_cache is not None else None
        )
        self.registry.subscribe(self._on_mutation,
                                before=self._check_mutation_safe,
                                delta=True)
        if workers:
            # workers >= 1: N threads pull morsel steps via the scheduler's
            # checkout/checkin split; step() is disabled (it would race)
            self._pool = WorkerPool(self, workers)
        # metric collectors close over live objects (incl. the pool), so
        # build the registry last; it adds no bookkeeping of its own
        self._metrics = build_service_metrics(self)

    # ------------------------------------------------------------------ #
    # per-query resources
    # ------------------------------------------------------------------ #
    def _make_engine(self, tables: Dict[str, MaskedRelation]
                     ) -> ImputationService:
        # the engine carries the query's observability handles: executors
        # read tracer/provenance off it (getattr), and _flush_key feeds
        # the provenance recorder at the exact counter-increment site.
        # IVM also needs provenance: without the imputed-table set a cached
        # answer cannot prove the mutated table never fed its imputations.
        prov = (ProvenanceRecorder()
                if self.explain_enabled or self._ivm is not None else None)
        if self.store is not None:
            return self.store.bind(self._factory, self._per_attr,
                                   tracer=self.tracer, provenance=prov)
        # isolation (safe default): a cold engine per query, exactly the
        # serial-replay construction — equivalence is trivial by design.
        # The engine only reads its tables, so it shares the session's
        # copies rather than paying a second copy per query.
        return ImputationService(
            tables, default=self._factory, per_attr=self._per_attr,
            tracer=self.tracer, provenance=prov,
        )

    # ------------------------------------------------------------------ #
    # submit / poll / result
    # ------------------------------------------------------------------ #
    def _result_key(self, query: Query, strategy: str) -> Optional[Tuple]:
        """ResultCache key for ``query`` at the registry's *current* epochs
        (None when caching is off or the query names an unknown table —
        the latter is left to fail loudly at admission)."""
        if self.result_cache is None:
            return None
        try:
            epochs = self.registry.epochs(query.tables)
        except KeyError:
            return None
        # scheduling knobs (policy, weights, deadlines, quotas, cost
        # model) are deliberately NOT part of the key: answers are
        # policy-independent (see docs/serving.md "Scheduling & QoS"),
        # so an answer computed under one policy is valid under any other
        exec_sig = (strategy, self.shared_impute, self.exec_impl) + tuple(
            sorted(self._exec_kwargs.items())
        )
        return (query_signature(query, self.plan_cache.planner), exec_sig,
                epochs)

    def _session_setup(self, query: Query, strategy: str,
                       extra_dep_tables: Tuple[str, ...] = ()):
        """Materialize a session's resources — at admission in serial mode,
        at the first morsel step (on a worker, off the service lock) in
        pool mode; either way a deep waiting queue holds no table copies
        and the latency clock covers planning like a cold serial run."""
        with self._lock:
            fallback = None
            if strategy == "offline":
                # the offline baseline never consults a plan — don't pay for
                # (or skew the telemetry of) planning it
                plan, hit = None, False
            else:
                plan, hit = self.plan_cache.get(
                    query, self.tables, extra_dep_tables=extra_dep_tables
                )
            if (plan is not None and self.exec_impl == "compiled" and hit
                    and self.plan_cache.hit_count(query)
                    >= self.compile_after_hits):
                # hot signature: serve (or lower and stamp) a compiled
                # artifact keyed by the tables' current epochs — a stale
                # stamp is never served (plan_cache.compiled_artifact),
                # and mutation hooks evict the whole entry anyway
                epochs = self.registry.epochs(query.tables)
                artifact = self.plan_cache.compiled_artifact(
                    query, strategy, epochs
                )
                if artifact is None:
                    try:
                        artifact = compile_plan(
                            query, plan, self.tables, strategy,
                            use_vf=self._exec_kwargs["use_vf"],
                            minmax_opt=self._exec_kwargs["minmax_opt"],
                            join_impl=self._exec_kwargs["join_impl"],
                        )
                    except CompileFallback as e:
                        # cache the fallback too — this signature can
                        # never lower under these knobs; don't retry
                        artifact = e
                    self.plan_cache.store_compiled(
                        query, strategy, epochs, artifact
                    )
                if isinstance(artifact, CompiledPlan):
                    plan = artifact
                else:
                    fallback = artifact
            # snapshot references + epochs atomically: the registry is
            # copy-on-write, so the heavy per-table copies can run off the
            # lock on the snapshot objects (never mutated in place), while
            # the result key still matches exactly what the copies observe.
            # The key is computed here, not at submit: a mutation may land
            # while the session waits in the admission queue.
            snaps = {t: self.tables[t] for t in query.tables}
            key = self._result_key(query, strategy)
        tables = {t: rel.copy() for t, rel in snaps.items()}
        engine = self._make_engine(tables)
        if fallback is not None:
            engine.counters.compile_fallbacks += 1
        return plan, engine, tables, hit, key

    def submit(self, query: Query, *, strategy: Optional[str] = None,
               tenant: Optional[int] = None,
               extra_dep_tables: Tuple[str, ...] = ()) -> int:
        """Enqueue a query; returns its ticket.  The result cache is
        consulted first: a signature already answered at the current table
        epochs completes immediately without planning or execution.
        Otherwise admission is immediate when fewer than ``max_inflight``
        sessions are running and the tenant is under its quota, else the
        session waits (FIFO, quota-blocked sessions skipped in place).

        ``extra_dep_tables`` widens the cache-dependency set beyond the
        query's own tables — a compound outer query rewritten from a
        sub-query result depends on the sub-query's tables too, even though
        its signature never names them (they used to leak)."""
        strategy = strategy or self.default_strategy
        with self._lock:
            if self.result_cache is not None:
                key = self._result_key(query, strategy)
                cached = (self.result_cache.get(key)
                          if key is not None else None)
                if cached is not None:
                    session = QuerySession.from_cached(
                        next(self._tickets), query, strategy, cached, tenant
                    )
                    self._sessions[session.ticket] = session
                    if self.tracer.enabled:
                        session.trace_span = self.tracer.begin(
                            "query", cat="query", ticket=session.ticket,
                            tenant=tenant, strategy=strategy,
                            result_cache_hit=True)
                    if self.explain_enabled:
                        self._explains[session.ticket] = {
                            "ticket": session.ticket, "strategy": strategy,
                            "result_cache_hit": True,
                        }
                    self._finalize(session)
                    return session.ticket
            session = QuerySession(
                ticket=next(self._tickets),
                query=query,
                strategy=strategy,
                setup=lambda: self._session_setup(query, strategy,
                                                  extra_dep_tables),
                tenant=tenant,
                exec_kwargs=self._exec_kwargs,
                extra_dep_tables=extra_dep_tables,
            )
            self._sessions[session.ticket] = session
            session.tracer = self.tracer
            if self.tracer.enabled:
                session.trace_span = self.tracer.begin(
                    "query", cat="query", ticket=session.ticket,
                    tenant=tenant, strategy=strategy,
                    policy=self.scheduler.policy, exec_impl=self.exec_impl,
                    epoch=self.registry.global_epoch)
            self._waiting.append(session)
            self._admit()
            if session.state == QUEUED:  # ring full or quota exhausted
                with self._tel_lock:
                    self.serving.admission_queued += 1
            return session.ticket

    def poll(self, ticket: int) -> str:
        """State of a plain or compound ticket:
        queued | running | done | failed."""
        with self._lock:
            return self._poll_locked(ticket)

    def _poll_locked(self, ticket: int) -> str:
        comp = self._compounds.get(ticket)
        if comp is not None:
            if comp.result is None and ticket in self._pending_compounds:
                # truthful polling: branches may all be finished already
                # (result-cache hits, a step on another ticket) — combine
                # now instead of reporting a phantom "running"
                self._resolve_compounds()
            if comp.result is not None:
                return DONE
            branches = [self._sessions[t].state for t in comp.tickets]
            if FAILED in branches:
                return FAILED
            if all(s == QUEUED for s in branches):
                return QUEUED
            return RUNNING
        return self._sessions[ticket].state

    def step(self) -> bool:
        """One scheduler tick (one morsel of one session) plus any admission
        and compound resolution it unlocks.  Returns True if work remains.

        Inline stepping and a worker pool would race on the same scheduler
        queues — with ``workers >= 1`` use ``run_until_idle``/``result``
        (the pool drives progress) instead."""
        if self._pool is not None:
            raise RuntimeError(
                "step() drives the scheduler inline and would race the "
                "worker pool — use run_until_idle()/result(), or build "
                "the service with workers=0"
            )
        with self._lock:
            finished = self.scheduler.step()
            if finished is not None:
                self._finalize(finished)
            self._admit()
            self._resolve_compounds()
            return bool(self.scheduler.running or self._waiting)

    def run_until_idle(self) -> None:
        if self._pool is not None:
            self._pool.wait_idle()
            with self._lock:  # safety net — checkins resolve incrementally
                self._resolve_compounds()
            return
        while self.step():
            pass

    def result(self, ticket: int):
        """Block until ``ticket`` finishes — by driving the scheduler
        inline (serial mode) or by waiting on the workers (pool mode).

        Plain tickets return the :class:`ExecutionResult`; compound tickets
        return ``(answers, stats)`` (see ``submit_union`` etc.)."""
        if self._pool is not None:
            return self._threaded_result(ticket)
        if ticket in self._compounds:
            return self._compound_result(ticket)
        session = self._sessions[ticket]
        while session.state in (QUEUED, RUNNING):
            if not self.step():
                break
        if session.state == FAILED:
            raise session.error
        assert session.state == DONE, session.state
        return session.result

    def _threaded_result(self, ticket: int):
        """Pool-mode ``result``: wait on the condition until the workers
        finish the ticket (or a branch fails / a worker crashes)."""
        with self._cv:
            comp = self._compounds.get(ticket)
            if comp is not None:
                while comp.result is None:
                    for t in comp.tickets:  # tickets may grow (nested)
                        if self._sessions[t].state == FAILED:
                            raise self._sessions[t].error
                    self._pool.check()
                    self._cv.wait(0.05)
                return comp.result
            session = self._sessions[ticket]
            while session.state in (QUEUED, RUNNING):
                self._pool.check()
                self._cv.wait(0.05)
            if session.state == FAILED:
                raise session.error
            assert session.state == DONE, session.state
            return session.result

    def answers(self, ticket: int) -> List[tuple]:
        """Answer tuples of a plain or compound ticket (drives the
        scheduler to completion like :meth:`result`)."""
        if ticket in self._compounds:
            answers, _stats = self.result(ticket)
            return answers
        return self.result(ticket).answer_tuples()

    def close(self) -> None:
        """Detach from the registry's subscriber hooks and cancel the
        admission queue.

        Detaching is required when the registry outlives the service
        (several services over one shared registry): an
        attached-but-discarded service would be kept alive by the
        subscription, its plan/result caches never freed, and every future
        mutation would still pay its invalidation scan.

        Queued-but-never-admitted sessions are **cancelled, not dropped**:
        each lands a ``failed=True`` QueryRecord (extending the PR 4
        "failures are telemetry" fix to shutdown), ``poll`` reports
        ``failed``, and ``result`` raises the cancellation.  Already
        admitted sessions are untouched — drain them first
        (``run_until_idle``) for a clean shutdown, or after close() via
        ``step``/``result``, which no longer admits anything new.

        With a worker pool, close() first stops and joins the workers
        (in-flight steps complete and check in); the pool is detached, so
        inline ``step``/``result`` work again on whatever remains."""
        if self._pool is not None:
            self._pool.shutdown()  # joins — must not hold the lock here
            self._pool = None  # unguarded: workers joined; no concurrent readers remain
        with self._lock:
            self.registry.unsubscribe(self._on_mutation)
            while self._waiting:
                session = self._waiting.popleft()
                session.cancel(RuntimeError(
                    f"service closed before ticket {session.ticket} was "
                    f"admitted"
                ))
                self._finalize(session)

    def release(self, ticket: int) -> None:
        """Drop a finished ticket's retained result.

        Sessions keep their :class:`ExecutionResult` (the materialized
        answer relation) until released so ``result``/``answers`` stay
        idempotent; a long-lived service under sustained traffic should
        release tickets once consumed.  Telemetry (``serving.records``)
        is unaffected.  Compound release also drops the branch sessions."""
        with self._lock:
            self._release_locked(ticket)

    def _release_locked(self, ticket: int) -> None:  # requires: _lock|_cv
        comp = self._compounds.get(ticket)
        if comp is not None:
            branch_states = [self._sessions[t].state for t in comp.tickets]
            assert comp.result is not None or FAILED in branch_states, (
                f"release of unfinished compound ticket {ticket}"
            )
            del self._compounds[ticket]
            self._pending_compounds.discard(ticket)
            for t in comp.tickets:
                self.release(t)
            return
        session = self._sessions[ticket]
        assert session.state in (DONE, FAILED), (
            f"release of unfinished ticket {ticket} ({session.state})"
        )
        del self._sessions[ticket]
        self._explains.pop(ticket, None)

    # ------------------------------------------------------------------ #
    # compound (§9.3) queries — routed through sessions
    # ------------------------------------------------------------------ #
    def submit_union(self, left: Query, right: Query, *,
                     strategy: Optional[str] = None,
                     tenant: Optional[int] = None) -> int:
        return self._submit_compound("union", left, right,
                                     strategy=strategy, tenant=tenant)

    def submit_minus(self, left: Query, right: Query, *,
                     strategy: Optional[str] = None,
                     tenant: Optional[int] = None) -> int:
        return self._submit_compound("minus", left, right,
                                     strategy=strategy, tenant=tenant)

    def submit_nested(self, outer: Query, in_attr: str, sub: Query, *,
                      strategy: Optional[str] = None,
                      tenant: Optional[int] = None) -> int:
        """Outer query with ``in_attr IN (sub)``: the subquery session runs
        first (blocking subtree); the rewritten outer query is submitted the
        moment it completes."""
        with self._lock:
            sub_ticket = self.submit(sub, strategy=strategy, tenant=tenant)
            ticket = next(self._tickets)
            self._compounds[ticket] = _Compound(
                kind="nested", tickets=[sub_ticket], outer=outer,
                in_attr=in_attr, strategy=strategy, tenant=tenant,
            )
            self._pending_compounds.add(ticket)
            # the subquery may already be DONE (result-cache hit): resolve
            # now so the outer query is submitted — and possibly combined —
            # without waiting for an unrelated step() to notice
            self._resolve_compounds()
            return ticket

    def _submit_compound(self, kind: str, left: Query, right: Query, *,
                         strategy: Optional[str], tenant: Optional[int]) -> int:
        with self._lock:
            lt = self.submit(left, strategy=strategy, tenant=tenant)
            rt = self.submit(right, strategy=strategy, tenant=tenant)
            ticket = next(self._tickets)
            self._compounds[ticket] = _Compound(kind=kind, tickets=[lt, rt])
            self._pending_compounds.add(ticket)
            # both branches may have completed at submit (result-cache
            # hits): resolve immediately so poll() never reports "running"
            # for a compound whose work is already done
            self._resolve_compounds()
            return ticket

    def _resolve_compounds(self) -> None:  # requires: _lock|_cv
        # Fixpoint, not a single sweep: submitting a nested compound's outer
        # query can itself complete via the result cache, which makes the
        # compound combinable in the same call (the submit-time resolution
        # the poll() contract depends on).
        progress = True
        while progress:
            progress = False
            for ticket in list(self._pending_compounds):
                comp = self._compounds[ticket]
                if comp.result is not None:
                    self._pending_compounds.discard(ticket)
                    continue
                if any(self._sessions[t].state == FAILED
                       for t in comp.tickets):
                    # never resolvable — stop rescanning it every step; the
                    # branch error surfaces via result()/poll()
                    self._pending_compounds.discard(ticket)
                    continue
                if comp.kind == "nested" and comp.outer is not None:
                    sub = self._sessions[comp.tickets[0]]
                    if sub.state == DONE:
                        outer2 = nested_outer_query(
                            comp.outer, comp.in_attr, sub.result
                        )
                        # the rewritten outer query bakes the sub-query's
                        # answer into an IN-set: its cached plan/answer must
                        # also die when a *sub-query* table mutates
                        comp.tickets.append(self.submit(
                            outer2, strategy=comp.strategy,
                            tenant=comp.tenant,
                            extra_dep_tables=tuple(
                                t for t in sub.query.tables
                                if t not in outer2.tables
                            ),
                        ))
                        comp.outer = None  # outer submitted; await it
                        progress = True
                    continue
                sessions = [self._sessions[t] for t in comp.tickets]
                if comp.kind != "nested" and len(sessions) < 2:
                    continue
                if all(s.state == DONE for s in sessions):
                    comp.result = self._combine(comp, sessions)
                    self._pending_compounds.discard(ticket)
                    progress = True

    def _combine(self, comp: _Compound, sessions: List[QuerySession]
                 ) -> Tuple[List[tuple], Dict]:
        stats = merge_stats(*(s.result.counters for s in sessions))
        if comp.kind == "union":
            answers = union_answers(sessions[0].result.answer_tuples(),
                                    sessions[1].result.answer_tuples())
        elif comp.kind == "minus":
            answers = minus_answers(sessions[0].result.answer_tuples(),
                                    sessions[1].result.answer_tuples())
        else:  # nested: the outer session's answer is the result
            answers = sessions[-1].result.answer_tuples()
        return answers, stats

    def _compound_result(self, ticket: int) -> Tuple[List[tuple], Dict]:
        comp = self._compounds[ticket]
        while comp.result is None:
            for t in comp.tickets:
                if self._sessions[t].state == FAILED:
                    raise self._sessions[t].error
            if not self.step():
                self._resolve_compounds()
                if comp.result is None:
                    for t in comp.tickets:
                        if self._sessions[t].state == FAILED:
                            raise self._sessions[t].error
                    raise RuntimeError("compound query stuck (branch failed?)")
        return comp.result

    # ------------------------------------------------------------------ #
    # admission + finalization
    # ------------------------------------------------------------------ #
    def _tenant_quota(self, tenant) -> Optional[int]:
        return self._tenant_quotas.get(tenant, self._default_tenant_quota)

    def _admit(self) -> None:  # requires: _lock|_cv
        # FIFO except for per-tenant quotas: a session whose tenant is at
        # its quota is skipped (put back at the front, order preserved) so
        # one tenant's flood cannot head-of-line-block everyone else's
        # admissions; it is reconsidered as soon as a slot frees up.
        quota_blocked: Deque[QuerySession] = deque()
        while self._waiting and self.scheduler.running < self.max_inflight:
            session = self._waiting.popleft()
            quota = self._tenant_quota(session.tenant)
            if (quota is not None
                    and self.scheduler.tenant_running(session.tenant)
                    >= quota):
                quota_blocked.append(session)
                continue
            if self._pool is not None:
                # planning + table copies run at the first morsel step on
                # whichever worker claims the session (off this lock), and
                # order-independent sibling morsels fan through the pool
                session.defer_setup = True
                session.task_runner = self._pool.map_morsels
            self.scheduler.add(session)
            if session.state == FAILED:
                self._finalize(session)
        self._waiting.extendleft(reversed(quota_blocked))
        self.serving.observe_concurrency(self.scheduler.running)
        if self._pool is not None:
            self._cv.notify_all()  # wake idle workers for the new sessions

    # ------------------------------------------------------------------ #
    # worker-pool hooks (called by WorkerPool under the service lock)
    # ------------------------------------------------------------------ #
    def _checkout_session(self) -> Optional[QuerySession]:
        return self.scheduler.next_session()

    def _checkin_session(self, session: QuerySession, finished: bool) -> None:
        self.scheduler.checkin(session, finished)
        if finished:
            self._finalize(session)
        self._admit()
        self._resolve_compounds()
        self._cv.notify_all()  # wake result()/wait_idle() waiters

    def _finalize(self, session: QuerySession) -> None:  # requires: _lock|_cv
        if session.state == DONE:
            if session.result_cache_hit:
                # no relational work ran — record the hit with empty
                # counters so totals keep meaning "work actually done"
                counters = ExecutionCounters(
                    join_impl=session.result.counters.join_impl,
                    exec_impl=session.result.counters.exec_impl,
                )
            else:
                counters = session.result.counters
                self._cache_result(session)
        else:  # FAILED: the query still consumed admission + scheduling —
            # record it (counters as far as the session got) instead of
            # silently dropping it from the telemetry
            counters = (
                dataclasses.replace(session.engine.counters)
                if session.engine is not None else ExecutionCounters()
            )
        # harvest impute provenance before release_resources drops the
        # engine; the report reconciles with the recorded counters exactly
        # (on_flush mirrors every counters.imputations increment)
        if (self.explain_enabled and session.engine is not None
                and getattr(session.engine, "provenance", None) is not None):
            report = session.engine.provenance.report()
            report["ticket"] = session.ticket
            report["strategy"] = session.strategy
            report["failed"] = session.state == FAILED
            report["counters_imputations"] = counters.imputations
            self._explains[session.ticket] = report
        if session.trace_span is not None:
            self.tracer.end(session.trace_span, state=session.state,
                            steps=session.steps_taken,
                            sched_cost=round(session.sched_cost, 9))
            session.trace_span = None
        self.serving.record_query(QueryRecord(
            ticket=session.ticket,
            tenant=session.tenant,
            strategy=session.strategy,
            queue_wait_s=session.queue_wait_s,
            latency_s=session.latency_s,
            plan_cache_hit=session.plan_cache_hit,
            counters=counters,
            result_cache_hit=session.result_cache_hit,
            failed=session.state == FAILED,
            steps=session.steps_taken,
            sched_cost=session.sched_cost,
            # None survives: a never-admitted session (cancelled queue,
            # setup failure) must not masquerade as "admitted at clock 0"
            admit_clock=session.admit_clock,
            finish_clock=session.finish_clock,
            deadline_met=session.deadline_met,
        ))
        # only the result (and its counters) outlives completion — the
        # table copies / engine / coroutine are the session's bulk
        session.release_resources()

    def _cache_result(self, session: QuerySession) -> None:
        """Insert a completed execution into the result cache, unless a
        mutation landed mid-flight (the key's epochs no longer match — the
        snapshot this session answered from is already stale).

        With IVM on, the entry also carries its maintenance sidecar (the
        query, the provenance-derived imputed-table set, and any aggregate
        auxiliary state); the dependency set registered in the reverse
        index includes the session's extra dependency tables so compound
        rewrites invalidate on their sub-query's tables too."""
        if self.result_cache is None or session.result_key is None:
            return
        current = self._result_key(session.query, session.strategy)
        if current != session.result_key:
            return
        record = None
        if self._ivm is not None:
            prov = (getattr(session.engine, "provenance", None)
                    if session.engine is not None else None)
            record = make_record(session.query, session.result, prov)
        deps = tuple(session.query.tables) + tuple(session.extra_dep_tables)
        self.result_cache.put(session.result_key, session.result,
                              ivm=record, tables=deps)

    # ------------------------------------------------------------------ #
    # registry-mutation invalidation (subscribed in __init__)
    # ------------------------------------------------------------------ #
    def _check_mutation_safe(self, table: str) -> None:
        """Pre-commit veto: with a shared impute store, mutating a table
        that running sessions are reading would mix epochs inside one query
        (their executors scan pre-mutation snapshots while the store refits
        on the new rows).  Fail loud before anything is committed; drain
        first.  Per-query isolation needs no veto — admitted sessions own
        point-in-time copies."""
        if self.store is None:
            return
        with self._lock:
            busy = [s.ticket for s in self.scheduler.sessions()
                    if table in s.query.tables]
        if busy:
            raise RuntimeError(
                f"mutation of {table!r} while shared-impute sessions "
                f"{busy} are reading it — drain the service first "
                f"(run_until_idle) or use per-query isolation"
            )

    def _on_mutation(self, table: str, delta=None) -> None:
        """Post-commit maintenance: the mutated table's epoch already
        advanced.  Plans are always evicted (their join order came from
        now-stale selectivity scans).  Cached answers are evicted too —
        unless IVM is on, in which case the maintainer patches every
        dependent answer the delta algebra can maintain exactly and evicts
        only the fallbacks (per dependent entry, exactly one of
        ``results_patched`` / ``ivm_fallbacks`` advances)."""
        with self._lock:
            plans = self.plan_cache.invalidate_table(table)
            patched = 0
            if self._ivm is not None:
                patched, results = self._ivm.apply(table, delta)
            else:
                results = (
                    self.result_cache.invalidate_table(table)
                    if self.result_cache is not None else 0
                )
            cells = (self.store.invalidate(table)
                     if self.store is not None else 0)
            with self._tel_lock:
                self.serving.invalidation_events += 1
                self.serving.plans_invalidated += plans
                self.serving.results_invalidated += results
                self.serving.store_cells_invalidated += cells
                self.serving.results_patched += patched
                if self._ivm is not None:
                    self.serving.ivm_fallbacks += results

    # ------------------------------------------------------------------ #
    # telemetry
    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        """Flat ``serving_*``-ready metrics: scheduling, plan cache, result
        cache, invalidation, and cross-query imputation sharing."""
        with self._lock:
            return self._summary_locked()

    def _summary_locked(self) -> Dict[str, float]:
        with self._tel_lock:  # consistent snapshot of the counter fields
            out = self.serving.summary()
        out.update({
            f"plan_cache_{k}": v for k, v in self.plan_cache.stats().items()
        })
        out["plan_cache_compiled"] = self.plan_cache.compiled_count()
        out["exec_impl"] = self.exec_impl
        if self.result_cache is not None:
            out.update({
                f"result_cache_{k}": v
                for k, v in self.result_cache.stats().items()
            })
        out["registry_epoch"] = self.registry.global_epoch
        out["shared_impute"] = int(self.shared_impute)
        out["scheduler_policy"] = self.scheduler.policy
        out["sched_clock"] = round(self.scheduler.clock, 6)
        if self.store is not None:
            out["store_filled_cells"] = self.store.filled_cells()
        return out

    def tenant_summary(self) -> Dict:
        """Per-tenant QoS telemetry over finished queries: p50/p95
        latency, queue wait, morsel steps, charged cost + cost share,
        p95 turnaround on the scheduler clock, deadline hit-rate
        (see :meth:`ServingStats.tenant_summary`)."""
        with self._lock:
            return self.serving.tenant_summary()

    # ------------------------------------------------------------------ #
    # observability: metrics / explain / trace export
    # ------------------------------------------------------------------ #
    def metrics(self, fmt: str = "json"):
        """Metrics snapshot over the live serving state (no duplicate
        bookkeeping — collectors read the same objects ``summary()``
        folds).  ``fmt="json"`` returns the nested dict,
        ``fmt="prometheus"`` the text exposition format.  Collected under
        the service lock, so one call is internally consistent."""
        with self._lock:
            if fmt == "json":
                return self._metrics.snapshot()
            if fmt == "prometheus":
                return self._metrics.prometheus()
            raise ValueError(
                f"unknown metrics format {fmt!r} "
                f"(expected 'json' or 'prometheus')"
            )

    def explain(self, ticket: int) -> Dict:
        """The impute-provenance report of a finished ticket: decision-
        function log, per-operator imputation sites, and totals that
        reconcile exactly with the query's recorded counters.  Requires
        ``explain=True`` (or ``QUIP_EXPLAIN``) at construction; compound
        tickets return ``{"compound": kind, "branches": [...]}``.  The
        report is dropped with :meth:`release`."""
        with self._lock:
            if not self.explain_enabled:
                raise RuntimeError(
                    "explain is disabled — construct QuipService with "
                    "explain=True (or set QUIP_EXPLAIN=1)"
                )
            comp = self._compounds.get(ticket)
            if comp is not None:
                return {
                    "ticket": ticket,
                    "compound": comp.kind,
                    "branches": [self._explains[t] for t in comp.tickets],
                }
            return self._explains[ticket]

    def explain_text(self, ticket: int) -> str:
        """:meth:`explain` rendered as a human-readable report."""
        report = self.explain(ticket)
        if "compound" in report:
            parts = [f"explain ticket={ticket} "
                     f"compound={report['compound']}"]
            parts.extend(render_explain(b) for b in report["branches"])
            return "\n".join(parts)
        return render_explain(report)

    def export_trace(self, path: Optional[str] = None,
                     ticket: Optional[int] = None) -> Dict:
        """The recorded spans as a Chrome trace-event document (load in
        Perfetto / chrome://tracing).  ``ticket`` filters to one query;
        ``path`` also writes the JSON to disk.  Returns the document."""
        with self._lock:
            doc = self.tracer.chrome_trace(ticket=ticket)
        if path is not None:
            with open(path, "w") as fh:
                json.dump(doc, fh, indent=1, default=str)
        return doc
