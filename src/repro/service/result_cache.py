"""Answer-level LRU result cache, keyed on query signature × knobs × epochs.

The plan cache shares planning and the shared impute store shares imputed
values, but until this layer an identical query signature still re-executed
all of the relational work.  The :class:`TableRegistry`'s epochs are what
make caching the *answer* sound: the key is

    (query_signature, exec-knob signature, epochs of the tables read)

so a hit is only possible when every table the query reads is bit-identical
to the execution that produced the cached answer — execution is a
deterministic function of (query, knobs, tables) (imputers included; see
docs/serving.md), hence the cached :class:`ExecutionResult` is exactly what
re-running would produce.  Any mutation bumps the touched table's epoch,
which makes all dependent keys unreachable; the IVM maintainer
(``repro.service.ivm``, gated by ``QUIP_IVM``) then either *patches* the
entry onto the new epoch vector or purges it (``invalidate_table`` /
``invalidate_key``) so stale answers don't squat in the LRU.

Each entry carries an optional :class:`~repro.service.ivm.IvmRecord`
sidecar (the query, provenance-derived imputed-table set, and aggregate
auxiliary state) that makes patching possible; entries cached without one
(IVM off, or no provenance available) simply fall back to eviction.

``QuipService.submit`` consults the cache before planning; a completed
session inserts its result keyed on the epochs it actually observed at
admission (and skips insertion if a mutation landed mid-flight).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Tuple

from repro.core.executor import ExecutionResult
from repro.service.lru import LruCache

__all__ = ["ResultCache", "CachedResult"]

# (query_signature, exec_signature, per-table epochs); the query signature's
# second element is the tables tuple (see plan_cache.query_signature), which
# drives the reverse index (plus any extra dependency tables the serving
# layer registers for compound sub-queries).
ResultKey = Tuple[Tuple, Tuple, Tuple[int, ...]]


@dataclasses.dataclass
class CachedResult:
    """One cache slot: the materialized answer plus the IVM sidecar
    (``None`` when the entry is not incrementally maintainable)."""

    result: ExecutionResult
    ivm: Optional[object] = None  # IvmRecord; typed loosely to avoid a cycle


class ResultCache(LruCache):
    """LRU over :data:`ResultKey` → :class:`CachedResult`
    (answer relation + counters + IVM sidecar), with hit/miss/invalidation
    telemetry.

    Cached results are shared, read-only objects: callers consume them via
    ``answer_tuples()`` / counters and must not mutate the relation.
    ``invalidate_table`` purges every entry depending on the mutated table
    in O(dependents) (the bumped epoch already makes them unreachable;
    purging frees the memory now).
    """

    def __init__(self, capacity: int = 128):
        super().__init__(capacity)

    def get(self, key: ResultKey) -> Optional[ExecutionResult]:
        entry = self.lookup(key)
        return None if entry is None else entry.result

    def put(self, key: ResultKey, result: ExecutionResult,
            ivm: Optional[object] = None,
            tables: Optional[Iterable[str]] = None) -> None:
        """Cache ``result``; ``ivm`` is the maintenance sidecar and
        ``tables`` widens the dependency set beyond the signature's own
        tables (compound sub-query dependencies)."""
        self.insert(key, CachedResult(result, ivm), tables=tables)

    def entry(self, key: ResultKey) -> Optional[CachedResult]:
        """The full slot (result + sidecar) without LRU/stat effects —
        the IVM maintainer's accessor."""
        return self.peek(key)

    def _key_tables(self, key: ResultKey) -> Tuple[str, ...]:
        return key[0][1]  # the query signature's tables tuple
