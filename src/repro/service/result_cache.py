"""Answer-level LRU result cache, keyed on query signature × knobs × epochs.

The plan cache shares planning and the shared impute store shares imputed
values, but until this layer an identical query signature still re-executed
all of the relational work.  The :class:`TableRegistry`'s epochs are what
make caching the *answer* sound: the key is

    (query_signature, exec-knob signature, epochs of the tables read)

so a hit is only possible when every table the query reads is bit-identical
to the execution that produced the cached answer — execution is a
deterministic function of (query, knobs, tables) (imputers included; see
docs/serving.md), hence the cached :class:`ExecutionResult` is exactly what
re-running would produce.  Any mutation bumps the touched table's epoch,
which makes all dependent keys unreachable; ``invalidate_table`` also purges
them eagerly so stale answers don't squat in the LRU.

``QuipService.submit`` consults the cache before planning; a completed
session inserts its result keyed on the epochs it actually observed at
admission (and skips insertion if a mutation landed mid-flight).
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.core.executor import ExecutionResult
from repro.service.lru import LruCache

__all__ = ["ResultCache"]

# (query_signature, exec_signature, per-table epochs); the query signature's
# second element is the tables tuple (see plan_cache.query_signature), which
# invalidate_table scans.
ResultKey = Tuple[Tuple, Tuple, Tuple[int, ...]]


class ResultCache(LruCache):
    """LRU over :data:`ResultKey` → materialized :class:`ExecutionResult`
    (answer relation + counters), with hit/miss/invalidation telemetry.

    Cached results are shared, read-only objects: callers consume them via
    ``answer_tuples()`` / counters and must not mutate the relation.
    ``invalidate_table`` purges every entry whose query reads the mutated
    table (the bumped epoch already makes them unreachable; purging frees
    the memory now).
    """

    def __init__(self, capacity: int = 128):
        super().__init__(capacity)

    def get(self, key: ResultKey) -> Optional[ExecutionResult]:
        return self.lookup(key)

    def put(self, key: ResultKey, result: ExecutionResult) -> None:
        self.insert(key, result)

    def _key_tables(self, key: ResultKey) -> Tuple[str, ...]:
        return key[0][1]  # the query signature's tables tuple
