"""Capacity-bounded LRU shared by the serving caches.

PlanCache and ResultCache need identical bookkeeping — an OrderedDict LRU
with hit/miss/eviction counters, flat ``stats()``, and table-driven
invalidation for registry mutations.  One implementation lives here;
subclasses only say which tables a cached key depends on.

Invalidation is O(dependents), not O(cache): every insert registers the
entry under each table it depends on in a per-table reverse index, so a
registry mutation touches exactly the dependent keys.  The dependency set
defaults to :meth:`_key_tables` (the tables named in the key itself) but
can be widened per entry via ``insert(..., tables=...)`` — the serving
layer uses this for answers whose signature names a table only inside a
compound sub-query, which the key-derived scan used to leak.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

__all__ = ["LruCache"]


class LruCache:
    """OrderedDict-backed LRU with hit/miss/eviction/invalidation counters
    and a per-table reverse index for O(dependents) invalidation.

    Subclasses implement :meth:`_key_tables` — the base tables an entry
    was derived from — the default dependency set an insert registers in
    the reverse index (override per entry with ``insert(tables=...)``).

    ``capacity=0`` disables the cache uniformly: every ``lookup`` is a
    counted miss and ``insert`` is a no-op, so call sites need no special
    casing (``QuipService(plan_cache_size=0)`` / ``result_cache_size=0``
    both mean "cache off").  Negative capacities raise :class:`ValueError`
    — a real exception, not an ``assert`` that ``python -O`` strips."""

    def __init__(self, capacity: int):
        capacity = int(capacity)
        if capacity < 0:
            raise ValueError(
                f"cache capacity must be >= 0 (0 disables the cache), "
                f"got {capacity}"
            )
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        # reverse index: table -> set of keys depending on it, mirrored by
        # key -> dependency tuple so removal can unlink without rescanning
        self._by_table: Dict[str, set] = {}
        self._deps: Dict[object, Tuple[str, ...]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key) -> Optional[object]:
        """Entry for ``key`` (LRU-touched, counted) or None on a miss."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return entry
        self.misses += 1
        return None

    def peek(self, key) -> Optional[object]:
        """Entry for ``key`` without LRU movement or hit/miss counting —
        for maintenance passes (IVM patching), not serving lookups."""
        return self._entries.get(key)

    def insert(self, key, value,
               tables: Optional[Iterable[str]] = None) -> None:
        """Insert/overwrite ``key``.  ``tables`` is the dependency set
        registered in the reverse index (default: :meth:`_key_tables`)."""
        if self.capacity == 0:  # disabled: hold nothing, evict nothing
            return
        if key in self._entries:
            self._unlink(key)
        deps = tuple(dict.fromkeys(
            self._key_tables(key) if tables is None else tables
        ))
        self._entries[key] = value
        self._entries.move_to_end(key)
        self._deps[key] = deps
        for t in deps:
            self._by_table.setdefault(t, set()).add(key)
        while len(self._entries) > self.capacity:
            old_key, _ = self._entries.popitem(last=False)
            self._unlink(old_key)
            self.evictions += 1

    def _unlink(self, key) -> None:
        """Drop ``key`` from the reverse index (entry removal follows or
        already happened)."""
        for t in self._deps.pop(key, ()):
            bucket = self._by_table.get(t)
            if bucket is not None:
                bucket.discard(key)
                if not bucket:
                    del self._by_table[t]

    def remove(self, key) -> bool:
        """Silently drop one entry (no eviction/invalidation counting) —
        the IVM maintainer uses this to re-key a patched entry."""
        if key not in self._entries:
            return False
        del self._entries[key]
        self._unlink(key)
        return True

    def keys_for_table(self, table: str) -> Tuple[object, ...]:
        """Keys currently depending on ``table`` (snapshot copy)."""
        return tuple(self._by_table.get(table, ()))

    def dependencies(self, key) -> Tuple[str, ...]:
        """The dependency set ``key`` was inserted under."""
        return self._deps.get(key, ())

    def _key_tables(self, key) -> Iterable[str]:
        raise NotImplementedError

    def invalidate_table(self, table: str) -> int:
        """Purge every entry depending on ``table``; returns the count.
        O(dependents) via the reverse index — a mutation no longer pays a
        full-cache scan."""
        stale = self.keys_for_table(table)
        for k in stale:
            del self._entries[k]
            self._unlink(k)
        self.invalidations += len(stale)
        return len(stale)

    def invalidate_key(self, key) -> bool:
        """Purge one entry, counted as an invalidation (the IVM fallback
        path: a delta arrived but this answer could not be patched)."""
        if self.remove(key):
            self.invalidations += 1
            return True
        return False

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
        }
