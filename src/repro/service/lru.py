"""Capacity-bounded LRU shared by the serving caches.

PlanCache and ResultCache need identical bookkeeping — an OrderedDict LRU
with hit/miss/eviction counters, flat ``stats()``, and table-driven
invalidation for registry mutations.  One implementation lives here;
subclasses only say which tables a cached key depends on.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, Optional

__all__ = ["LruCache"]


class LruCache:
    """OrderedDict-backed LRU with hit/miss/eviction/invalidation counters.

    Subclasses implement :meth:`_key_tables` — the base tables an entry
    was derived from — so :meth:`invalidate_table` can purge everything a
    registry mutation staled.

    ``capacity=0`` disables the cache uniformly: every ``lookup`` is a
    counted miss and ``insert`` is a no-op, so call sites need no special
    casing (``QuipService(plan_cache_size=0)`` / ``result_cache_size=0``
    both mean "cache off").  Negative capacities raise :class:`ValueError`
    — a real exception, not an ``assert`` that ``python -O`` strips."""

    def __init__(self, capacity: int):
        capacity = int(capacity)
        if capacity < 0:
            raise ValueError(
                f"cache capacity must be >= 0 (0 disables the cache), "
                f"got {capacity}"
            )
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key) -> Optional[object]:
        """Entry for ``key`` (LRU-touched, counted) or None on a miss."""
        entry = self._entries.get(key)
        if entry is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return entry
        self.misses += 1
        return None

    def insert(self, key, value) -> None:
        if self.capacity == 0:  # disabled: hold nothing, evict nothing
            return
        self._entries[key] = value
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def _key_tables(self, key) -> Iterable[str]:
        raise NotImplementedError

    def invalidate_table(self, table: str) -> int:
        """Purge every entry derived from ``table``; returns the count."""
        stale = [k for k in self._entries if table in self._key_tables(k)]
        for k in stale:
            del self._entries[k]
        self.invalidations += len(stale)
        return len(stale)

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
        }
