"""One submitted query inside QuipService: state machine + step coroutine.

A session owns everything per-query: the table copies its executor scans,
its ImputationService (possibly store-backed), its plan clone, and the
``QuipExecutor.steps()`` generator the scheduler advances.  Those
resources are built lazily by the injected ``setup`` callable at
*admission* (``start``), not at submission — a deep admission queue must
not hold table copies, and the latency clock covers planning exactly like
a cold serial run does.  Lifecycle::

    QUEUED --admit--> RUNNING --steps exhausted--> DONE
                         \\--exception-----------> FAILED

``strategy="offline"`` runs the offline baseline as a single step (it is a
blocking whole-table pass by definition — nothing to interleave).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional, Tuple

from repro.core.compiled import CompiledPlan
from repro.core.executor import (
    ExecutionResult,
    QuipExecutor,
    execute_offline,
)
from repro.core.plan import PlanNode, Query
from repro.core.relation import MaskedRelation
from repro.imputers.base import ImputationService
from repro.obs.trace import NULL_SPAN, NULL_TRACER

__all__ = ["QuerySession", "QUEUED", "RUNNING", "DONE", "FAILED"]

QUEUED, RUNNING, DONE, FAILED = "queued", "running", "done", "failed"

# plan (None for offline, a CompiledPlan when the service promoted the
# signature — see QuipService compile_after_hits), engine, table copies,
# plan_cache_hit, result-cache key (epochs at admission; None = don't cache)
SessionSetup = Callable[
    [], Tuple[Optional[PlanNode], ImputationService,
              Dict[str, MaskedRelation], bool, Optional[Tuple]]
]


class QuerySession:
    def __init__(
        self,
        ticket: int,
        query: Query,
        strategy: str,
        setup: SessionSetup,
        tenant: Optional[int] = None,
        exec_kwargs: Optional[Dict] = None,
        extra_dep_tables: Tuple[str, ...] = (),
    ):
        self.ticket = ticket
        self.query = query
        self.strategy = strategy
        self.tenant = tenant
        self._setup = setup
        self.exec_kwargs = dict(exec_kwargs or {})
        # cache-dependency tables beyond query.tables (compound rewrites:
        # the baked-in IN-set depends on the sub-query's tables)
        self.extra_dep_tables: Tuple[str, ...] = tuple(extra_dep_tables)

        self.plan: Optional[PlanNode] = None
        self.engine: Optional[ImputationService] = None
        self.tables: Optional[Dict[str, MaskedRelation]] = None
        self.plan_cache_hit = False
        self.result_cache_hit = False
        # worker-pool mode: materialize resources at the *first step*
        # (off the admission lock) instead of inside start(), and fan
        # intra-query sibling morsels through this runner (see
        # service/workers.py); both are set by QuipService._admit
        self.defer_setup = False
        self.task_runner = None
        # observability: the service points these at its Tracer and the
        # query-lifetime span id (begin/end — cross-thread safe); the
        # defaults keep standalone sessions zero-overhead
        self.tracer = NULL_TRACER
        self.trace_span: Optional[int] = None
        # set at admission: where a DONE result may be inserted in the
        # ResultCache (captures the table epochs the execution observed)
        self.result_key: Optional[Tuple] = None

        # -- per-step QoS accounting (read/written by MorselScheduler) -- #
        self.last_step_wall_s = 0.0  # wall seconds of the latest morsel
        self.last_step_sim_s = 0.0  # simulated imputation seconds, ditto
        self.steps_taken = 0  # morsel steps (== scheduler steps charged)
        self.active_s = 0.0  # total wall+simulated across all steps
        self.sched_cost = 0.0  # cost charged under the scheduler's model
        self.admit_clock: Optional[float] = None  # scheduler clock at add
        self.finish_clock: Optional[float] = None  # ... at completion
        self.deadline: Optional[float] = None  # absolute, on the clock axis
        self.deadline_met: Optional[bool] = None

        self.state = QUEUED
        self.submitted_at = time.perf_counter()
        self.started_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self.result: Optional[ExecutionResult] = None
        self.error: Optional[BaseException] = None
        self._gen: Optional[Iterator[None]] = None
        self._executor = None

    # -- timeline ---------------------------------------------------------#
    @property
    def queue_wait_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return self.started_at - self.submitted_at

    @property
    def latency_s(self) -> float:
        if self.finished_at is None:
            return 0.0
        return self.finished_at - self.submitted_at

    # -- lifecycle --------------------------------------------------------#
    @classmethod
    def from_cached(cls, ticket: int, query: Query, strategy: str,
                    result: ExecutionResult,
                    tenant: Optional[int] = None) -> "QuerySession":
        """A session born DONE from a result-cache hit: no resources, no
        scheduling — ``result``/``answers``/``poll`` behave exactly like a
        session that ran (the cached ExecutionResult is shared, read-only)."""
        session = cls(ticket, query, strategy, setup=lambda: None,
                      tenant=tenant)
        session.result = result
        session.result_cache_hit = True
        session.state = DONE
        session.started_at = session.submitted_at
        session.finished_at = time.perf_counter()
        return session

    def start(self) -> None:
        """Admission: materialize resources, build the step coroutine.

        With ``defer_setup`` (worker-pool mode) admission only flips the
        state — planning and table copies run inside the first ``step()``
        on whichever worker picks the session up, so they never serialize
        under the service lock; a setup failure then surfaces exactly like
        a first-morsel failure (FAILED, finalized by the pool)."""
        assert self.state == QUEUED, self.state
        self.started_at = time.perf_counter()
        self.state = RUNNING
        if not self.defer_setup:
            self._materialize()

    def _materialize(self) -> None:
        tr = self.tracer
        with (tr.span("session_setup", cat="sched", ticket=self.ticket,
                      parent=self.trace_span)
              if tr.enabled else NULL_SPAN) as sp:
            self._materialize_body()
            if tr.enabled:
                sp.set(plan_cache_hit=self.plan_cache_hit,
                       state=self.state)

    def _materialize_body(self) -> None:
        try:
            (self.plan, self.engine, self.tables,
             self.plan_cache_hit, self.result_key) = self._setup()
            if self.strategy == "offline":
                self._gen = self._offline_steps()
            elif isinstance(self.plan, CompiledPlan):
                # promoted hot signature: one straight-line vectorized pass
                # (a single blocking step, like offline — there are no
                # morsels to interleave)
                self._gen = self._compiled_steps()
            else:
                executor = QuipExecutor(
                    self.query,
                    self.tables,
                    self.plan,
                    self.engine,
                    strategy=self.strategy,
                    **self.exec_kwargs,
                )
                executor.task_runner = self.task_runner
                self._executor = executor
                self._gen = executor.steps()
        except Exception as e:  # plan/setup errors surface via result()
            self._fail(e)

    def _offline_steps(self) -> Iterator[None]:
        self.result = execute_offline(self.query, self.tables, self.engine)
        return
        yield  # pragma: no cover - makes this a generator

    def _compiled_steps(self) -> Iterator[None]:
        self.result = self.plan.run(self.tables, self.engine)
        return
        yield  # pragma: no cover - makes this a generator

    def step(self) -> bool:
        """Advance one morsel; True when the session left RUNNING.

        Each step records its own **active time** — the wall seconds the
        morsel consumed plus the delta of the engine's simulated
        imputation seconds — so the QoS scheduler can charge a 50 ms
        ρ-fixpoint morsel 50× a 1 ms scan morsel instead of one ticket."""
        if self.state != RUNNING:
            return True
        tr = self.tracer
        with (tr.span("morsel_step", cat="sched", ticket=self.ticket,
                      parent=self.trace_span, step=self.steps_taken)
              if tr.enabled else NULL_SPAN) as sp:
            if self._gen is None:  # deferred setup: first step materializes
                self._materialize()
                if self.state != RUNNING:
                    sp.set(state=self.state)
                    return True
            sim0 = (self.engine.simulated_seconds
                    if self.engine is not None else 0.0)
            t0 = time.perf_counter()
            try:
                next(self._gen)
                finished = False
            except StopIteration:
                if self.result is None:
                    self.result = self._executor.result
                self.state = DONE
                self.finished_at = time.perf_counter()
                finished = True
            except Exception as e:  # query errors surface via result();
                self._fail(e)       # KeyboardInterrupt/SystemExit propagate
                finished = True
            wall = time.perf_counter() - t0
            sim = (self.engine.simulated_seconds
                   if self.engine is not None else 0.0) - sim0
            self.last_step_wall_s = wall
            self.last_step_sim_s = sim
            self.steps_taken += 1
            self.active_s += wall + sim
            if tr.enabled:
                sp.set(finished=finished, state=self.state)
        return finished

    def cancel(self, error: BaseException) -> None:
        """Fail a never-admitted (QUEUED) session — e.g. the admission
        queue being cancelled at ``QuipService.close()``.  The session
        lands a ``failed=True`` QueryRecord instead of vanishing; its
        queue-wait covers submit → cancellation."""
        assert self.state == QUEUED, self.state
        self.started_at = time.perf_counter()
        self._fail(error)

    def _fail(self, error: BaseException) -> None:
        self.state = FAILED
        self.error = error
        self.finished_at = time.perf_counter()

    def release_resources(self) -> None:
        """Drop per-query execution state once the session has finished.

        The table copies, engine, plan and coroutine are the bulk of a
        session's footprint; a long-lived service only needs the result
        (and its counters) after completion."""
        assert self.state in (DONE, FAILED), self.state
        self.engine = None
        self.tables = None
        self.plan = None
        self._gen = None
        self._executor = None
