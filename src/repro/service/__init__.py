"""QuipService — the concurrent query-serving subsystem.

Layers (see docs/serving.md):

* :mod:`repro.service.server`       — submit/poll/result API + admission
* :mod:`repro.service.registry`     — epoch-versioned mutable table registry
* :mod:`repro.service.scheduler`    — QoS morsel scheduler (rr/wfq/deadline)
* :mod:`repro.service.session`      — per-query state machine
* :mod:`repro.service.plan_cache`   — LRU plan cache (canonical signatures)
* :mod:`repro.service.result_cache` — answer cache keyed on table epochs
* :mod:`repro.service.impute_store` — cross-query imputation sharing
* :mod:`repro.service.workers`      — threaded morsel worker pool
"""

from repro.service.impute_store import SharedImputeStore, resolve_shared_impute
from repro.service.plan_cache import PlanCache, query_signature
from repro.service.registry import TableRegistry
from repro.service.result_cache import ResultCache
from repro.service.scheduler import COST_MODELS, POLICIES, MorselScheduler
from repro.service.server import QuipService
from repro.service.session import QuerySession
from repro.service.workers import WorkerPool

__all__ = [
    "QuipService",
    "QuerySession",
    "WorkerPool",
    "MorselScheduler",
    "POLICIES",
    "COST_MODELS",
    "PlanCache",
    "query_signature",
    "ResultCache",
    "SharedImputeStore",
    "TableRegistry",
    "resolve_shared_impute",
]
