"""Delta-driven result-cache maintenance (DBSP-style IVM), gated by QUIP_IVM.

Before this module every registry mutation burned all dependent cached
answers.  The maintainer turns each commit's :class:`TableDelta` into a
*patch* on the cached answers that provably stay exact, and counts a
fallback (today's eviction) everywhere exactness cannot be proven —
answers stay bit-identical to cold replay **by construction**, never by
hope (docs/ivm.md carries the full argument; the serving fuzzer's
delta-mode profile checks it against cold serial replay).

The linearity that makes patching possible: QUIP answers are
strategy-independent multisets, so the pre-aggregate body of a query is a
bag-linear function of each base table with the others held fixed.  A
commit mutates exactly one table, so the delta-join ΔQ = Q with T replaced
by ΔT (the other delta-join terms vanish) — evaluated here by a cold
offline sub-execution over ``{T: ΔT-part, S: current S}``:

* **select/project answers** — the cached answer is a Z-set over answer
  tuples; the patch is ``old − Q(removed ⋈ rest) + Q(added ⋈ rest)``
  (plain :class:`~repro.core.delta.ZSet` arithmetic).
* **COUNT/SUM/AVG aggregates** — per-group ``(n_rows, n_present, exact
  total)`` sidecars (:class:`~repro.core.executor.AggAux`) recorded at
  execution time are combined linearly and the answer relation rebuilt
  bit-for-bit (:func:`~repro.core.executor.relation_from_agg_aux`).

Fallback (evict + count) whenever:

* the commit has no delta (``replace_table``, duplicate update rows);
* the cached answer's provenance shows imputed cells on the mutated table
  (refitting the imputer on the mutated table can change what unchanged
  rows impute to — the imputation-interaction rule from the issue);
* delta rows carry missing values on attributes the query references
  (they would be imputed against a mini-table fit, not the cold fit);
* MIN/MAX (not linear), float-typed SUM/AVG or totals outside the exact
  float64 bound, group-by columns with missing/NaN cells;
* the entry depends on the mutated table only through a compound
  sub-query (the IN-literal may change — the old entry would squat);
* the stored epoch vector is not exactly "current epochs with the mutated
  table one behind" (the entry predates an unmaintained commit);
* answers contain NaN (NaN != NaN breaks multiset arithmetic) or any
  patched weight/count would go negative.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, FrozenSet, Iterable, Optional, Set, Tuple

import numpy as np

from repro.core.delta import TableDelta, ZSet
from repro.core.env import env_flag
from repro.core.executor import (
    AggAux,
    ExecutionResult,
    GroupStat,
    agg_aux_of,
    execute_offline,
    relation_from_agg_aux,
)
from repro.core.plan import Query
from repro.core.relation import MaskedRelation
from repro.imputers.base import ImputationService
from repro.obs.provenance import ProvenanceRecorder

__all__ = ["resolve_ivm", "IvmRecord", "IvmMaintainer", "referenced_attrs"]

_PATCHABLE_AGGS = ("count", "sum", "avg")


def resolve_ivm(ivm: Optional[bool] = None) -> bool:
    """Explicit argument > ``QUIP_IVM`` env (truthy/falsy via
    :func:`env_flag`) > off."""
    if ivm is not None:
        return bool(ivm)
    return env_flag("QUIP_IVM", False)


@dataclasses.dataclass
class IvmRecord:
    """Maintenance sidecar cached next to an answer.

    ``imputed_tables`` is the provenance-exact set of tables whose
    imputation machinery showed *any* activity (computed, cached, or
    cross-query hits) while producing the answer — a mutation on one of
    them must evict, because refitting can change what the unchanged rows
    impute to.  Patching widens the set with the sub-execution's own
    provenance, so the rule stays sound across repeated patches."""

    query: Query
    imputed_tables: FrozenSet[str]
    agg_aux: Optional[AggAux] = None


def make_record(query: Query, result: ExecutionResult,
                provenance: Optional[ProvenanceRecorder]
                ) -> Optional[IvmRecord]:
    """Build the sidecar for a finished execution, or ``None`` when the
    entry cannot be maintained (no provenance was recorded — without it
    the imputation-interaction rule cannot be checked)."""
    if provenance is None:
        return None
    imputed = _active_tables(provenance)
    return IvmRecord(query, frozenset(imputed), result.agg_aux)


def _active_tables(provenance: ProvenanceRecorder) -> Set[str]:
    report = provenance.report()
    return {
        s["table"] for s in report["sites"]
        if s["requested"] or s["computed"] or s["cache_hits"]
        or s["cross_hits"]
    }


def referenced_attrs(query: Query,
                     tables: Dict[str, Iterable[str]]) -> Dict[str, Set[str]]:
    """Per-table attribute sets the answer can depend on: predicates,
    projection, aggregate attr/group-by — or every column when the query
    outputs whole rows (no projection, no aggregate).  ``tables`` maps
    table → its column names (for the whole-row case)."""
    out: Dict[str, Set[str]] = {t: set() for t in query.tables}
    attrs = list(query.predicate_attrs()) + list(query.projection)
    if query.aggregate is not None:
        if query.aggregate.attr:
            attrs.append(query.aggregate.attr)
        if query.aggregate.group_by:
            attrs.append(query.aggregate.group_by)
    elif not query.projection:
        for t in query.tables:
            out[t].update(tables[t])
    for a in attrs:
        t = a.split(".", 1)[0]
        if t in out:
            out[t].add(a)
    return out


class _Fallback(Exception):
    """Internal: this entry cannot be patched exactly — evict it."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


class IvmMaintainer:
    """Applies one table's :class:`TableDelta` to every dependent result-
    cache entry: patch where exact, evict (and count the fallback reason)
    otherwise.  Runs under the service lock — single-writer over the
    cache, like the plain invalidation path it replaces."""

    def __init__(self, registry, result_cache, imputer_factory,
                 per_attr: Optional[Dict] = None):
        self.registry = registry
        self.result_cache = result_cache
        self._factory = imputer_factory
        self._per_attr = dict(per_attr or {})
        # telemetry only (read by tests/benchmarks; no lock discipline —
        # mutated solely under the service lock via apply())
        self.fallback_reasons: Counter = Counter()

    # ------------------------------------------------------------------ #
    # entry points
    # ------------------------------------------------------------------ #
    def apply(self, table: str,
              delta: Optional[TableDelta]) -> Tuple[int, int]:
        """Maintain every cached answer depending on ``table``; returns
        ``(patched, evicted)``.  Every dependent entry lands in exactly
        one bucket — the accounting invariant the fuzzer checks."""
        cache = self.result_cache
        patched = evicted = 0
        for key in cache.keys_for_table(table):
            entry = cache.entry(key)
            if entry is None:  # pragma: no cover - snapshot is atomic
                continue
            try:
                self._maintain(key, entry, table, delta)
                patched += 1
            except _Fallback as f:
                self.fallback_reasons[f.reason] += 1
                cache.invalidate_key(key)
                evicted += 1
            except Exception:  # pragma: no cover - defensive: never stale
                self.fallback_reasons["error"] += 1
                cache.invalidate_key(key)
                evicted += 1
        return patched, evicted

    # ------------------------------------------------------------------ #
    # per-entry maintenance
    # ------------------------------------------------------------------ #
    def _maintain(self, key, entry, table: str,
                  delta: Optional[TableDelta]) -> None:
        if delta is None:
            raise _Fallback("no_delta")
        record: Optional[IvmRecord] = entry.ivm
        if record is None:
            raise _Fallback("no_record")
        sig_tables = key[0][1]
        if table not in sig_tables:
            # dependency via a compound sub-query: the rewritten IN-set may
            # change with the sub-table, so the entry must not survive
            raise _Fallback("compound_dep")
        reg = self.registry
        expected = tuple(
            reg.epoch(t) - (1 if t == table else 0) for t in sig_tables
        )
        if tuple(key[2]) != expected:
            raise _Fallback("stale_epochs")
        if table in record.imputed_tables:
            raise _Fallback("imputed_overlap")
        query = record.query
        referenced = referenced_attrs(
            query, {t: reg[t].column_names() for t in query.tables}
        )
        for part in (delta.removed, delta.added):
            if part is None:
                continue
            for a in referenced.get(table, ()):
                if part.missing[a].any():
                    raise _Fallback("delta_missing")
        if query.aggregate is not None:
            new_result, imputed = self._patch_aggregate(
                entry, query, table, delta, referenced
            )
        else:
            new_result, imputed = self._patch_tuples(
                entry, query, table, delta, referenced
            )
        new_key = (key[0], key[1], reg.epochs(sig_tables))
        new_record = IvmRecord(
            query, record.imputed_tables | frozenset(imputed),
            new_result.agg_aux,
        )
        deps = self.result_cache.dependencies(key)
        self.result_cache.remove(key)
        self.result_cache.put(new_key, new_result, ivm=new_record,
                              tables=deps)

    # -- delta sub-execution -------------------------------------------- #
    def _run_delta(self, body_query: Query, table: str,
                   part: Optional[MaskedRelation],
                   referenced: Dict[str, Set[str]]
                   ) -> Optional[Tuple[ExecutionResult, Set[str]]]:
        """Evaluate the query body over ``{table: delta-part, others:
        current registry copies}`` with a cold engine — the one surviving
        delta-join term, since the commit touched a single table.  Missing
        bits on unreferenced attributes are cleared first (those cells
        cannot affect the answer; imputing them against the mini delta
        table would be wasted and, worse, fit-dependent).  Returns
        ``(result, provenance-active tables)`` or ``None`` for an empty
        side."""
        if part is None or part.num_rows == 0:
            return None
        sub_tables: Dict[str, MaskedRelation] = {}
        for t in body_query.tables:
            rel = (part if t == table else self.registry[t]).copy()
            refs = referenced.get(t, set())
            for a in rel.column_names():
                if a not in refs and rel.missing[a].any():
                    rel.missing[a][:] = False
            sub_tables[t] = rel
        prov = ProvenanceRecorder()
        engine = ImputationService(
            sub_tables, default=self._factory, per_attr=self._per_attr,
            provenance=prov,
        )
        result = execute_offline(body_query, sub_tables, engine)
        return result, _active_tables(prov)

    # -- select/project answers ----------------------------------------- #
    def _patch_tuples(self, entry, query: Query, table: str,
                      delta: TableDelta, referenced
                      ) -> Tuple[ExecutionResult, Set[str]]:
        old = entry.result
        old_tuples = old.relation.to_sorted_tuples()
        _check_no_nan(old_tuples)
        imputed: Set[str] = set()
        zset = ZSet.from_rows(old_tuples)
        for part, sign in ((delta.removed, -1), (delta.added, +1)):
            ran = self._run_delta(query, table, part, referenced)
            if ran is None:
                continue
            result, active = ran
            imputed |= active
            tuples = result.answer_tuples()
            _check_no_nan(tuples)
            side = ZSet.from_rows(tuples)
            zset = zset.add(side if sign > 0 else side.negate())
        zset = zset.consolidate()
        if not zset.is_positive():
            raise _Fallback("negative_weight")
        rel = _relation_from_tuples(old.relation.schema, zset)
        new_result = ExecutionResult(rel, old.counters, old.stats, old.plan)
        return new_result, imputed

    # -- COUNT/SUM/AVG aggregates ---------------------------------------- #
    def _patch_aggregate(self, entry, query: Query, table: str,
                         delta: TableDelta, referenced
                         ) -> Tuple[ExecutionResult, Set[str]]:
        agg = query.aggregate
        old_aux: Optional[AggAux] = (
            entry.ivm.agg_aux if entry.ivm is not None else None
        )
        if agg.op not in _PATCHABLE_AGGS:
            raise _Fallback("minmax")
        if old_aux is None or not old_aux.valid:
            raise _Fallback("no_aux")
        if agg.op != "count" and (agg.attr is None
                                  or old_aux.attr_kind != "int"):
            raise _Fallback("float_agg")
        body_query = Query(query.tables, query.selections, query.joins,
                           (), None)
        imputed: Set[str] = set()
        side_aux: Dict[int, Optional[AggAux]] = {-1: None, +1: None}
        for part, sign in ((delta.removed, -1), (delta.added, +1)):
            ran = self._run_delta(body_query, table, part, referenced)
            if ran is None:
                continue
            result, active = ran
            imputed |= active
            aux = agg_aux_of(result.relation, agg)
            if not aux.valid:
                raise _Fallback("group_keys")
            side_aux[sign] = aux
        new_aux = _merge_aux(old_aux, side_aux[-1], side_aux[+1])
        rel = relation_from_agg_aux(new_aux, entry.result.relation.schema)
        if rel is None:
            raise _Fallback("aux_rebuild")
        old = entry.result
        new_result = ExecutionResult(rel, old.counters, old.stats, old.plan,
                                     agg_aux=new_aux)
        return new_result, imputed


# ------------------------------------------------------------------------- #
# pure helpers
# ------------------------------------------------------------------------- #
def _check_no_nan(tuples) -> None:
    for row in tuples:
        for v in row:
            if isinstance(v, float) and v != v:
                raise _Fallback("nan_answer")


def _relation_from_tuples(schema, zset: ZSet) -> MaskedRelation:
    """Materialize a consolidated answer Z-set back into a relation with
    the cached answer's schema.  ``None`` cells get the absent bit (any
    payload round-trips to ``None`` in ``to_sorted_tuples``, which also
    re-sorts — insertion order is irrelevant)."""
    rows = []
    for tup, w in zset.consolidate().items():
        rows.extend([tup] * w)
    names = schema.column_names()
    cols = {n: np.zeros(len(rows), dtype=schema.column(n).np_dtype)
            for n in names}
    absent = {n: np.zeros(len(rows), dtype=bool) for n in names}
    for i, tup in enumerate(rows):
        for n, v in zip(names, tup):
            if v is None:
                absent[n][i] = True
            else:
                cols[n][i] = v
    rel = MaskedRelation.from_columns(schema, cols)
    for n in names:
        rel.absent[n][:] = absent[n]
    return rel


_ZERO_STAT = GroupStat(0, 0, 0, 0, True)


def _merge_aux(old: AggAux, removed: Optional[AggAux],
               added: Optional[AggAux]) -> AggAux:
    """``old − removed + added`` per group — the bag-linearity of the
    pre-aggregate body made arithmetic.  Raises :class:`_Fallback` on any
    impossible count (negative, present > rows) or on an inexact total
    when the op needs one."""
    need_exact = old.op in ("sum", "avg")
    keys = set(old.groups)
    for side in (removed, added):
        if side is not None:
            keys |= set(side.groups)
    groups: Dict[object, GroupStat] = {}
    for k in keys:
        o = old.groups.get(k, _ZERO_STAT)
        r = removed.groups.get(k, _ZERO_STAT) if removed else _ZERO_STAT
        a = added.groups.get(k, _ZERO_STAT) if added else _ZERO_STAT
        n_rows = o.n_rows - r.n_rows + a.n_rows
        n_present = o.n_present - r.n_present + a.n_present
        if n_rows < 0 or n_present < 0 or n_present > n_rows:
            raise _Fallback("negative_group")
        exact = o.exact and r.exact and a.exact
        if need_exact and not exact:
            raise _Fallback("inexact_total")
        groups[k] = GroupStat(
            n_rows=n_rows,
            n_present=n_present,
            total=o.total - r.total + a.total if exact else 0,
            abs_total=o.abs_total - r.abs_total + a.abs_total if exact else 0,
            exact=exact,
        )
    if old.group_by is not None:
        # drop vanished groups; keep the scalar stat even at zero rows
        groups = {k: st for k, st in groups.items() if st.n_rows != 0}
    return AggAux(old.op, old.attr, old.group_by, old.attr_kind, True,
                  groups)
