"""Round-robin morsel-interleaving scheduler.

Runs many QuipExecutor pipelines as coroutines on one thread: each
scheduler step advances exactly one session by one top-level morsel
(``QuipExecutor.steps()``), then rotates.  A query stuck in a long
ρ-fixpoint only occupies its own step — queued neighbors keep streaming
between its morsels, so one slow query cannot head-of-line-block the
admission queue.  Generator stepping also serializes every
enqueue→flush→lookup sequence, which is what makes the shared ImputeStore
safe without locks (see service/impute_store.py).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional

from repro.service.session import RUNNING, QuerySession

__all__ = ["MorselScheduler"]


class MorselScheduler:
    def __init__(self):
        self._ring: Deque[QuerySession] = deque()

    @property
    def running(self) -> int:
        return len(self._ring)

    def sessions(self) -> List[QuerySession]:
        return list(self._ring)

    def add(self, session: QuerySession) -> None:
        session.start()
        if session.state == RUNNING:
            self._ring.append(session)

    def step(self) -> Optional[QuerySession]:
        """Advance the head session one morsel.  Returns the session if it
        finished (done or failed) on this step, else None."""
        if not self._ring:
            return None
        session = self._ring.popleft()
        if session.step():
            return session
        self._ring.append(session)
        return None

    def drain(self) -> List[QuerySession]:
        """Step until every running session finishes; returns them in
        completion order."""
        finished: List[QuerySession] = []
        while self._ring:
            done = self.step()
            if done is not None:
                finished.append(done)
        return finished
