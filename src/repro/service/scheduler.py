"""Per-tenant QoS morsel scheduling (round-robin / weighted-fair / deadline).

Runs many QuipExecutor pipelines as coroutines on one thread: each
scheduler step advances exactly one session by one top-level morsel
(``QuipExecutor.steps()``), then picks the next session by policy.  A
query stuck in a long ρ-fixpoint only occupies its own step — queued
neighbors keep streaming between its morsels, so one slow query cannot
head-of-line-block the admission queue.  Generator stepping also
serializes every enqueue→flush→lookup sequence, which is what makes the
shared ImputeStore safe without locks (see service/impute_store.py) —
and, crucially, what makes **answers policy-independent**: any policy
produces the same per-query answers as serial replay, it only changes
*who waits* (see docs/serving.md "Scheduling & QoS").

Policies
--------
``rr``
    The original FIFO ring: one step per session per rotation, tenants
    ignored.  A tenant flooding expensive sessions gets one ring slot per
    session, so its share grows linearly with its flood.
``wfq``
    Weighted fair queueing over *tenants* (stride/virtual-time): every
    step charges the session's tenant ``cost / weight`` of virtual time
    and the tenant with the least virtual time runs next (sessions of one
    tenant round-robin among themselves).  A tenant's morsel-time share
    converges to its weight share regardless of how many sessions it
    floods.  Tenants joining after idling are clamped to the current
    virtual-time floor, so sleeping never banks credit.
``deadline``
    Earliest-deadline-first over sessions.  A tenant's deadline *class*
    (relative, in cost units) is added to the scheduler clock at
    admission; sessions without a class sort last (FIFO among
    themselves).  Deadline classes are assigned under every policy — so
    ``deadline_met`` telemetry is comparable across policies — but only
    this policy orders by them.

Charging (``cost_model``)
-------------------------
``active`` (default)
    Per-step **active time**: the wall seconds the morsel actually
    consumed inside ``session.step()`` plus the step's *simulated*
    imputation seconds (``ImputationService.simulated_seconds`` delta —
    expensive imputers modeled without sleeps).  A 50 ms ρ-fixpoint
    morsel costs 50× a 1 ms scan morsel, not one ticket.
``simulated``
    Only the simulated-seconds delta (plus an epsilon floor so virtual
    time always advances) — deterministic across runs.
``unit``
    One ticket per step — deterministic step-share accounting, what the
    fairness tests and ``benchmarks/exp10_qos.py`` assert on (no wall
    clock anywhere).

The scheduler ``clock`` advances by the charged cost of every step, so
deadlines and per-session turnaround (``admit_clock``/``finish_clock``)
live on one policy-comparable, optionally wall-clock-free axis.
"""

from __future__ import annotations

import heapq
import itertools
import math
from collections import Counter, deque
from typing import Deque, Dict, List, Optional

from repro.obs.trace import NULL_TRACER
from repro.service.session import RUNNING, QuerySession

__all__ = ["MorselScheduler", "POLICIES", "COST_MODELS"]

POLICIES = ("rr", "wfq", "deadline")
COST_MODELS = ("active", "simulated", "unit")

# floor so zero-measured-cost steps still advance virtual time / the clock
_EPS = 1e-9


class _TenantState:
    """Per-tenant WFQ bookkeeping: weight, virtual time, session ring."""

    __slots__ = ("key", "seq", "weight", "vtime", "queue")

    def __init__(self, key, seq: int, weight: float):
        self.key = key
        self.seq = seq  # first-activation order: deterministic tie-break
        self.weight = weight
        self.vtime = 0.0
        self.queue: Deque[QuerySession] = deque()


class MorselScheduler:
    def __init__(
        self,
        policy: str = "rr",
        *,
        weights: Optional[Dict] = None,
        default_weight: float = 1.0,
        deadlines: Optional[Dict] = None,
        default_deadline: Optional[float] = None,
        cost_model: str = "active",
    ):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; "
                             f"expected one of {POLICIES}")
        if cost_model not in COST_MODELS:
            raise ValueError(f"unknown cost model {cost_model!r}; "
                             f"expected one of {COST_MODELS}")
        if default_weight <= 0:
            raise ValueError("default_weight must be > 0")
        for t, w in (weights or {}).items():
            if w <= 0:
                raise ValueError(f"tenant {t!r} weight must be > 0, got {w}")
        self.policy = policy
        self.cost_model = cost_model
        self._weights = dict(weights or {})
        self._default_weight = float(default_weight)
        self._deadlines = dict(deadlines or {})
        self._default_deadline = default_deadline

        #: total charged cost so far — seconds under ``active``/``simulated``,
        #: steps under ``unit``; deadlines and turnaround live on this axis
        self.clock = 0.0

        self._ring: Deque[QuerySession] = deque()  # rr
        self._tenants: Dict[object, _TenantState] = {}  # wfq
        self._active: set = set()  # wfq: tenants with queued sessions
        self._vfloor = 0.0  # wfq: max vtime any tenant retired at
        self._heap: List[tuple] = []  # deadline: (deadline, seq, session)
        self._seq = itertools.count()
        self._nrun = 0
        self._run_by_tenant: Counter = Counter()
        self._tenant_steps: Counter = Counter()
        self._tenant_cost: Counter = Counter()
        # sessions popped by next_session() and not yet checked back in —
        # the worker pool steps them off-lock; they stay visible to
        # sessions() (the mutation veto must see in-flight readers) and
        # keep their tenant's WFQ state active
        self._checked_out: set = set()
        self._out_by_tenant: Counter = Counter()
        self._edf_keys: Dict[int, tuple] = {}  # ticket -> (deadline, seq)
        # observability: QuipService points this at its Tracer; scheduling
        # decisions emit instants (admitted / checkout / checkin) so a
        # trace shows *why* a morsel ran when it did
        self.tracer = NULL_TRACER

    # ------------------------------------------------------------------ #
    # introspection
    # ------------------------------------------------------------------ #
    @property
    def running(self) -> int:
        return self._nrun

    def sessions(self) -> List[QuerySession]:
        """Every admitted-but-unfinished session — queued *and* checked
        out (a session being stepped on a worker thread is still reading
        its tables; the mutation veto depends on seeing it)."""
        if self.policy == "rr":
            queued: List[QuerySession] = list(self._ring)
        elif self.policy == "wfq":
            queued = [s for t in self._tenants.values() for s in t.queue]
        else:
            queued = [
                s for _d, _i, s in sorted(self._heap, key=lambda e: e[:2])
            ]
        return list(self._checked_out) + queued

    def tenant_running(self, tenant) -> int:
        """Currently admitted (RUNNING) sessions of ``tenant`` — what the
        per-tenant admission quota in QuipService gates on."""
        return self._run_by_tenant[tenant]

    def weight(self, tenant) -> float:
        return self._weights.get(tenant, self._default_weight)

    def tenant_accounting(self) -> Dict[object, Dict[str, float]]:
        """Live per-tenant share accounting: morsel steps taken, charged
        cost, and configured weight (records-based shares live on
        ``ServingStats.tenant_summary``)."""
        tenants = set(self._tenant_steps) | set(self._weights)
        return {
            t: {
                "steps": int(self._tenant_steps[t]),
                "cost": self._tenant_cost[t],
                "weight": self.weight(t),
            }
            for t in tenants
        }

    # ------------------------------------------------------------------ #
    # admission
    # ------------------------------------------------------------------ #
    def add(self, session: QuerySession) -> None:
        session.start()
        if session.state != RUNNING:
            return
        session.admit_clock = self.clock
        rel = self._deadlines.get(session.tenant, self._default_deadline)
        if rel is not None:
            session.deadline = self.clock + float(rel)
        if self.tracer.enabled:
            self.tracer.instant(
                "admitted", cat="sched", ticket=session.ticket,
                parent=session.trace_span, tenant=session.tenant,
                clock=self.clock, deadline=session.deadline)
        self._nrun += 1
        self._run_by_tenant[session.tenant] += 1
        if self.policy == "rr":
            self._ring.append(session)
        elif self.policy == "wfq":
            ts = self._tenants.get(session.tenant)
            if ts is None:
                ts = _TenantState(session.tenant, next(self._seq),
                                  self.weight(session.tenant))
                self._tenants[session.tenant] = ts
            if session.tenant not in self._active:
                # (re)activation: clamp to the floor so idling banks no
                # credit — a returning tenant competes from "now", it does
                # not get a monopolizing backlog of virtual time.  (A
                # tenant whose sessions are all checked out to workers is
                # still active — its queue is empty but it is not idle.)
                floor = min(
                    (self._tenants[k].vtime for k in self._active),
                    default=self._vfloor,
                )
                ts.vtime = max(ts.vtime, floor)
                self._active.add(session.tenant)
            ts.queue.append(session)
        else:  # deadline: EDF; no class sorts last, FIFO among peers
            key = session.deadline if session.deadline is not None else math.inf
            heapq.heappush(self._heap, (key, next(self._seq), session))

    # ------------------------------------------------------------------ #
    # one scheduling decision, split into checkout / checkin so a worker
    # pool can run session.step() off the service lock: next_session()
    # picks by policy, checkin() charges and requeues.  step() composes
    # the two back-to-back — the serial semantics, bit-identical to the
    # pre-pool per-policy step bodies.
    # ------------------------------------------------------------------ #
    def next_session(self) -> Optional[QuerySession]:
        """Pop the policy-chosen runnable session, marking it checked out
        until :meth:`checkin`.  Returns None when nothing is queued —
        which, under a pool, may mean every admitted session is currently
        checked out on some worker (``running`` stays > 0)."""
        if self.policy == "rr":
            session = self._ring.popleft() if self._ring else None
        elif self.policy == "wfq":
            ready = [
                self._tenants[k] for k in self._active
                if self._tenants[k].queue
            ]
            if not ready:
                session = None
            else:
                ts = min(ready, key=lambda t: (t.vtime, t.seq))
                session = ts.queue.popleft()
        else:  # deadline
            if not self._heap:
                session = None
            else:
                key, seq, session = heapq.heappop(self._heap)
                # remember the EDF key: requeueing with the original
                # (deadline, seq) keeps FIFO among equal deadlines stable
                self._edf_keys[session.ticket] = (key, seq)
        if session is not None:
            self._checked_out.add(session)
            self._out_by_tenant[session.tenant] += 1
            if self.tracer.enabled:
                self.tracer.instant(
                    "sched_checkout", cat="sched", ticket=session.ticket,
                    parent=session.trace_span, tenant=session.tenant,
                    policy=self.policy)
        return session

    def checkin(self, session: QuerySession, finished: bool) -> float:
        """Charge a stepped session's tenant and requeue it (or retire it
        when finished).  Returns the charged cost."""
        self._checked_out.discard(session)
        self._out_by_tenant[session.tenant] -= 1
        cost = self._charge(session, finished)
        if self.tracer.enabled:
            self.tracer.instant(
                "sched_checkin", cat="sched", ticket=session.ticket,
                parent=session.trace_span, tenant=session.tenant,
                cost=round(cost, 9), finished=finished)
        if self.policy == "rr":
            if not finished:
                self._ring.append(session)
        elif self.policy == "wfq":
            ts = self._tenants[session.tenant]
            ts.vtime += cost / ts.weight
            if finished:
                if not ts.queue and not self._out_by_tenant[ts.key]:
                    self._active.discard(ts.key)
                    self._vfloor = max(self._vfloor, ts.vtime)
            else:
                ts.queue.append(session)
        else:  # deadline
            key, seq = self._edf_keys.pop(session.ticket)
            if not finished:
                heapq.heappush(self._heap, (key, seq, session))
        return cost

    def step(self) -> Optional[QuerySession]:
        """Advance the policy-chosen session one morsel and charge its
        tenant.  Returns the session if it finished (done or failed) on
        this step, else None."""
        session = self.next_session()
        if session is None:
            return None
        finished = session.step()
        self.checkin(session, finished)
        return session if finished else None

    def _charge(self, session: QuerySession, finished: bool) -> float:
        if self.cost_model == "unit":
            cost = 1.0
        elif self.cost_model == "simulated":
            cost = session.last_step_sim_s + _EPS
        else:  # active: wall + simulated, floored so the clock advances
            cost = max(session.last_step_wall_s + session.last_step_sim_s,
                       _EPS)
        self.clock += cost
        session.sched_cost += cost
        tenant = session.tenant
        self._tenant_steps[tenant] += 1
        self._tenant_cost[tenant] += cost
        if finished:
            self._nrun -= 1
            self._run_by_tenant[tenant] -= 1
            session.finish_clock = self.clock
            if session.deadline is not None:
                session.deadline_met = self.clock <= session.deadline
        return cost

    def drain(self) -> List[QuerySession]:
        """Step until every running session finishes; returns them in
        completion order (under ``deadline`` that order respects deadline
        classes).  Only *admitted* sessions drain — QuipService cancels
        its never-admitted waiting queue on ``close()`` so queued work
        lands a failed QueryRecord instead of vanishing."""
        finished: List[QuerySession] = []
        while self._nrun:
            if not self._has_queued():
                # every remaining session is checked out to a worker —
                # serial draining cannot touch them; the pool drains them
                break
            done = self.step()
            if done is not None:
                finished.append(done)
        return finished

    def _has_queued(self) -> bool:
        if self.policy == "rr":
            return bool(self._ring)
        if self.policy == "wfq":
            return any(self._tenants[k].queue for k in self._active)
        return bool(self._heap)
