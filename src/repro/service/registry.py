"""Epoch-versioned table registry: the mutable source of truth for serving.

QUIP's premise is that imputation happens *at query time* against the data
as it stands (paper §1, §6) — so the serving layer cannot assume the
registry is frozen forever.  :class:`TableRegistry` wraps the tables dict
behind a mutation API and a **global + per-table epoch counter**; every
cache above it (plan cache, result cache, shared impute store) either keys
on the epochs or is invalidated through the registry's subscriber hooks
the moment a table changes.

Semantics:

* The registry is a read-only :class:`~collections.abc.Mapping` — every
  call site that used to take ``Dict[str, MaskedRelation]`` (planner,
  executors, imputation services) works unchanged.
* Mutations are **copy-on-write**: they build a fresh
  :class:`MaskedRelation` and swap it in, so table snapshots already taken
  by in-flight sessions are untouched (each query stays point-in-time
  consistent with the registry as of its admission).
* ``delete_rows`` / ``insert_rows`` rebuild the base table canonically
  (``tids`` re-indexed to ``arange(n)``), so the dense per-(table, attr)
  imputation caches — recreated after invalidation — size to the new row
  count and base-row ids line up again.
* Arguments are validated **pre-commit**: unknown attributes, value-length
  mismatches, out-of-range or non-integer row ids, and value arrays whose
  dtype cannot be safely cast to the column dtype all raise *before* any
  epoch bump or table swap, so a failed mutation leaves the registry (and
  every cache keyed on its epochs) untouched.
* Every mutation bumps the table's epoch and the global epoch, then
  notifies subscribers.  Subscribers may also register a ``before`` hook
  that can veto the mutation (raise) while nothing has been committed —
  QuipService uses this to refuse mutating a table that shared-impute
  sessions are currently reading.
* Subscribers registered with ``delta=True`` additionally receive the
  commit as a :class:`~repro.core.delta.TableDelta` (``None`` when the
  commit is not expressible as a delta — ``replace_table``, duplicate row
  ids in one ``update_rows``); the serving layer's IVM maintainer uses
  this to patch cached answers instead of evicting them (docs/ivm.md).
"""

from __future__ import annotations

from collections.abc import Mapping
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.delta import (
    TableDelta,
    delta_for_delete,
    delta_for_insert,
    delta_for_update,
)
from repro.core.relation import MaskedRelation

__all__ = ["TableRegistry"]

# (before, after, wants_delta): ``before`` may veto by raising; ``after``
# is called post-commit as ``after(table)`` or — when wants_delta —
# ``after(table, delta)``.
_Subscriber = Tuple[Optional[Callable[[str], None]], Callable, bool]


class TableRegistry(Mapping):
    """Mapping of table name → :class:`MaskedRelation` with epoch-counted,
    copy-on-write mutations and invalidation callbacks."""

    def __init__(self, tables: Dict[str, MaskedRelation]):
        self._tables: Dict[str, MaskedRelation] = dict(tables)
        self._epochs: Dict[str, int] = {t: 0 for t in self._tables}
        self._global_epoch = 0
        self._subscribers: List[_Subscriber] = []

    # ------------------------------------------------------------------ #
    # Mapping interface (drop-in for the plain tables dict)
    # ------------------------------------------------------------------ #
    def __getitem__(self, table: str) -> MaskedRelation:
        return self._tables[table]

    def __iter__(self) -> Iterator[str]:
        return iter(self._tables)

    def __len__(self) -> int:
        return len(self._tables)

    # ------------------------------------------------------------------ #
    # epochs
    # ------------------------------------------------------------------ #
    @property
    def global_epoch(self) -> int:
        """Total mutations committed against any table."""
        return self._global_epoch

    def epoch(self, table: str) -> int:
        return self._epochs[table]

    def epochs(self, tables: Iterable[str]) -> Tuple[int, ...]:
        """Per-table epochs in ``tables`` order — the version vector the
        result cache keys on."""
        return tuple(self._epochs[t] for t in tables)

    # ------------------------------------------------------------------ #
    # invalidation hooks
    # ------------------------------------------------------------------ #
    def subscribe(self, on_mutation: Callable, *,
                  before: Optional[Callable[[str], None]] = None,
                  delta: bool = False) -> None:
        """Register invalidation hooks.  ``before(table)`` runs pre-commit
        and may raise to veto (nothing mutated yet); ``on_mutation`` runs
        post-commit, observing the new table and epochs — called as
        ``on_mutation(table)`` or, with ``delta=True``, as
        ``on_mutation(table, delta)`` where ``delta`` is the commit's
        :class:`TableDelta` (or ``None`` for non-delta commits)."""
        self._subscribers.append((before, on_mutation, bool(delta)))

    def unsubscribe(self, on_mutation: Callable) -> None:
        """Remove the hooks registered with ``on_mutation``.  A subscriber
        discarded while the registry lives on (service churn over one
        long-lived registry) must unsubscribe, or the registry keeps it —
        and its caches — alive and pays its invalidation work on every
        mutation."""
        # equality, not identity: bound methods are re-created per attribute
        # access, so ``registry.unsubscribe(svc._on_mutation)`` must match
        # the equal-but-distinct object stored by subscribe
        self._subscribers = [
            (b, a, w) for b, a, w in self._subscribers if a != on_mutation
        ]

    # ------------------------------------------------------------------ #
    # mutation API (all copy-on-write; all bump epochs + notify)
    # ------------------------------------------------------------------ #
    def _commit(
        self, table: str,
        build: Callable[[MaskedRelation], MaskedRelation],
        make_delta: Optional[
            Callable[[MaskedRelation, MaskedRelation], Optional[TableDelta]]
        ] = None,
    ) -> None:
        if table not in self._tables:
            raise KeyError(f"unknown table {table!r}")
        for before, _after, _w in self._subscribers:
            if before is not None:
                before(table)
        old = self._tables[table]
        new = build(old)
        # materialize the delta slices only if someone will consume them
        delta: Optional[TableDelta] = None
        if make_delta is not None and any(w for _b, _a, w in self._subscribers):
            delta = make_delta(old, new)
        self._tables[table] = new
        self._epochs[table] += 1
        self._global_epoch += 1
        # The mutation is committed and the epoch has advanced: every
        # subscriber MUST observe it, even if an earlier after-hook raises —
        # otherwise later subscribers keep serving stale plans/answers whose
        # epoch keys claim freshness.  Run them all, then re-raise.
        errors = []
        for _before, after, wants_delta in self._subscribers:
            try:
                if wants_delta:
                    after(table, delta)
                else:
                    after(table)
            except Exception as e:
                errors.append(e)
        if errors:
            if len(errors) == 1:
                raise errors[0]
            agg = RuntimeError(
                f"{len(errors)} post-commit subscribers failed for "
                f"table {table!r}: "
                f"{[f'{type(e).__name__}: {e}' for e in errors]}"
            )
            raise agg from errors[0]

    @staticmethod
    def _check_rows(rel: MaskedRelation, rows: np.ndarray) -> np.ndarray:
        rows_in = np.asarray(rows)
        if rows_in.size and not np.issubdtype(rows_in.dtype, np.integer):
            # float row ids would silently truncate under an astype —
            # refuse them before anything is committed
            raise TypeError(
                f"row ids must be integers, got dtype {rows_in.dtype}"
            )
        rows = rows_in.astype(np.int64, copy=False).reshape(-1)
        if len(rows) and (rows.min() < 0 or rows.max() >= rel.num_rows):
            raise IndexError(
                f"row ids out of range [0, {rel.num_rows}): "
                f"{rows[(rows < 0) | (rows >= rel.num_rows)][:8].tolist()}"
            )
        return rows

    def update_rows(self, table: str, rows: np.ndarray,
                    values: Dict[str, np.ndarray]) -> None:
        """Overwrite ``values[attr][i]`` into row ``rows[i]`` of ``table``
        for each attr; updated cells become known (missing bit cleared).

        Validates everything pre-commit: row ids (integer dtype, in
        bounds), attribute names, value lengths, and value dtypes
        (``same_kind``-castable to the column dtype — a float array
        aimed at an int column raises instead of silently truncating)."""
        if table not in self._tables:
            raise KeyError(f"unknown table {table!r}")
        rel = self._tables[table]
        idx = self._check_rows(rel, rows)
        checked: Dict[str, np.ndarray] = {}
        for attr, vals in values.items():
            if not rel.schema.has(attr):
                raise KeyError(
                    f"update_rows: no column {attr!r} in table {table!r}"
                )
            arr = np.asarray(vals)
            if len(arr) != len(idx):
                raise ValueError(
                    f"{table}.{attr}: {len(arr)} values for {len(idx)} rows"
                )
            target = rel.schema.column(attr).np_dtype
            if not np.can_cast(arr.dtype, target, casting="same_kind"):
                raise TypeError(
                    f"update_rows: {table}.{attr} values have dtype "
                    f"{arr.dtype}, not castable to column dtype "
                    f"{np.dtype(target)} (same_kind)"
                )
            checked[attr] = arr

        def build(old: MaskedRelation) -> MaskedRelation:
            new = old.copy()
            for attr, arr in checked.items():
                new.set_values(attr, idx, arr)
            return new

        self._commit(
            table, build,
            make_delta=lambda old, new: delta_for_update(table, old, new, idx),
        )

    def delete_rows(self, table: str, rows: np.ndarray) -> None:
        """Drop rows by id; the table is rebuilt canonically (``tids``
        re-indexed to ``arange`` of the new row count).  Row ids are
        validated (integer dtype, in bounds) before anything commits."""
        if table not in self._tables:
            raise KeyError(f"unknown table {table!r}")
        idx = self._check_rows(self._tables[table], rows)

        def build(rel: MaskedRelation) -> MaskedRelation:
            keep = np.ones(rel.num_rows, dtype=bool)
            keep[idx] = False
            return MaskedRelation.from_columns(
                rel.schema,
                {a: rel.cols[a][keep] for a in rel.cols},
                missing={a: rel.missing[a][keep] for a in rel.missing},
                base_table=table,
            )

        self._commit(
            table, build,
            make_delta=lambda old, _new: delta_for_delete(table, old, idx),
        )

    def insert_rows(self, table: str, values: Dict[str, np.ndarray],
                    missing: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Append rows (``values[attr]`` one array per column; ``missing``
        optionally marks imputable cells among them)."""

        def build(rel: MaskedRelation) -> MaskedRelation:
            lengths = {len(np.asarray(v)) for v in values.values()}
            if len(lengths) != 1:
                raise ValueError(f"ragged insert into {table!r}: {lengths}")
            (n_new,) = lengths
            for a, mask in (missing or {}).items():
                if len(np.asarray(mask)) != n_new:
                    raise ValueError(
                        f"insert into {table!r}: missing mask for {a!r} has "
                        f"{len(np.asarray(mask))} rows, values have {n_new}"
                    )
            cols, miss = {}, {}
            for spec in rel.schema.columns:
                if spec.name not in values:
                    raise ValueError(
                        f"insert into {table!r} missing column {spec.name!r}"
                    )
                cols[spec.name] = np.concatenate([
                    rel.cols[spec.name],
                    np.asarray(values[spec.name], dtype=spec.np_dtype),
                ])
                new_miss = (
                    np.asarray(missing[spec.name], dtype=bool)
                    if missing and spec.name in missing
                    else np.zeros(n_new, dtype=bool)
                )
                miss[spec.name] = np.concatenate(
                    [rel.missing[spec.name], new_miss]
                )
            return MaskedRelation.from_columns(
                rel.schema, cols, missing=miss, base_table=table
            )

        self._commit(
            table, build,
            make_delta=lambda old, new: delta_for_insert(
                table, new, old.num_rows
            ),
        )

    def replace_table(self, table: str, relation: MaskedRelation) -> None:
        """Swap in a whole new relation under an existing name.  Not
        expressible as a row delta — subscribers see ``delta=None`` and
        fall back to full invalidation."""
        self._commit(table, lambda _old: relation)
