"""Epoch-versioned table registry: the mutable source of truth for serving.

QUIP's premise is that imputation happens *at query time* against the data
as it stands (paper §1, §6) — so the serving layer cannot assume the
registry is frozen forever.  :class:`TableRegistry` wraps the tables dict
behind a mutation API and a **global + per-table epoch counter**; every
cache above it (plan cache, result cache, shared impute store) either keys
on the epochs or is invalidated through the registry's subscriber hooks
the moment a table changes.

Semantics:

* The registry is a read-only :class:`~collections.abc.Mapping` — every
  call site that used to take ``Dict[str, MaskedRelation]`` (planner,
  executors, imputation services) works unchanged.
* Mutations are **copy-on-write**: they build a fresh
  :class:`MaskedRelation` and swap it in, so table snapshots already taken
  by in-flight sessions are untouched (each query stays point-in-time
  consistent with the registry as of its admission).
* ``delete_rows`` / ``insert_rows`` rebuild the base table canonically
  (``tids`` re-indexed to ``arange(n)``), so the dense per-(table, attr)
  imputation caches — recreated after invalidation — size to the new row
  count and base-row ids line up again.
* Every mutation bumps the table's epoch and the global epoch, then
  notifies subscribers.  Subscribers may also register a ``before`` hook
  that can veto the mutation (raise) while nothing has been committed —
  QuipService uses this to refuse mutating a table that shared-impute
  sessions are currently reading.
"""

from __future__ import annotations

from collections.abc import Mapping
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.relation import MaskedRelation

__all__ = ["TableRegistry"]


class TableRegistry(Mapping):
    """Mapping of table name → :class:`MaskedRelation` with epoch-counted,
    copy-on-write mutations and invalidation callbacks."""

    def __init__(self, tables: Dict[str, MaskedRelation]):
        self._tables: Dict[str, MaskedRelation] = dict(tables)
        self._epochs: Dict[str, int] = {t: 0 for t in self._tables}
        self._global_epoch = 0
        # (before, after) hooks; ``before`` may veto by raising
        self._subscribers: List[Tuple[Optional[Callable[[str], None]],
                                      Callable[[str], None]]] = []

    # ------------------------------------------------------------------ #
    # Mapping interface (drop-in for the plain tables dict)
    # ------------------------------------------------------------------ #
    def __getitem__(self, table: str) -> MaskedRelation:
        return self._tables[table]

    def __iter__(self) -> Iterator[str]:
        return iter(self._tables)

    def __len__(self) -> int:
        return len(self._tables)

    # ------------------------------------------------------------------ #
    # epochs
    # ------------------------------------------------------------------ #
    @property
    def global_epoch(self) -> int:
        """Total mutations committed against any table."""
        return self._global_epoch

    def epoch(self, table: str) -> int:
        return self._epochs[table]

    def epochs(self, tables: Iterable[str]) -> Tuple[int, ...]:
        """Per-table epochs in ``tables`` order — the version vector the
        result cache keys on."""
        return tuple(self._epochs[t] for t in tables)

    # ------------------------------------------------------------------ #
    # invalidation hooks
    # ------------------------------------------------------------------ #
    def subscribe(self, on_mutation: Callable[[str], None], *,
                  before: Optional[Callable[[str], None]] = None) -> None:
        """Register invalidation hooks.  ``before(table)`` runs pre-commit
        and may raise to veto (nothing mutated yet); ``on_mutation(table)``
        runs post-commit, observing the new table and epochs."""
        self._subscribers.append((before, on_mutation))

    def unsubscribe(self, on_mutation: Callable[[str], None]) -> None:
        """Remove the hooks registered with ``on_mutation``.  A subscriber
        discarded while the registry lives on (service churn over one
        long-lived registry) must unsubscribe, or the registry keeps it —
        and its caches — alive and pays its invalidation work on every
        mutation."""
        # equality, not identity: bound methods are re-created per attribute
        # access, so ``registry.unsubscribe(svc._on_mutation)`` must match
        # the equal-but-distinct object stored by subscribe
        self._subscribers = [
            (b, a) for b, a in self._subscribers if a != on_mutation
        ]

    # ------------------------------------------------------------------ #
    # mutation API (all copy-on-write; all bump epochs + notify)
    # ------------------------------------------------------------------ #
    def _commit(self, table: str,
                build: Callable[[MaskedRelation], MaskedRelation]) -> None:
        if table not in self._tables:
            raise KeyError(f"unknown table {table!r}")
        for before, _after in self._subscribers:
            if before is not None:
                before(table)
        self._tables[table] = build(self._tables[table])
        self._epochs[table] += 1
        self._global_epoch += 1
        # The mutation is committed and the epoch has advanced: every
        # subscriber MUST observe it, even if an earlier after-hook raises —
        # otherwise later subscribers keep serving stale plans/answers whose
        # epoch keys claim freshness.  Run them all, then re-raise.
        errors = []
        for _before, after in self._subscribers:
            try:
                after(table)
            except Exception as e:
                errors.append(e)
        if errors:
            if len(errors) == 1:
                raise errors[0]
            agg = RuntimeError(
                f"{len(errors)} post-commit subscribers failed for "
                f"table {table!r}: "
                f"{[f'{type(e).__name__}: {e}' for e in errors]}"
            )
            raise agg from errors[0]

    @staticmethod
    def _check_rows(rel: MaskedRelation, rows: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) and (rows.min() < 0 or rows.max() >= rel.num_rows):
            raise IndexError(
                f"row ids out of range [0, {rel.num_rows}): "
                f"{rows[(rows < 0) | (rows >= rel.num_rows)][:8].tolist()}"
            )
        return rows

    def update_rows(self, table: str, rows: np.ndarray,
                    values: Dict[str, np.ndarray]) -> None:
        """Overwrite ``values[attr][i]`` into row ``rows[i]`` of ``table``
        for each attr; updated cells become known (missing bit cleared)."""

        def build(rel: MaskedRelation) -> MaskedRelation:
            idx = self._check_rows(rel, rows)
            new = rel.copy()
            for attr, vals in values.items():
                vals = np.asarray(vals)
                if len(vals) != len(idx):
                    raise ValueError(
                        f"{table}.{attr}: {len(vals)} values for "
                        f"{len(idx)} rows"
                    )
                new.set_values(attr, idx, vals)
            return new

        self._commit(table, build)

    def delete_rows(self, table: str, rows: np.ndarray) -> None:
        """Drop rows by id; the table is rebuilt canonically (``tids``
        re-indexed to ``arange`` of the new row count)."""

        def build(rel: MaskedRelation) -> MaskedRelation:
            idx = self._check_rows(rel, rows)
            keep = np.ones(rel.num_rows, dtype=bool)
            keep[idx] = False
            return MaskedRelation.from_columns(
                rel.schema,
                {a: rel.cols[a][keep] for a in rel.cols},
                missing={a: rel.missing[a][keep] for a in rel.missing},
                base_table=table,
            )

        self._commit(table, build)

    def insert_rows(self, table: str, values: Dict[str, np.ndarray],
                    missing: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Append rows (``values[attr]`` one array per column; ``missing``
        optionally marks imputable cells among them)."""

        def build(rel: MaskedRelation) -> MaskedRelation:
            lengths = {len(np.asarray(v)) for v in values.values()}
            if len(lengths) != 1:
                raise ValueError(f"ragged insert into {table!r}: {lengths}")
            (n_new,) = lengths
            for a, mask in (missing or {}).items():
                if len(np.asarray(mask)) != n_new:
                    raise ValueError(
                        f"insert into {table!r}: missing mask for {a!r} has "
                        f"{len(np.asarray(mask))} rows, values have {n_new}"
                    )
            cols, miss = {}, {}
            for spec in rel.schema.columns:
                if spec.name not in values:
                    raise ValueError(
                        f"insert into {table!r} missing column {spec.name!r}"
                    )
                cols[spec.name] = np.concatenate([
                    rel.cols[spec.name],
                    np.asarray(values[spec.name], dtype=spec.np_dtype),
                ])
                new_miss = (
                    np.asarray(missing[spec.name], dtype=bool)
                    if missing and spec.name in missing
                    else np.zeros(n_new, dtype=bool)
                )
                miss[spec.name] = np.concatenate(
                    [rel.missing[spec.name], new_miss]
                )
            return MaskedRelation.from_columns(
                rel.schema, cols, missing=miss, base_table=table
            )

        self._commit(table, build)

    def replace_table(self, table: str, relation: MaskedRelation) -> None:
        """Swap in a whole new relation under an existing name."""
        self._commit(table, lambda _old: relation)
