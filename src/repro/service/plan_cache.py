"""LRU plan cache keyed on a canonical query signature.

Planning a query is not free — ``make_plan`` scans every base table for
selectivity statistics before the ImputeDB-style join ordering runs.  A
serving workload repeats query shapes (the skew the paper's multi-tenant
scenario assumes), so QuipService interns the *pre-rewrite* SPJ plan per
signature and hands each execution a structural clone: executors mutate
plan nodes (ρ wrapping reassigns parents, VF-list construction rewrites
verify/filter sets), so the cached tree itself must stay pristine.

The signature canonicalizes everything the planner looks at — tables,
selections (``in``-sets sorted), joins, projection, aggregate, planner
name.  It deliberately does *not* hash table contents: a plan is valid
exactly until one of the tables it was costed on mutates, at which point
the registry's invalidation hook calls :meth:`PlanCache.invalidate_table`
— the cached join order was driven by selectivity scans of the old data,
so every dependent entry is evicted and the next submission re-plans
against the mutated registry (see docs/serving.md).

Each entry also carries **per-signature hit counts** (the hotness signal
``QuipService(compile_after_hits=K)`` promotes on) and any **compiled
artifacts** lowered for the signature, keyed by (strategy, table epochs).
Artifacts live and die with their plan entry — eviction and
``invalidate_table`` drop them together — and the epoch stamp is a second
defensive gate: an artifact lowered at different epochs is never served
(see docs/compiled.md "Epoch invalidation").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.compiled import CompiledPlan
from repro.core.executor import make_plan
from repro.core.plan import PlanNode, Query, clone_plan
from repro.core.relation import MaskedRelation
from repro.service.lru import LruCache

__all__ = ["PlanCache", "query_signature"]


def _canonical_value(value) -> object:
    if isinstance(value, frozenset):
        return tuple(sorted(value))
    return value


def query_signature(query: Query, planner: str = "imputedb") -> Tuple:
    """Hashable canonical form of everything the planner consumes."""
    sels = tuple(
        (p.attr, p.op, _canonical_value(p.value)) for p in query.selections
    )
    joins = tuple((j.left_attr, j.right_attr) for j in query.joins)
    agg = (
        (query.aggregate.op, query.aggregate.attr, query.aggregate.group_by)
        if query.aggregate is not None
        else None
    )
    return (planner, tuple(query.tables), sels, joins,
            tuple(query.projection), agg)


@dataclasses.dataclass
class _PlanEntry:
    """One cached signature: the pristine plan, how often it hit, and any
    compiled artifacts lowered for it.

    ``compiled`` maps strategy → (epochs, artifact); the artifact is either
    a :class:`CompiledPlan` or the :class:`CompileFallback` that lowering
    raised — caching the fallback too stops the service from re-attempting
    a lowering that can never succeed for the signature."""

    plan: PlanNode
    hits: int = 0
    compiled: Dict[str, Tuple[Tuple, object]] = dataclasses.field(
        default_factory=dict
    )


class PlanCache(LruCache):
    """LRU over ``query_signature`` → :class:`_PlanEntry`, with hit/miss
    counters.  ``get`` always returns a fresh :func:`clone_plan` copy.
    ``invalidate_table`` evicts every plan whose query reads the mutated
    table — its join order was chosen from now-stale selectivity scans —
    and every compiled artifact riding on it."""

    def __init__(self, capacity: int = 64, planner: str = "imputedb"):
        super().__init__(capacity)
        self.planner = planner

    def get(self, query: Query, tables: Dict[str, MaskedRelation],
            planner: Optional[str] = None,
            extra_dep_tables: Tuple[str, ...] = ()) -> Tuple[PlanNode, bool]:
        """Returns ``(plan, hit)``; plans the query on a miss.

        ``extra_dep_tables`` widens the reverse-index dependency set beyond
        the signature's own tables — a compound outer query rewritten from
        a sub-query result depends on the sub-query's tables too, even
        though its signature never names them (the entry-leak fix).

        All hit bookkeeping (the LRU's counters via ``lookup`` plus the
        entry's per-signature count) lands *before* ``clone_plan`` runs, so
        a clone failure surfaces to the caller without desyncing the
        counters from the served state."""
        planner = planner or self.planner
        sig = query_signature(query, planner)
        entry = self.lookup(sig)
        if entry is not None:
            entry.hits += 1
            return clone_plan(entry.plan), True
        plan = make_plan(query, tables, planner=planner)
        self.insert(sig, _PlanEntry(plan),
                    tables=tuple(sig[1]) + tuple(extra_dep_tables))
        return clone_plan(plan), False

    # -- per-signature hotness + compiled artifacts --------------------- #
    def hit_count(self, query: Query, planner: Optional[str] = None) -> int:
        """Hits served for the signature so far (0 when uncached).  A pure
        peek: no LRU touch, no hit/miss accounting."""
        sig = query_signature(query, planner or self.planner)
        entry = self._entries.get(sig)
        return entry.hits if entry is not None else 0

    def compiled_artifact(self, query: Query, strategy: str, epochs: Tuple,
                          planner: Optional[str] = None) -> Optional[object]:
        """Cached artifact for (signature, strategy) iff it was lowered at
        exactly ``epochs``; a stale-epoch artifact is dropped, not served.
        Registry invalidation hooks already evict the whole entry on
        mutation — the epoch stamp is the defensive second gate."""
        sig = query_signature(query, planner or self.planner)
        entry = self._entries.get(sig)
        if entry is None:
            return None
        cached = entry.compiled.get(strategy)
        if cached is None:
            return None
        stamped_epochs, artifact = cached
        if stamped_epochs != epochs:
            del entry.compiled[strategy]
            return None
        return artifact

    def store_compiled(self, query: Query, strategy: str, epochs: Tuple,
                       artifact: object,
                       planner: Optional[str] = None) -> None:
        """Attach a lowered artifact (or its :class:`CompileFallback`) to
        the signature's entry; a no-op when the signature is uncached
        (capacity 0 / already evicted) — the artifact simply isn't kept."""
        sig = query_signature(query, planner or self.planner)
        entry = self._entries.get(sig)
        if entry is not None:
            entry.compiled[strategy] = (epochs, artifact)

    def compiled_count(self) -> int:
        """Live :class:`CompiledPlan` artifacts (cached fallbacks excluded)."""
        return sum(
            1
            for e in self._entries.values()
            for _epochs, a in e.compiled.values()
            if isinstance(a, CompiledPlan)
        )

    def summary(self) -> Dict[str, object]:
        """``stats()`` plus the per-signature view: hit counts and live
        compiled-artifact totals, keyed by the canonical signature."""
        out: Dict[str, object] = dict(self.stats())
        out["compiled"] = self.compiled_count()
        out["signature_hits"] = {
            sig: e.hits for sig, e in self._entries.items()
        }
        return out

    def _key_tables(self, key: Tuple) -> Tuple[str, ...]:
        return key[1]  # query_signature: (planner, tables, ...)
