"""LRU plan cache keyed on a canonical query signature.

Planning a query is not free — ``make_plan`` scans every base table for
selectivity statistics before the ImputeDB-style join ordering runs.  A
serving workload repeats query shapes (the skew the paper's multi-tenant
scenario assumes), so QuipService interns the *pre-rewrite* SPJ plan per
signature and hands each execution a structural clone: executors mutate
plan nodes (ρ wrapping reassigns parents, VF-list construction rewrites
verify/filter sets), so the cached tree itself must stay pristine.

The signature canonicalizes everything the planner looks at — tables,
selections (``in``-sets sorted), joins, projection, aggregate, planner
name.  It deliberately does *not* hash table contents: the registry is
immutable while a service is up, and invalidation-on-mutation is an open
item (see ROADMAP).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Tuple

from repro.core.executor import make_plan
from repro.core.plan import PlanNode, Query, clone_plan
from repro.core.relation import MaskedRelation

__all__ = ["PlanCache", "query_signature"]


def _canonical_value(value) -> object:
    if isinstance(value, frozenset):
        return tuple(sorted(value))
    return value


def query_signature(query: Query, planner: str = "imputedb") -> Tuple:
    """Hashable canonical form of everything the planner consumes."""
    sels = tuple(
        (p.attr, p.op, _canonical_value(p.value)) for p in query.selections
    )
    joins = tuple((j.left_attr, j.right_attr) for j in query.joins)
    agg = (
        (query.aggregate.op, query.aggregate.attr, query.aggregate.group_by)
        if query.aggregate is not None
        else None
    )
    return (planner, tuple(query.tables), sels, joins,
            tuple(query.projection), agg)


class PlanCache:
    """LRU over ``query_signature`` → pristine SPJ plan, with hit/miss
    counters.  ``get`` always returns a fresh :func:`clone_plan` copy."""

    def __init__(self, capacity: int = 64, planner: str = "imputedb"):
        assert capacity >= 1
        self.capacity = int(capacity)
        self.planner = planner
        self._plans: "OrderedDict[Tuple, PlanNode]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._plans)

    def get(self, query: Query, tables: Dict[str, MaskedRelation],
            planner: Optional[str] = None) -> Tuple[PlanNode, bool]:
        """Returns ``(plan, hit)``; plans the query on a miss."""
        planner = planner or self.planner
        sig = query_signature(query, planner)
        cached = self._plans.get(sig)
        if cached is not None:
            self._plans.move_to_end(sig)
            self.hits += 1
            return clone_plan(cached), True
        plan = make_plan(query, tables, planner=planner)
        self._plans[sig] = plan
        while len(self._plans) > self.capacity:
            self._plans.popitem(last=False)
            self.evictions += 1
        self.misses += 1
        return clone_plan(plan), False

    def stats(self) -> Dict[str, int]:
        return {
            "size": len(self._plans),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
