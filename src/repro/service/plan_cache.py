"""LRU plan cache keyed on a canonical query signature.

Planning a query is not free — ``make_plan`` scans every base table for
selectivity statistics before the ImputeDB-style join ordering runs.  A
serving workload repeats query shapes (the skew the paper's multi-tenant
scenario assumes), so QuipService interns the *pre-rewrite* SPJ plan per
signature and hands each execution a structural clone: executors mutate
plan nodes (ρ wrapping reassigns parents, VF-list construction rewrites
verify/filter sets), so the cached tree itself must stay pristine.

The signature canonicalizes everything the planner looks at — tables,
selections (``in``-sets sorted), joins, projection, aggregate, planner
name.  It deliberately does *not* hash table contents: a plan is valid
exactly until one of the tables it was costed on mutates, at which point
the registry's invalidation hook calls :meth:`PlanCache.invalidate_table`
— the cached join order was driven by selectivity scans of the old data,
so every dependent entry is evicted and the next submission re-plans
against the mutated registry (see docs/serving.md).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.executor import make_plan
from repro.core.plan import PlanNode, Query, clone_plan
from repro.core.relation import MaskedRelation
from repro.service.lru import LruCache

__all__ = ["PlanCache", "query_signature"]


def _canonical_value(value) -> object:
    if isinstance(value, frozenset):
        return tuple(sorted(value))
    return value


def query_signature(query: Query, planner: str = "imputedb") -> Tuple:
    """Hashable canonical form of everything the planner consumes."""
    sels = tuple(
        (p.attr, p.op, _canonical_value(p.value)) for p in query.selections
    )
    joins = tuple((j.left_attr, j.right_attr) for j in query.joins)
    agg = (
        (query.aggregate.op, query.aggregate.attr, query.aggregate.group_by)
        if query.aggregate is not None
        else None
    )
    return (planner, tuple(query.tables), sels, joins,
            tuple(query.projection), agg)


class PlanCache(LruCache):
    """LRU over ``query_signature`` → pristine SPJ plan, with hit/miss
    counters.  ``get`` always returns a fresh :func:`clone_plan` copy.
    ``invalidate_table`` evicts every plan whose query reads the mutated
    table — its join order was chosen from now-stale selectivity scans."""

    def __init__(self, capacity: int = 64, planner: str = "imputedb"):
        super().__init__(capacity)
        self.planner = planner

    def get(self, query: Query, tables: Dict[str, MaskedRelation],
            planner: Optional[str] = None) -> Tuple[PlanNode, bool]:
        """Returns ``(plan, hit)``; plans the query on a miss."""
        planner = planner or self.planner
        sig = query_signature(query, planner)
        cached = self.lookup(sig)
        if cached is not None:
            return clone_plan(cached), True
        plan = make_plan(query, tables, planner=planner)
        self.insert(sig, plan)
        return clone_plan(plan), False

    def _key_tables(self, key: Tuple) -> Tuple[str, ...]:
        return key[1]  # query_signature: (planner, tables, ...)
