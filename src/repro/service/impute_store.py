"""Cross-query imputation sharing: one ImputeStore for many query sessions.

The PR-2 ImputationService already guarantees that within ONE query the
same missing cell is computed once no matter how many pipeline copies touch
it.  :class:`SharedImputeStore` lifts that guarantee across queries: every
per-query service binds to the same dense value/filled caches and the same
fitted models, so a value query A paid for is a cache hit for query B and
a blocking imputer (GBDT, KNN reference matrix) trains once per table
instead of once per query.

Consistency argument (docs/serving.md expands on this):

* base tables only change through the epoch-versioned ``TableRegistry``,
  whose mutation hooks drop this store's per-table caches and fitted
  models (``ImputeStore.invalidate``) before any post-mutation query runs;
* imputers are deterministic functions of (base table, attr, tid) once
  fitted, and fitting is itself a deterministic function of the base table;
* therefore every query — shared store or not — would compute the *same*
  value for a given cell, and sharing changes only *who computes it first*.
  Answers are bit-identical to per-query isolation; only the invocation
  counters shrink.  The equivalence tests in tests/test_service.py assert
  exactly this.

Flush discipline: the store is thread-safe.  Executors resolve missing
cells through the atomic ``ImputationService.request`` — dedup, model
fit, compute, fill, and gather all run under that key's flush lock
(``ImputeStore.flush_lock``), so concurrent worker-pool sessions (and
sibling parallel morsels of one query) serialize per (table, attr) and
never observe a half-filled batch.  Whole-queue ``flush`` additionally
serializes store-wide via ``begin_flush``/``end_flush``; a *same-thread*
reentrant flush (an imputer requesting the very attribute it is
computing) still fails loud instead of deadlocking.  Metadata (cache
creation, invalidation, snapshots) sits under a separate short-lived
meta lock; lock order is always flush-serial → key lock → meta lock.

Gating: per-query isolation is the safe default; sharing is enabled by
constructing QuipService with ``shared_impute=True`` or by setting
``QUIP_SHARED_IMPUTE=1``.
"""

from __future__ import annotations

import itertools
from typing import Callable, Dict, Optional

from repro.core.env import env_flag
from repro.core.relation import MaskedRelation
from repro.core.stats import ExecutionCounters, RuntimeStats
from repro.imputers.base import ImputationService, Imputer, ImputeStore

__all__ = ["SharedImputeStore", "resolve_shared_impute"]


def resolve_shared_impute(shared: Optional[bool]) -> bool:
    """Explicit argument > ``QUIP_SHARED_IMPUTE`` env (truthy/falsy via
    :func:`env_flag` — ``true``/``yes``/``on`` work, garbage raises) > off."""
    if shared is not None:
        return bool(shared)
    return env_flag("QUIP_SHARED_IMPUTE", False)


class SharedImputeStore(ImputeStore):
    """An :class:`ImputeStore` shared by many per-query services.

    Tracks per-cell ownership (which query filled it) so services can count
    cross-query hits, and hands each bound service a distinct ``owner_id``.
    """

    def __init__(self, tables: Dict[str, MaskedRelation]):
        super().__init__(tables, track_owners=True)
        self._owner_ids = itertools.count(1)

    def bind(
        self,
        default: Callable[[], Imputer],
        per_attr: Optional[Dict[str, Imputer]] = None,
        stats: Optional[RuntimeStats] = None,
        counters: Optional[ExecutionCounters] = None,
        batching: Optional[bool] = None,
        tracer=None,
        provenance=None,
    ) -> ImputationService:
        """A fresh per-query service (own queue, counters, stats) backed by
        this store's caches and models.  ``tracer``/``provenance`` ride on
        the per-query service (spans and explain reports stay per-query
        even though the cell caches are shared)."""
        return ImputationService(
            self.tables,
            default=default,
            per_attr=per_attr,
            stats=stats,
            counters=counters,
            batching=batching,
            store=self,
            owner_id=next(self._owner_ids),
            tracer=tracer,
            provenance=provenance,
        )
