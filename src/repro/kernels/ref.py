"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels are validated against these in
``tests/test_kernels.py`` over shape/dtype sweeps (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hashing import MULTIPLIERS, OFFSETS

__all__ = ["bloom_probe_ref", "masked_distance_ref", "masked_knn_ref"]

def bloom_probe_ref(
    bits: jnp.ndarray, folded: jnp.ndarray, num_hashes: int, log2m: int
) -> jnp.ndarray:
    """bits: (2**log2m // 32,) uint32 bitset.  folded: (n,) uint32 keys
    (int64 keys are folded on the host — see ``hashing.fold64`` — because
    x32-mode JAX has no 64-bit lanes).  True iff all ``num_hashes`` bits
    are set."""
    folded = folded.astype(jnp.uint32)[:, None]
    a = jnp.asarray(MULTIPLIERS[:num_hashes])[None, :]
    b = jnp.asarray(OFFSETS[:num_hashes])[None, :]
    pos = ((folded * a + b) >> jnp.uint32(32 - log2m)).astype(jnp.uint32)
    word = (pos >> jnp.uint32(5)).astype(jnp.int32)
    bit = pos & jnp.uint32(31)
    w = jnp.take(bits, word, axis=0)
    hit = (w >> bit) & jnp.uint32(1)
    return jnp.all(hit == 1, axis=1)


def masked_distance_ref(
    q: jnp.ndarray, qm: jnp.ndarray, r: jnp.ndarray, rm: jnp.ndarray
) -> jnp.ndarray:
    """Partial-distance matrix for masked KNN (sklearn KNNImputer semantics).

    q: (nq, d) float32, qm: (nq, d) observed-mask (1.0 observed, 0.0 missing)
    r: (nr, d), rm: (nr, d).
    dist[i,j] = (d / n_co) * sum_k qm*rm*(q-r)^2   over co-observed dims;
    +inf (large) where n_co == 0.
    """
    q = q.astype(jnp.float32) * qm
    r = r.astype(jnp.float32) * rm
    q2 = (q * q) @ rm.T  # sum_k qm*q^2*rm  (qm baked into q)
    r2 = qm @ (r * r).T
    cross = q @ r.T
    sq = q2 + r2 - 2.0 * cross
    n_co = qm @ rm.T
    d = q.shape[1]
    scaled = jnp.where(n_co > 0, sq * (d / jnp.maximum(n_co, 1.0)), jnp.inf)
    return jnp.maximum(scaled, 0.0)


def masked_knn_ref(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    k: int,
):
    """Top-k smallest partial distances.  Returns (dists (nq,k), idx (nq,k))."""
    dmat = masked_distance_ref(q, qm, r, rm)
    neg, idx = jax.lax.top_k(-dmat, k)
    return -neg, idx


def attention_ref(q, k, v, causal: bool = True, window=None, scale=None):
    """Oracle for the flash-attention kernel: materialized-softmax GQA.

    q: (B, S, H, D); k/v: (B, S, KV, D) → (B, S, H, D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // max(kv, 1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.astype(jnp.float32).reshape(b, s, kv, rep, d)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg,
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrst,btkd->bskrd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
