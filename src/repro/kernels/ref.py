"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: kernels are validated against these in
``tests/test_kernels.py`` over shape/dtype sweeps (interpret=True on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.hashing import MULTIPLIERS, OFFSETS

__all__ = [
    "bloom_probe_ref",
    "hash_join_probe_sorted_ref",
    "hash_join_ref",
    "masked_distance_ref",
    "masked_knn_ref",
    "neighbor_mean_ref",
    "neighbor_mode_ref",
    "segment_reduce_ref",
]


def hash_join_probe_sorted_ref(
    sorted_keys: jnp.ndarray, order: jnp.ndarray, probe_folded: jnp.ndarray,
    max_dup: int,
):
    """Probe half of the sort-based join: build side pre-sorted once
    (``sorted_keys = build[order]``, stable) so chunked probes don't repeat
    the O(nb·log nb) sort.  Returns ``(counts (np,) int32,
    matches (np, max_dup) int32)``: row i holds the build rows whose folded
    key equals probe i's, ascending, ``-1``-padded."""
    nb = sorted_keys.shape[0]
    lo = jnp.searchsorted(sorted_keys, probe_folded, side="left")
    hi = jnp.searchsorted(sorted_keys, probe_folded, side="right")
    counts = (hi - lo).astype(jnp.int32)
    j = jnp.arange(max_dup, dtype=jnp.int32)
    idx = lo.astype(jnp.int32)[:, None] + j[None, :]
    valid = j[None, :] < counts[:, None]
    gathered = jnp.take(
        order, jnp.clip(idx, 0, max(nb - 1, 0)), axis=0
    ).astype(jnp.int32)
    return counts, jnp.where(valid, gathered, -1)


def hash_join_ref(
    build_folded: jnp.ndarray, probe_folded: jnp.ndarray, max_dup: int
):
    """Fold-level hash-join candidates, sort-based (the jnp oracle for the
    open-addressing Pallas pair in ``hash_join.py``).

    build_folded: (nb,) uint32; probe_folded: (np,) uint32; ``max_dup`` is a
    static bound on the fold-level duplication of the build side.  Fold
    collisions are resolved by the host wrapper (``ops.hash_join_match``)
    against the original 64-bit keys.
    """
    order = jnp.argsort(build_folded, stable=True).astype(jnp.int32)
    return hash_join_probe_sorted_ref(
        build_folded[order], order, probe_folded, max_dup
    )

def bloom_probe_ref(
    bits: jnp.ndarray, folded: jnp.ndarray, num_hashes: int, log2m: int
) -> jnp.ndarray:
    """bits: (2**log2m // 32,) uint32 bitset.  folded: (n,) uint32 keys
    (int64 keys are folded on the host — see ``hashing.fold64`` — because
    x32-mode JAX has no 64-bit lanes).  True iff all ``num_hashes`` bits
    are set."""
    folded = folded.astype(jnp.uint32)[:, None]
    a = jnp.asarray(MULTIPLIERS[:num_hashes])[None, :]
    b = jnp.asarray(OFFSETS[:num_hashes])[None, :]
    pos = ((folded * a + b) >> jnp.uint32(32 - log2m)).astype(jnp.uint32)
    word = (pos >> jnp.uint32(5)).astype(jnp.int32)
    bit = pos & jnp.uint32(31)
    w = jnp.take(bits, word, axis=0)
    hit = (w >> bit) & jnp.uint32(1)
    return jnp.all(hit == 1, axis=1)


def masked_distance_ref(
    q: jnp.ndarray, qm: jnp.ndarray, r: jnp.ndarray, rm: jnp.ndarray
) -> jnp.ndarray:
    """Partial-distance matrix for masked KNN (sklearn KNNImputer semantics).

    q: (nq, d) float32, qm: (nq, d) observed-mask (1.0 observed, 0.0 missing)
    r: (nr, d), rm: (nr, d).
    dist[i,j] = (d / n_co) * sum_k qm*rm*(q-r)^2   over co-observed dims;
    +inf (large) where n_co == 0.
    """
    q = q.astype(jnp.float32) * qm
    r = r.astype(jnp.float32) * rm
    q2 = (q * q) @ rm.T  # sum_k qm*q^2*rm  (qm baked into q)
    r2 = qm @ (r * r).T
    cross = q @ r.T
    sq = q2 + r2 - 2.0 * cross
    n_co = qm @ rm.T
    d = q.shape[1]
    scaled = jnp.where(n_co > 0, sq * (d / jnp.maximum(n_co, 1.0)), jnp.inf)
    return jnp.maximum(scaled, 0.0)


def masked_knn_ref(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    k: int,
):
    """Top-k smallest partial distances.  Returns (dists (nq,k), idx (nq,k))."""
    dmat = masked_distance_ref(q, qm, r, rm)
    neg, idx = jax.lax.top_k(-dmat, k)
    return -neg, idx


def neighbor_mean_ref(vals: jnp.ndarray) -> jnp.ndarray:
    """KNN float aggregation: per-row mean of the (b, k) neighbour targets."""
    return vals.astype(jnp.float32).mean(axis=1)


def neighbor_mode_ref(codes: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """KNN categorical aggregation: per-row mode over dictionary codes.

    codes: (b, k) int32 in [0, num_classes).  Returns (b,) int32 — the class
    with the highest count; ties break to the *smallest* class index
    (``jnp.argmax`` returns the first maximum), which, with classes produced
    by ``np.unique`` (ascending values), matches the per-row
    ``u[np.argmax(c)]`` loop of the seed imputer bit-for-bit.
    """
    onehot = jax.nn.one_hot(codes, num_classes, dtype=jnp.int32)  # (b, k, U)
    counts = onehot.sum(axis=1)  # (b, U)
    return jnp.argmax(counts, axis=1).astype(jnp.int32)


def segment_reduce_ref(
    vals: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int, op: str
) -> jnp.ndarray:
    """Grouped-aggregate segment reduction (the jnp oracle for
    ``segment_ops.segment_reduce_pallas``).

    vals: (n,); seg_ids: (n,) int32 in [0, num_segments) (negative ids drop
    the row).  ``op`` is static: sum/min/max — count is a sum of ones, done
    by the caller.  Empty segments hold the reduction identity of the
    compute dtype (0 / dtype-max / dtype-min), matching ``jax.ops``
    semantics; callers mask them via the count op.
    """
    if op == "sum":
        return jax.ops.segment_sum(vals, seg_ids, num_segments=num_segments)
    if op == "min":
        return jax.ops.segment_min(vals, seg_ids, num_segments=num_segments)
    if op == "max":
        return jax.ops.segment_max(vals, seg_ids, num_segments=num_segments)
    raise ValueError(f"unknown segment op {op!r}")


def attention_ref(q, k, v, causal: bool = True, window=None, scale=None):
    """Oracle for the flash-attention kernel: materialized-softmax GQA.

    q: (B, S, H, D); k/v: (B, S, KV, D) → (B, S, H, D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // max(kv, 1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.astype(jnp.float32).reshape(b, s, kv, rep, d)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg,
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrst,btkd->bskrd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
