"""Pallas kernel pair: open-addressing hash-join build + probe.

This is the kernel backing of the QUIP join spine (modified outer join ⋈̂,
paper Alg. 1, and the BF_Join recovery pass, Alg. 2).  The relational core —
"all (probe_idx, build_idx) pairs with equal keys" — was previously served
by a pure-NumPy sort-join (``core.triggers.multi_match``); these kernels move
it onto the same ref/pallas dispatch layer as the bloom probe and the masked
KNN distance (``kernels.ops``).

Layout
------
Keys are host-folded int64 → uint32 (``hashing.fold64``) because x32-mode JAX
and the TPU VPU have no 64-bit integer lanes.  Fold collisions therefore make
the kernel emit *candidate* pairs; the ``ops.hash_join_match`` wrapper
re-checks candidates against the original 64-bit keys on the host, so the
subsystem is exact end-to-end.

* **build** — one sequential pass inserting each build key into a
  power-of-two open-addressing table (linear probing, multiply-shift home
  slot).  Slots store the folded key plus the build-row index; ``idx == -1``
  marks an empty slot, so any uint32 key value is representable.  Insertion
  in row order makes fold-equal keys occupy their shared probe chain in
  ascending row order — exactly the order the sort-based NumPy oracle emits.
* **probe** — a grid over ``BLOCK``-lane probe-key blocks with the whole
  table VMEM-resident (like the bloom-probe bitset).  Each lane walks its
  chain until the first empty slot, counting matches and scattering matched
  build indices into a fixed-size ``(BLOCK, max_dup)`` match block via a
  one-hot column select (``max_dup`` = max fold-level duplication of the
  build side, a static host-computed bound).  Outputs are the per-probe match
  counts plus the ragged pairs in these fixed-size blocks.

Chain walks terminate because the table is at most half full (capacity ≥ 2n),
and a defensive step bound of ``capacity`` caps the while loop regardless.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "hash_join_build_pallas",
    "hash_join_probe_pallas",
    "table_log2cap",
]

BLOCK = 256  # probe keys per grid step

# Dedicated odd multiplier for the table's home-slot hash (splitmix-derived,
# distinct from the bloom filter's MULTIPLIERS so table layout and bloom bits
# stay uncorrelated).
_TABLE_MULT = 0x2545F491


def table_log2cap(n_build: int) -> int:
    """log2 table capacity: smallest power of two ≥ 2·n (load factor ≤ 0.5),
    floored at 128 slots so tiny builds still vectorize."""
    cap = 128
    log2cap = 7
    while cap < 2 * max(n_build, 1):
        cap <<= 1
        log2cap += 1
    return log2cap


def _home(keys: jnp.ndarray, log2cap: int) -> jnp.ndarray:
    return (keys * jnp.uint32(_TABLE_MULT)) >> jnp.uint32(32 - log2cap)


# --------------------------------------------------------------------------- #
# build
# --------------------------------------------------------------------------- #
def _build_kernel(keys_ref, slot_key_ref, slot_idx_ref, *, n: int,
                  log2cap: int):
    # The table is carried functionally through the insertion loop (ref
    # reads inside a while_loop cond don't discharge in interpret mode) and
    # written back once at the end.
    cap = 1 << log2cap
    mask = jnp.uint32(cap - 1)
    keys = keys_ref[...].astype(jnp.uint32)

    def insert(i, table):
        slot_key, slot_idx = table
        key = keys[i]

        def occupied(pos):
            return (
                jax.lax.dynamic_index_in_dim(
                    slot_idx, pos.astype(jnp.int32), keepdims=False
                )
                >= 0
            )

        pos = jax.lax.while_loop(
            occupied, lambda p: (p + 1) & mask, _home(key, log2cap)
        )
        at = pos.astype(jnp.int32)
        return (
            jax.lax.dynamic_update_index_in_dim(slot_key, key, at, 0),
            jax.lax.dynamic_update_index_in_dim(
                slot_idx, i.astype(jnp.int32), at, 0
            ),
        )

    slot_key, slot_idx = jax.lax.fori_loop(
        0,
        n,
        insert,
        (jnp.zeros((cap,), jnp.uint32), jnp.full((cap,), -1, jnp.int32)),
    )
    slot_key_ref[...] = slot_key
    slot_idx_ref[...] = slot_idx


@functools.partial(jax.jit, static_argnames=("log2cap", "interpret"))
def hash_join_build_pallas(
    folded: jnp.ndarray, *, log2cap: int, interpret: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """folded: (n,) uint32 build keys → (slot_key (cap,) uint32,
    slot_idx (cap,) int32) with ``slot_idx == -1`` marking empty slots."""
    n = folded.shape[0]
    cap = 1 << log2cap
    assert cap >= 2 * max(n, 1), "hash table must stay at most half full"
    return pl.pallas_call(
        functools.partial(_build_kernel, n=n, log2cap=log2cap),
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((cap,), lambda i: (0,)),
            pl.BlockSpec((cap,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap,), jnp.uint32),
            jax.ShapeDtypeStruct((cap,), jnp.int32),
        ],
        interpret=interpret,
    )(folded.astype(jnp.uint32))


# --------------------------------------------------------------------------- #
# probe
# --------------------------------------------------------------------------- #
def _probe_kernel(probe_ref, slot_key_ref, slot_idx_ref, counts_ref,
                  matches_ref, *, log2cap: int, max_dup: int):
    cap = 1 << log2cap
    mask = jnp.uint32(cap - 1)
    keys = probe_ref[...].astype(jnp.uint32)
    slot_key = slot_key_ref[...]
    slot_idx = slot_idx_ref[...]
    nlanes = keys.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (nlanes, max_dup), 1)

    def cond(state):
        _pos, _cnt, _m, active, step = state
        return jnp.logical_and(jnp.any(active), step < cap)

    def body(state):
        pos, cnt, m, active, step = state
        at = pos.astype(jnp.int32)
        sk = jnp.take(slot_key, at, axis=0)
        si = jnp.take(slot_idx, at, axis=0)
        occupied = si >= 0
        match = active & occupied & (sk == keys)
        put = match[:, None] & (col == jnp.minimum(cnt, max_dup - 1)[:, None])
        m = jnp.where(put, si[:, None], m)
        cnt = cnt + match.astype(jnp.int32)
        active = active & occupied
        pos = jnp.where(active, (pos + 1) & mask, pos)
        return pos, cnt, m, active, step + 1

    state = (
        _home(keys, log2cap),
        jnp.zeros(nlanes, jnp.int32),
        jnp.full((nlanes, max_dup), -1, jnp.int32),
        jnp.ones(nlanes, jnp.bool_),
        jnp.int32(0),
    )
    _pos, cnt, m, _active, _step = jax.lax.while_loop(cond, body, state)
    counts_ref[...] = cnt
    matches_ref[...] = m


@functools.partial(
    jax.jit, static_argnames=("log2cap", "max_dup", "interpret")
)
def hash_join_probe_pallas(
    slot_key: jnp.ndarray,
    slot_idx: jnp.ndarray,
    folded_probe: jnp.ndarray,
    *,
    log2cap: int,
    max_dup: int,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Probe the built table with (n,) uint32 keys.

    Returns ``(counts (n,) int32, matches (n, max_dup) int32)`` where row i
    holds the matched build-row indices in chain order (ascending build row
    for fold-equal keys) and ``-1`` pads unused columns.
    """
    n = folded_probe.shape[0]
    cap = 1 << log2cap
    f = folded_probe.astype(jnp.uint32)
    pad = (-n) % BLOCK
    if pad:
        f = jnp.pad(f, (0, pad))
    npad = f.shape[0]
    counts, matches = pl.pallas_call(
        functools.partial(_probe_kernel, log2cap=log2cap, max_dup=max_dup),
        grid=(npad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((cap,), lambda i: (0,)),  # whole table in VMEM
            pl.BlockSpec((cap,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK, max_dup), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad, max_dup), jnp.int32),
        ],
        interpret=interpret,
    )(f, slot_key, slot_idx)
    return counts[:n], matches[:n]
