"""Pallas TPU kernels: KNN neighbour aggregation (mean / categorical mode).

After the masked-distance kernel and top-k pick the k neighbours per query
row, the imputed value is a per-row reduction of the gathered neighbour
targets — a float mean, or, for dictionary-coded categorical attributes, the
mode.  The seed engine ran the mode as a per-row Python loop
(``np.unique`` + ``argmax`` per row), an O(b·k) interpreter hot path inside
the paper's dominant cost (Fig. 2: KNN inference).  Here both reductions are
single-pass vector kernels:

* ``neighbor_mean_pallas``  — (b, k) float32 → (b,) row means.  Rows are
  tiled in BB=128 blocks; padded k-columns are zero so the sum is exact and
  the divide uses the true k.
* ``neighbor_mode_pallas``  — (b, k) int32 dictionary codes → (b,) argmax
  of the per-row bincount.  Counts are built per row block against a
  broadcasted class iota (one VPU compare+add per neighbour column — k is
  small and static, so the loop unrolls), then ``argmax`` over classes.
  Ties break to the smallest class index, matching the ``np.unique``-order
  semantics of the NumPy oracle bit-for-bit.  The (BB, num_classes) count
  block is VMEM-resident: callers dictionary-compress the batch first
  (classes = distinct neighbour values, typically ≪ b·k).

Padded codes are −1, which matches no class; fully-padded rows argmax to
class 0 and are sliced off by the host wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["neighbor_mean_pallas", "neighbor_mode_pallas"]

BB = 128  # query rows per block
LANE = 128  # lane multiple for the k / class dimensions


def _pad_axis(x, mult, axis, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _mean_kernel(vals_ref, out_ref, *, k: int):
    vals = vals_ref[...].astype(jnp.float32)  # (BB, Kp); pad columns are 0
    out_ref[...] = vals.sum(axis=1) / jnp.float32(k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def neighbor_mean_pallas(vals: jnp.ndarray, *, interpret: bool = True
                         ) -> jnp.ndarray:
    """(b, k) float32 neighbour targets → (b,) float32 row means."""
    b, k = vals.shape
    v = _pad_axis(vals.astype(jnp.float32), BB, 0, 0.0)
    v = _pad_axis(v, LANE, 1, 0.0)
    bp, kp = v.shape
    out = pl.pallas_call(
        functools.partial(_mean_kernel, k=k),
        grid=(bp // BB,),
        in_specs=[pl.BlockSpec((BB, kp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=interpret,
    )(v)
    return out[:b]


def _mode_kernel(codes_ref, out_ref, *, k: int, num_classes_p: int):
    classes = jax.lax.broadcasted_iota(jnp.int32, (BB, num_classes_p), 1)
    counts = jnp.zeros((BB, num_classes_p), jnp.int32)
    for j in range(k):  # static unroll: KNN k is small
        cj = codes_ref[:, j]  # (BB,)
        counts = counts + (cj[:, None] == classes).astype(jnp.int32)
    out_ref[...] = jnp.argmax(counts, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_classes", "interpret"))
def neighbor_mode_pallas(codes: jnp.ndarray, *, num_classes: int,
                         interpret: bool = True) -> jnp.ndarray:
    """(b, k) int32 codes in [0, num_classes) → (b,) int32 per-row mode
    class (bincount argmax, ties to the smallest class index)."""
    b, k = codes.shape
    c = _pad_axis(codes.astype(jnp.int32), BB, 0, -1)
    c = _pad_axis(c, LANE, 1, -1)
    bp, kp = c.shape
    ncp = num_classes + ((-num_classes) % LANE)
    out = pl.pallas_call(
        functools.partial(_mode_kernel, k=k, num_classes_p=ncp),
        grid=(bp // BB,),
        in_specs=[pl.BlockSpec((BB, kp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.int32),
        interpret=interpret,
    )(c)
    return out[:b]
