"""Shared hash math for the bloom filter (build + probe must agree bit-for-bit).

Multiply-shift hashing over uint32 lanes (TPU-friendly: no 64-bit multiplies
on the VPU).  An int64 key is folded to uint32 via ``lo ^ (hi * PHI)`` and the
i-th hash is ``(folded * A_i + B_i) >> (32 - log2m)`` with odd multipliers.
"""

from __future__ import annotations

import numpy as np

__all__ = ["MULTIPLIERS", "OFFSETS", "fold64", "hash_positions_np", "MAX_HASHES"]

_PHI = np.uint32(0x9E3779B9)

# Odd multipliers / offsets (splitmix-derived), enough for k <= 8 hashes.
MULTIPLIERS = np.array(
    [0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F, 0x165667B1,
     0x9E3779B1, 0xFF51AFD7, 0xC4CEB9FF, 0x2545F491],
    dtype=np.uint32,
)
OFFSETS = np.array(
    [0x1B873593, 0xE6546B64, 0x85EBCA77, 0xC2B2AE3D,
     0x27D4EB4F, 0x165667C5, 0x9E3779B9, 0xFF51AFD9],
    dtype=np.uint32,
)
MAX_HASHES = len(MULTIPLIERS)


def fold64(keys) -> np.ndarray:
    """Fold int64 keys to uint32 (numpy); same math as the jnp/Pallas fold."""
    k = np.asarray(keys).astype(np.int64)
    lo = (k & np.int64(0xFFFFFFFF)).astype(np.uint32)
    hi = ((k >> np.int64(32)) & np.int64(0xFFFFFFFF)).astype(np.uint32)
    return lo ^ (hi * _PHI)


def hash_positions_np(keys, num_hashes: int, log2m: int) -> np.ndarray:
    """(n, num_hashes) bit positions in [0, 2**log2m)."""
    assert num_hashes <= MAX_HASHES
    folded = fold64(keys)[:, None]  # (n, 1)
    a = MULTIPLIERS[None, :num_hashes]
    b = OFFSETS[None, :num_hashes]
    h = folded * a + b  # uint32 wraparound
    return (h >> np.uint32(32 - log2m)).astype(np.uint32)
