"""Pallas TPU kernel: segment reductions for grouped aggregates.

The compiled-plan executor (``core/compiled.py``) lowers a grouped
COUNT/SUM/AVG/MIN/MAX to a segment reduction over the group-id column
(``inv`` from ``np.unique(keys, return_inverse=True)``).  The host NumPy
path in ``kernels.ops.segment_reduce`` stays the bit-exact oracle; this
kernel is the device path (``QUIP_SEGMENT_IMPL=pallas``).

Shape strategy: rows are tiled in RB-sized 1-D blocks; each grid step
builds a (RB, Sp) one-hot match of its segment ids against a class iota
and folds it into the (Sp,) accumulator held in the output block (the TPU
grid is sequential, so ``out_ref`` accumulates across steps — the same
revisiting pattern as the hash-join build kernel).  Sp is the padded
segment count; grouped aggregates have group cardinality ≪ rows, so the
(RB, Sp) block stays VMEM-resident.  Padded rows carry segment id −1,
which matches no class; padded segments are sliced off by the wrapper.

``op`` is static: ``sum`` accumulates ``+``, ``min``/``max`` accumulate
``jnp.minimum``/``maximum`` with the dtype identity as the initial fill
(count is a sum of ones, handled by the wrapper).  Empty segments hold
the identity; callers mask them via the count op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_reduce_pallas"]

RB = 512  # rows per block
LANE = 128  # lane multiple for the segment dimension

_OPS = ("sum", "min", "max")


def _pad_axis(x, mult, axis, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _identity(op: str, dtype) -> jnp.ndarray:
    if op == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        return jnp.array(info.max if op == "min" else info.min, dtype)
    return jnp.array(jnp.inf if op == "min" else -jnp.inf, dtype)


def _segment_kernel(vals_ref, seg_ref, out_ref, *, op: str):
    seg = seg_ref[...]  # (RB,) int32; pad rows are -1
    vals = vals_ref[...]  # (RB,)
    sp = out_ref.shape[0]
    classes = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], sp), 1)
    onehot = seg[:, None] == classes  # (RB, Sp)
    ident = _identity(op, vals.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    masked = jnp.where(onehot, vals[:, None], ident)
    if op == "sum":
        out_ref[...] += masked.sum(axis=0)
    elif op == "min":
        out_ref[...] = jnp.minimum(out_ref[...], masked.min(axis=0))
    else:
        out_ref[...] = jnp.maximum(out_ref[...], masked.max(axis=0))


@functools.partial(jax.jit, static_argnames=("num_segments", "op", "interpret"))
def segment_reduce_pallas(
    vals: jnp.ndarray,
    seg_ids: jnp.ndarray,
    *,
    num_segments: int,
    op: str,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n,) values + (n,) int32 segment ids → (num_segments,) reduction.

    Negative segment ids drop the row (the wrapper pads with −1).
    """
    if op not in _OPS:
        raise ValueError(f"unknown segment op {op!r}")
    (n,) = vals.shape
    ident = _identity(op, vals.dtype)
    v = _pad_axis(vals, RB, 0, ident)
    s = _pad_axis(seg_ids.astype(jnp.int32), RB, 0, -1)
    (npad,) = v.shape
    sp = num_segments + ((-num_segments) % LANE)
    out = pl.pallas_call(
        functools.partial(_segment_kernel, op=op),
        grid=(npad // RB,),
        in_specs=[
            pl.BlockSpec((RB,), lambda i: (i,)),
            pl.BlockSpec((RB,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((sp,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((sp,), vals.dtype),
        interpret=interpret,
    )(v, s)
    return out[:num_segments]
