"""Pallas TPU kernel: bloom-filter probe (QUIP join trigger / semi-join filter).

The bitset (≤ 2^23 bits = 1 MiB) is VMEM-resident for the whole grid; keys are
streamed in 1024-lane blocks.  Each lane computes ``num_hashes`` multiply-shift
positions and tests the corresponding bit via a vectorized word gather.  This
is the probe used by BF_Join (paper Alg. 2) and the VF-list semi-join filter
(paper §5.3) — memory-bound integer work that would otherwise round-trip HBM
per hash function.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.hashing import MULTIPLIERS, OFFSETS

__all__ = ["bloom_probe_pallas"]

BLOCK = 1024


def _kernel(folded_ref, bits_ref, out_ref, *, num_hashes: int, log2m: int):
    folded = folded_ref[...].astype(jnp.uint32)
    bits = bits_ref[...]
    ok = jnp.ones(folded.shape, dtype=jnp.bool_)
    for i in range(num_hashes):
        h = folded * jnp.uint32(int(MULTIPLIERS[i])) + jnp.uint32(int(OFFSETS[i]))
        pos = h >> jnp.uint32(32 - log2m)
        word_idx = (pos >> jnp.uint32(5)).astype(jnp.int32)
        bit = pos & jnp.uint32(31)
        w = jnp.take(bits, word_idx, axis=0)
        ok = ok & (((w >> bit) & jnp.uint32(1)) == 1)
    out_ref[...] = ok


@functools.partial(jax.jit, static_argnames=("num_hashes", "log2m", "interpret"))
def bloom_probe_pallas(
    bits: jnp.ndarray,
    folded: jnp.ndarray,
    *,
    num_hashes: int,
    log2m: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """bits: (2**log2m // 32,) uint32; folded: (n,) uint32 keys → (n,) bool.

    Keys are pre-folded to uint32 on the host (``hashing.fold64``): x32-mode
    JAX and the TPU VPU have no 64-bit integer lanes.
    """
    n = folded.shape[0]
    f = folded.astype(jnp.uint32)
    pad = (-n) % BLOCK
    if pad:
        f = jnp.pad(f, (0, pad))
    npad = f.shape[0]
    grid = (npad // BLOCK,)
    out = pl.pallas_call(
        functools.partial(_kernel, num_hashes=num_hashes, log2m=log2m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec(bits.shape, lambda i: (0,)),  # whole bitset in VMEM
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.bool_),
        interpret=interpret,
    )(f, bits)
    return out[:n]
