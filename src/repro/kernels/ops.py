"""Jit'd public wrappers for the Pallas kernels with implementation dispatch.

``impl``:
  * ``"numpy"``   — pure-host port (no device round-trip; exact keys for
                    the join, float32 math for the distance).
  * ``"ref"``     — pure-jnp oracle (fast XLA path on CPU; default here).
  * ``"pallas"``  — the Pallas kernel.  On this CPU-only container it runs in
                    interpret mode; on TPU it compiles to Mosaic.

Every public op resolves ``impl`` through a ``resolve_*_impl`` knob
(``QUIP_<OP>_IMPL`` env) or forwards it to one that does — the quiplint
kernel-parity pass (``python -m repro.analysis``) enforces this triple.
The unset default is chosen per-backend: Pallas on TPU, ref on CPU
(interpret-mode Pallas is a correctness tool, not a performance path).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.env import env_choice
from repro.kernels import ref as _ref
from repro.kernels.bloom_probe import bloom_probe_pallas
from repro.kernels.hash_join import (
    hash_join_build_pallas,
    hash_join_probe_pallas,
    table_log2cap,
)
from repro.kernels.hashing import MULTIPLIERS, OFFSETS, fold64
from repro.kernels.knn_distance import masked_distance_pallas
from repro.kernels.neighbor_agg import neighbor_mean_pallas, neighbor_mode_pallas
from repro.kernels.segment_ops import segment_reduce_pallas

__all__ = [
    "bloom_probe",
    "hash_join_match",
    "masked_distance",
    "masked_knn",
    "neighbor_aggregate",
    "segment_reduce",
    "default_impl",
    "resolve_bloom_impl",
    "resolve_dist_impl",
    "resolve_join_impl",
    "resolve_knn_impl",
    "resolve_segment_impl",
]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_HOST_IMPLS = ("numpy", "ref", "pallas")


def resolve_bloom_impl(impl: Optional[str] = None) -> str:
    """Bloom-probe dispatch: explicit ``impl`` > ``QUIP_BLOOM_IMPL`` env >
    the backend default (Pallas on TPU, ref elsewhere).  A *set* env value
    is validated against numpy/ref/pallas; unset falls through to the
    backend choice."""
    if impl is not None:
        if impl not in _HOST_IMPLS:
            raise ValueError(f"unknown bloom impl {impl!r}")
        return impl
    impl = env_choice("QUIP_BLOOM_IMPL", _HOST_IMPLS, "auto")
    return default_impl() if impl == "auto" else impl


def resolve_dist_impl(impl: Optional[str] = None) -> str:
    """Masked-distance dispatch: explicit ``impl`` > ``QUIP_DIST_IMPL`` env
    > the backend default (Pallas on TPU, ref elsewhere)."""
    if impl is not None:
        if impl not in _HOST_IMPLS:
            raise ValueError(f"unknown distance impl {impl!r}")
        return impl
    impl = env_choice("QUIP_DIST_IMPL", _HOST_IMPLS, "auto")
    return default_impl() if impl == "auto" else impl


def resolve_join_impl(impl: Optional[str] = None) -> str:
    """Kernel-level join dispatch: explicit ``impl`` > ``QUIP_JOIN_IMPL``
    env > the backend default.  Distinct from the *engine-level*
    ``core.triggers.resolve_join_impl``, whose unset default is the NumPy
    oracle (``multi_match``) and never reaches this module; an explicit
    ``QUIP_JOIN_IMPL=ref|pallas`` routes the engine here, where the same
    knob then picks the kernel path."""
    if impl is not None:
        if impl not in _HOST_IMPLS:
            raise ValueError(f"unknown join impl {impl!r}")
        return impl
    impl = env_choice("QUIP_JOIN_IMPL", _HOST_IMPLS, "auto")
    return default_impl() if impl == "auto" else impl


def bloom_probe(
    bits: jnp.ndarray,
    folded: jnp.ndarray,
    *,
    num_hashes: int,
    log2m: int,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """``folded``: uint32 host-folded keys (see ``hashing.fold64``)."""
    impl = resolve_bloom_impl(impl)
    if impl == "numpy":
        # host multiply-shift probe — same uint32 wraparound math as
        # hashing.hash_positions_np, but over pre-folded keys
        bits_np = np.asarray(bits, dtype=np.uint32)
        f = np.asarray(folded, dtype=np.uint32)[:, None]
        pos = ((f * MULTIPLIERS[None, :num_hashes]
                + OFFSETS[None, :num_hashes])
               >> np.uint32(32 - log2m)).astype(np.uint32)
        word = (pos >> np.uint32(5)).astype(np.int64)
        bit = pos & np.uint32(31)
        hit = (bits_np[word] >> bit) & np.uint32(1)
        return np.all(hit == 1, axis=1)
    if impl == "pallas":
        return bloom_probe_pallas(
            bits, folded, num_hashes=num_hashes, log2m=log2m, interpret=_interpret()
        )
    return _probe_ref_jit(bits, folded, num_hashes, log2m)


_probe_ref_jit = jax.jit(_ref.bloom_probe_ref, static_argnums=(2, 3))


_hash_join_probe_sorted_jit = jax.jit(
    _ref.hash_join_probe_sorted_ref, static_argnums=(3,)
)


def hash_join_match(
    build_keys,
    probe_keys,
    *,
    impl: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """All (probe_idx, build_idx) pairs with equal int64 keys.

    The kernel-backed twin of ``core.triggers.multi_match`` (the NumPy
    oracle): pairs come back as host int64 arrays ordered by probe index,
    ascending build index within a probe — bit-identical to the oracle.

    Keys are folded to uint32 for the device (``hashing.fold64``); the
    kernels emit fold-level *candidates* (counts + fixed-size match blocks)
    which are verified here against the original 64-bit keys, so fold
    collisions never produce wrong pairs.  ``impl="numpy"`` sort-joins on
    the original int64 keys directly (no folding, no verification pass).
    """
    impl = resolve_join_impl(impl)
    b = np.ascontiguousarray(np.asarray(build_keys, dtype=np.int64))
    p = np.ascontiguousarray(np.asarray(probe_keys, dtype=np.int64))
    if len(b) == 0 or len(p) == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    if impl == "numpy":
        return _hash_join_numpy(b, p)
    fb = fold64(b)
    fp = fold64(p)
    # static fold-level duplication bound (columns of the match block)
    max_dup = int(np.unique(fb, return_counts=True)[1].max())
    # bound the dense (chunk × max_dup) match block; chunking the probe side
    # keeps memory flat on skewed builds while preserving probe-major order
    chunk = max(256, _DENSE_BUDGET // max_dup)
    # build once (table / sorted order), probe per chunk
    if impl == "pallas":
        log2cap = table_log2cap(len(b))
        slot_key, slot_idx = hash_join_build_pallas(
            jnp.asarray(fb), log2cap=log2cap, interpret=_interpret()
        )
    else:
        order = np.argsort(fb, kind="stable").astype(np.int32)
        sorted_keys = jnp.asarray(fb[order])
        order = jnp.asarray(order)
    probe_parts, build_parts = [], []
    for lo in range(0, len(p), chunk):
        fpc = fp[lo:lo + chunk]
        if impl == "pallas":
            counts, matches = hash_join_probe_pallas(
                slot_key,
                slot_idx,
                jnp.asarray(fpc),
                log2cap=log2cap,
                max_dup=max_dup,
                interpret=_interpret(),
            )
        else:
            counts, matches = _hash_join_probe_sorted_jit(
                sorted_keys, order, jnp.asarray(fpc), max_dup
            )
        counts = np.asarray(counts, dtype=np.int64)
        matches = np.asarray(matches)
        # ragged expansion: row-major valid entries are already in oracle order
        probe_parts.append(
            np.repeat(np.arange(len(fpc), dtype=np.int64), counts) + lo
        )
        build_parts.append(matches[matches >= 0].astype(np.int64))
    probe_idx = np.concatenate(probe_parts)
    build_idx = np.concatenate(build_parts)
    # exact 64-bit verification kills fold-collision candidates
    keep = b[build_idx] == p[probe_idx]
    if not keep.all():
        probe_idx, build_idx = probe_idx[keep], build_idx[keep]
    return probe_idx, build_idx


_DENSE_BUDGET = 1 << 24  # match-block entries per probe chunk (64 MiB int32)


def _hash_join_numpy(b: np.ndarray, p: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Host sort-join on exact int64 keys: probe-major pairs, ascending
    build index within a probe (the stable argsort keeps equal keys in
    original order) — bit-identical to ``core.triggers.multi_match``."""
    order = np.argsort(b, kind="stable")
    sb = b[order]
    lo = np.searchsorted(sb, p, side="left")
    hi = np.searchsorted(sb, p, side="right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    probe_idx = np.repeat(np.arange(len(p), dtype=np.int64), counts)
    starts = np.repeat(lo, counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    build_idx = order[starts + offs].astype(np.int64)
    return probe_idx, build_idx


def masked_distance(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    *,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    impl = resolve_dist_impl(impl)
    if impl == "numpy":
        return _masked_distance_numpy(q, qm, r, rm)
    if impl == "pallas":
        return masked_distance_pallas(q, qm, r, rm, interpret=_interpret())
    return _dist_ref_jit(q, qm, r, rm)


def _masked_distance_numpy(q, qm, r, rm) -> np.ndarray:
    """float32 host port of ``ref.masked_distance_ref`` (same compute
    dtype, so the three impls agree to the kernel tests' tolerance)."""
    qm = np.asarray(qm, dtype=np.float32)
    rm = np.asarray(rm, dtype=np.float32)
    q = np.asarray(q, dtype=np.float32) * qm
    r = np.asarray(r, dtype=np.float32) * rm
    sq = (q * q) @ rm.T + qm @ (r * r).T - 2.0 * (q @ r.T)
    n_co = qm @ rm.T
    d = np.float32(q.shape[1])
    scaled = np.where(n_co > 0, sq * (d / np.maximum(n_co, np.float32(1.0))),
                      np.float32(np.inf))
    return np.maximum(scaled, np.float32(0.0))


_dist_ref_jit = jax.jit(_ref.masked_distance_ref)


def masked_knn(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    k: int,
    *,
    impl: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dmat = masked_distance(q, qm, r, rm, impl=impl)
    neg, idx = jax.lax.top_k(-jnp.asarray(dmat), k)
    return -neg, idx


def resolve_knn_impl(impl: Optional[str] = None) -> str:
    """KNN-aggregation dispatch: explicit ``impl`` > ``QUIP_KNN_IMPL`` env >
    ``"numpy"`` (the vectorized host oracle, bit-identical to the seed
    per-row loop)."""
    if impl is not None:
        if impl not in _HOST_IMPLS:
            raise ValueError(f"unknown knn impl {impl!r}")
        return impl
    return env_choice("QUIP_KNN_IMPL", _HOST_IMPLS, "numpy")


def resolve_segment_impl(impl: Optional[str] = None) -> str:
    """Segment-reduction dispatch: explicit ``impl`` > ``QUIP_SEGMENT_IMPL``
    env > ``"numpy"`` (the per-segment host oracle, bit-identical to the
    interpreter's per-group reductions)."""
    if impl is not None:
        if impl not in _HOST_IMPLS:
            raise ValueError(f"unknown segment impl {impl!r}")
        return impl
    return env_choice("QUIP_SEGMENT_IMPL", _HOST_IMPLS, "numpy")


_SEGMENT_OPS = ("count", "sum", "min", "max")

_seg_ref_jit = jax.jit(_ref.segment_reduce_ref, static_argnums=(2, 3))


def _segment_numpy(vals: np.ndarray, seg: np.ndarray, num_segments: int,
                   op: str) -> np.ndarray:
    """Host oracle: per-segment ufunc reductions in row order.

    A stable argsort groups rows by segment while preserving row order
    within each segment, so each slice is the exact sequence the
    interpreter's boolean-mask extraction produces — float sums therefore
    use the same pairwise accumulation and are bit-identical to
    ``executor._aggregate``.
    """
    if np.issubdtype(vals.dtype, np.integer):
        out_dtype = np.int64
        lo, hi = np.iinfo(np.int64).min, np.iinfo(np.int64).max
    else:
        out_dtype = np.float64
        lo, hi = -np.inf, np.inf
    ident = {"sum": 0, "min": hi, "max": lo}[op]
    out = np.full(num_segments, ident, dtype=out_dtype)
    order = np.argsort(seg, kind="stable")
    sv = vals[order]
    bounds = np.searchsorted(seg[order], np.arange(num_segments + 1))
    for i in range(num_segments):
        sl = sv[bounds[i]:bounds[i + 1]]
        if len(sl) == 0:
            continue
        out[i] = sl.sum() if op == "sum" else (
            sl.min() if op == "min" else sl.max()
        )
    return out


def segment_reduce(
    values: Optional[np.ndarray],
    seg_ids: np.ndarray,
    num_segments: int,
    op: str,
    *,
    impl: Optional[str] = None,
) -> np.ndarray:
    """Grouped-aggregate segment reduction: (n,) values + (n,) segment ids
    in [0, num_segments) → (num_segments,) per-segment COUNT/SUM/MIN/MAX.

    ``values`` is ignored for ``op="count"`` (pass None).  Empty segments
    hold the reduction identity (count 0, sum 0, min/max dtype extreme) —
    callers mask them via the count op.

    ``impl`` (or ``QUIP_SEGMENT_IMPL``): ``numpy`` (default; float64 host
    reductions, bit-identical to the interpreter's per-group path and the
    impl the compiled executor uses), ``ref`` (jnp/XLA segment ops), or
    ``pallas`` (TPU kernel; interpret mode elsewhere).  The device paths
    compute in int32/float32, so integer results are identical while
    within int32 range and float results may differ in final-ulp
    accumulation order — they are benchmark/TPU paths, not the
    answer-serving default.
    """
    impl = resolve_segment_impl(impl)
    if op not in _SEGMENT_OPS:
        raise ValueError(f"unknown segment op {op!r}")
    seg = np.asarray(seg_ids, dtype=np.int64)
    num_segments = int(num_segments)
    if op == "count":
        vals = np.ones(len(seg), dtype=np.int64)
        op = "sum"  # count ≡ sum of ones, on every impl
    else:
        vals = np.asarray(values)
        if vals.shape != seg.shape:
            raise ValueError(
                f"values {vals.shape} and seg_ids {seg.shape} disagree"
            )
    if num_segments == 0:
        return np.zeros(0, dtype=np.int64 if op == "count"
                        else (np.int64 if np.issubdtype(vals.dtype, np.integer)
                              else np.float64))
    if impl == "numpy" or len(seg) == 0:
        return _segment_numpy(vals, seg, num_segments, op)
    integer = np.issubdtype(vals.dtype, np.integer)
    jv = jnp.asarray(vals, dtype=jnp.int32 if integer else jnp.float32)
    js = jnp.asarray(seg, dtype=jnp.int32)
    if impl == "pallas":
        out = segment_reduce_pallas(
            jv, js, num_segments=num_segments, op=op,
            interpret=_interpret(),
        )
    else:
        out = _seg_ref_jit(jv, js, num_segments, op)
    res = np.asarray(out).astype(np.int64 if integer else np.float64)
    if op in ("min", "max"):
        # the device paths computed in int32/float32, so empty segments hold
        # the *compute*-dtype extreme; restamp the output-dtype identity so
        # every impl honours the same empty-segment contract
        empty = np.bincount(seg[seg >= 0], minlength=num_segments) == 0
        if empty.any():
            if integer:
                info = np.iinfo(np.int64)
                res[empty] = info.max if op == "min" else info.min
            else:
                res[empty] = np.inf if op == "min" else -np.inf
    return res


def _mode_codes_numpy(codes: np.ndarray, num_classes: int) -> np.ndarray:
    """Per-row bincount argmax without a Python row loop: one flat bincount
    over ``row * num_classes + code`` (the ``np.apply_along_axis``-free
    trick), then a first-maximum argmax — ties to the smallest class."""
    b, k = codes.shape
    flat = np.arange(b, dtype=np.int64)[:, None] * num_classes + codes
    counts = np.bincount(flat.ravel(), minlength=b * num_classes)
    return counts.reshape(b, num_classes).argmax(axis=1)


_AGG_BUDGET = 1 << 24  # count/one-hot entries per mode chunk (memory bound)


_mean_ref_jit = jax.jit(_ref.neighbor_mean_ref)
_mode_ref_jit = jax.jit(_ref.neighbor_mode_ref, static_argnums=(1,))


def neighbor_aggregate(
    neigh: np.ndarray,
    *,
    categorical: bool,
    impl: Optional[str] = None,
) -> np.ndarray:
    """Aggregate a (b, k) neighbour-target matrix to (b,) imputed values.

    Float attributes take the per-row mean; dictionary-coded categorical
    attributes take the per-row mode with ties broken to the smallest
    value — the exact semantics of the seed imputer's per-row
    ``np.unique``/``argmax`` loop, now one vectorized pass.

    ``impl`` (or ``QUIP_KNN_IMPL``): ``numpy`` (default; float64 mean,
    bit-identical to the seed engine on CPU), ``ref`` (jnp/XLA, float32
    mean), or ``pallas`` (TPU kernel; interpret mode elsewhere).  The mode
    path dictionary-compresses on the host (``np.unique``) so the device
    kernels see dense class codes; integer results are identical across all
    three impls, float means may differ in final-ulp accumulation order.
    """
    impl = resolve_knn_impl(impl)
    neigh = np.asarray(neigh)
    if neigh.ndim != 2:
        raise ValueError(f"neighbor_aggregate expects (b, k), got {neigh.shape}")
    if neigh.shape[0] == 0:
        return np.zeros(0, dtype=np.float64)
    if not categorical:
        if impl == "numpy":
            return neigh.astype(np.float64).mean(axis=1)
        vals = jnp.asarray(neigh, dtype=jnp.float32)
        if impl == "pallas":
            out = neighbor_mean_pallas(vals, interpret=_interpret())
        else:
            out = _mean_ref_jit(vals)
        return np.asarray(out, dtype=np.float64)
    uniq, inv = np.unique(neigh, return_inverse=True)
    codes = inv.reshape(neigh.shape).astype(np.int32)
    b, k = codes.shape
    num_classes = len(uniq)
    # row-chunk so the intermediate count matrix (numpy: b × classes;
    # ref/pallas: b × k × classes one-hot) stays within a fixed budget —
    # the reduction is per-row, so chunking is exact
    denom = num_classes if impl == "numpy" else num_classes * k
    chunk = max(1, _AGG_BUDGET // max(denom, 1))
    parts = []
    for lo in range(0, b, chunk):
        sub = codes[lo : lo + chunk]
        if impl == "numpy":
            parts.append(_mode_codes_numpy(sub, num_classes))
        elif impl == "pallas":
            parts.append(np.asarray(
                neighbor_mode_pallas(
                    jnp.asarray(sub), num_classes=num_classes,
                    interpret=_interpret(),
                )
            ))
        else:
            parts.append(np.asarray(_mode_ref_jit(jnp.asarray(sub),
                                                  num_classes)))
    idx = parts[0] if len(parts) == 1 else np.concatenate(parts)
    return uniq[idx].astype(np.float64)
