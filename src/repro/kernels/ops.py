"""Jit'd public wrappers for the Pallas kernels with implementation dispatch.

``impl``:
  * ``"ref"``     — pure-jnp oracle (fast XLA path on CPU; default here).
  * ``"pallas"``  — the Pallas kernel.  On this CPU-only container it runs in
                    interpret mode; on TPU it compiles to Mosaic.

The default is chosen per-backend: Pallas on TPU, ref on CPU (interpret-mode
Pallas is a correctness tool, not a performance path).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.bloom_probe import bloom_probe_pallas
from repro.kernels.knn_distance import masked_distance_pallas

__all__ = ["bloom_probe", "masked_distance", "masked_knn", "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bloom_probe(
    bits: jnp.ndarray,
    folded: jnp.ndarray,
    *,
    num_hashes: int,
    log2m: int,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """``folded``: uint32 host-folded keys (see ``hashing.fold64``)."""
    impl = impl or default_impl()
    if impl == "pallas":
        return bloom_probe_pallas(
            bits, folded, num_hashes=num_hashes, log2m=log2m, interpret=_interpret()
        )
    return _probe_ref_jit(bits, folded, num_hashes, log2m)


_probe_ref_jit = jax.jit(_ref.bloom_probe_ref, static_argnums=(2, 3))


def masked_distance(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    *,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    impl = impl or default_impl()
    if impl == "pallas":
        return masked_distance_pallas(q, qm, r, rm, interpret=_interpret())
    return _dist_ref_jit(q, qm, r, rm)


_dist_ref_jit = jax.jit(_ref.masked_distance_ref)


def masked_knn(
    q: jnp.ndarray,
    qm: jnp.ndarray,
    r: jnp.ndarray,
    rm: jnp.ndarray,
    k: int,
    *,
    impl: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    dmat = masked_distance(q, qm, r, rm, impl=impl)
    neg, idx = jax.lax.top_k(-dmat, k)
    return -neg, idx
