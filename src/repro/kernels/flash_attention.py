"""Pallas TPU kernel: fused flash attention (GQA, causal/windowed).

§Perf identified attention score-tensor HBM traffic as the dominant memory
term on every train/prefill cell (the pure-XLA chunked path still spills the
(q_block × k_block) probability tiles).  This kernel keeps the running
max / denominator / accumulator in VMEM across the k-block grid axis, so the
only HBM traffic is q/k/v reads and one output write — the structural fix
recorded in EXPERIMENTS.md §Roofline ("what would move the memory term").

Layout: q (B, H, S, D); k/v (B, KV, S, D); grid (B, H, NQ, NK) with the NK
axis innermost — TPU executes it sequentially per core, so the m/l planes
(extra outputs revisited at every kj) act as carried state, exactly like the
accumulator trick in ``knn_distance.py``.  Causal/window block skipping via
``pl.when``.  Validated in interpret mode against ``ref.attention_ref``
(CPU); on TPU the same BlockSpecs tile VMEM with MXU-aligned (128, D)
blocks.  (The m/l planes are (.., BQ) vectors; on real TPU they would be
padded to (BQ, 128) lanes — interpret mode does not require it.)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            bq: int, bk: int, nk: int, seq_len: int, rep: int,
            causal: bool, window: Optional[int], scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = qi * bq
    k_lo = kj * bk
    run = True
    if causal:
        run = k_lo <= q_lo + bq - 1  # block not strictly above the diagonal
    if window is not None:
        run = jnp.logical_and(run, k_lo + bk - 1 > q_lo - window)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < seq_len
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG)

        m_prev = m_ref[0, 0]  # (bq,)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=1)
        acc = o_ref[0, 0] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        o_ref[0, 0] = acc
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new

    @pl.when(kj == nk - 1)
    def _final():
        l = l_ref[0, 0]
        o_ref[0, 0] = o_ref[0, 0] / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, KV, D)
    v: jnp.ndarray,  # (B, S, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    kv = k.shape[2]
    rep = h // max(kv, 1)
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    bq = min(bq, max(s, 8))
    bk = min(bk, max(s, 8))

    qt = jnp.moveaxis(q, 2, 1)  # (B, H, S, D)
    kt = jnp.moveaxis(k, 2, 1)  # (B, KV, S, D)
    vt = jnp.moveaxis(v, 2, 1)

    pad_q = (-s) % bq
    pad_k = (-s) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq, sk = qt.shape[2], kt.shape[2]
    nq, nk = sq // bq, sk // bk

    grid = (b, h, nq, nk)
    out, _m, _l = pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, nk=nk, seq_len=s, rep=rep,
            causal=causal, window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, kj, rep=rep: (bi, hi // rep, kj, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, kj, rep=rep: (bi, hi // rep, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, kj: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, kj: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, kj: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :s, :]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, S, H, D)
