"""Pallas TPU kernel: masked partial-distance matrix for KNN imputation.

This is the imputation hot spot the paper optimizes against (KNN inference
dominates query time in Fig. 2/9/10).  The masked L2 distance decomposes into
three MXU matmuls (see ``ref.masked_distance_ref``):

    dist = (q²·qm) @ rmᵀ + qm @ (r²·rm)ᵀ − 2·(q·qm) @ (r·rm)ᵀ
    n_co = qm @ rmᵀ

so the kernel tiles (nq, nr) into MXU-aligned (BQ=128, BR=128) output blocks
with the feature dimension streamed in VMEM-resident (BK) chunks and all four
accumulations fused into a single pass (one read of q/r per tile instead of
four — 4× HBM traffic saving over composing the ref einsums).

Grid: (nq/BQ, nr/BR, d/BK); the k-loop accumulates into the output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["masked_distance_pallas"]

BQ, BR, BK = 128, 128, 128


def _kernel(q_ref, qm_ref, r_ref, rm_ref, out_ref, *, d_total: int, nk: int):
    kidx = pl.program_id(2)

    q = q_ref[...].astype(jnp.float32)
    qm = qm_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    rm = rm_ref[...].astype(jnp.float32)

    qv = q * qm
    rv = r * rm

    # Fused partial sums for this feature chunk.
    q2 = jax.lax.dot_general((qv * qv), rm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    r2 = jax.lax.dot_general(qm, (rv * rv), (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    cross = jax.lax.dot_general(qv, rv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    nco = jax.lax.dot_general(qm, rm, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)

    sq = q2 + r2 - 2.0 * cross

    @pl.when(kidx == 0)
    def _init():
        out_ref[0, ...] = sq
        out_ref[1, ...] = nco

    @pl.when(kidx > 0)
    def _acc():
        out_ref[0, ...] += sq
        out_ref[1, ...] += nco

    # Final chunk: rescale by d/n_co and mark empty overlaps unreachable.
    @pl.when(kidx == nk - 1)
    def _finalize():
        acc_sq = out_ref[0, ...]
        acc_n = out_ref[1, ...]
        scaled = jnp.where(
            acc_n > 0.0,
            jnp.maximum(acc_sq, 0.0) * (d_total / jnp.maximum(acc_n, 1.0)),
            jnp.float32(jnp.inf),
        )
        out_ref[0, ...] = scaled


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_distance_pallas(q, qm, r, rm, *, interpret: bool = True):
    """(nq, d) x (nr, d) → (nq, nr) scaled partial distances (float32)."""
    nq, d = q.shape
    nr = r.shape[0]
    q = _pad_to(q.astype(jnp.float32), BQ, 0)
    qm = _pad_to(qm.astype(jnp.float32), BQ, 0)
    r = _pad_to(r.astype(jnp.float32), BR, 0)
    rm = _pad_to(rm.astype(jnp.float32), BR, 0)
    q = _pad_to(q, BK, 1)
    qm = _pad_to(qm, BK, 1)
    r = _pad_to(r, BK, 1)
    rm = _pad_to(rm, BK, 1)
    nqp, dp = q.shape
    nrp = r.shape[0]
    nk = dp // BK

    grid = (nqp // BQ, nrp // BR, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, d_total=d, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BQ, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BQ, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BR, BK), lambda i, j, k: (j, k)),
            pl.BlockSpec((BR, BK), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((2, BQ, BR), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, nqp, nrp), jnp.float32),
        interpret=interpret,
    )(q, qm, r, rm)
    return out[0, :nq, :nr]
