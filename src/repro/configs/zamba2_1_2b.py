"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks every 6
layers [arXiv:2411.15242]."""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    hybrid_attn_period=6,
    shared_attn=True,
    activation="gelu",
))
