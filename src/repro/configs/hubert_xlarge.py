"""hubert-xlarge [audio] — encoder-only, w2v2-style backbone
[arXiv:2106.07447].  Frame frontend is a STUB (precomputed frame embeddings);
no autoregressive decode step (decode shapes skipped)."""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    activation="gelu",
    tie_embeddings=False,
))
