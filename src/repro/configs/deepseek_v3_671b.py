"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, first 3 layers
dense [arXiv:2412.19437]."""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    first_dense_layers=3,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    activation="silu",
))
