"""pixtral-12b [vlm] — pixtral-ViT frontend (stub) + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].  The modality frontend is a STUB: train /
prefill inputs are precomputed patch embeddings (B, S, d_model)."""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    activation="silu",
))
