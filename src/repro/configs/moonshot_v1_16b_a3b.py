"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 64 experts top-6 (+2 shared),
per-expert FFN 1408, first layer dense [hf:moonshotai/Moonlight-16B-A3B]."""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    activation="silu",
))
