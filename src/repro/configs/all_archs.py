"""Import every assigned architecture config (populates the registry)."""

import repro.configs.qwen2_5_3b  # noqa: F401
import repro.configs.gemma_7b  # noqa: F401
import repro.configs.qwen3_8b  # noqa: F401
import repro.configs.gemma2_27b  # noqa: F401
import repro.configs.pixtral_12b  # noqa: F401
import repro.configs.hubert_xlarge  # noqa: F401
import repro.configs.mamba2_370m  # noqa: F401
import repro.configs.moonshot_v1_16b_a3b  # noqa: F401
import repro.configs.deepseek_v3_671b  # noqa: F401
import repro.configs.zamba2_1_2b  # noqa: F401
