from repro.configs.base import (
    SHAPES,
    ArchConfig,
    ShapeConfig,
    all_archs,
    get_arch,
    runnable,
    runnable_cells,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "all_archs",
    "get_arch",
    "runnable",
    "runnable_cells",
]
