"""Architecture + shape configuration registry.

One :class:`ArchConfig` per assigned architecture (exact public configs) plus
a ``reduced()`` variant for CPU smoke tests.  :class:`ShapeConfig` describes
the assigned input shapes; ``runnable()`` encodes the skip rules recorded in
DESIGN.md §Arch-applicability (encoder-only ⇒ no decode; full-attention ⇒ no
500k context).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "register", "get_arch",
           "all_archs", "runnable_cells"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None  # final-logit softcap (gemma2)
    attn_softcap: Optional[float] = None  # attention-logit softcap (gemma2)
    local_window: Optional[int] = None  # sliding-window size
    layer_pattern: Tuple[str, ...] = ("global",)  # cycled over layers
    encoder_only: bool = False

    # mlp
    activation: str = "silu"  # silu | geglu | gelu

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # deepseek: leading dense layers

    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    hybrid_attn_period: int = 0  # zamba2: attention block every k layers
    shared_attn: bool = False  # zamba2: one weight-shared attn+MLP block

    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # attention implementation: "chunked" = flash-style online-softmax
    # blocks (production default); "naive" = materialized S² scores (the
    # §Perf baseline the hillclimb starts from).
    attn_impl: str = "chunked"
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    attn_pv_bf16: bool = False  # §Perf: bf16 P·V matmul (f32 accumulate)
    # MoE dispatch: "einsum" = GShard-style one-hot dispatch/combine
    # (baseline); "scatter" = index scatter/gather dispatch (§Perf
    # optimization — no (G,S,E,C) one-hot materialization, no fake FLOPs).
    moe_impl: str = "einsum"
    # §Perf: bf16 dispatch/combine one-hots (exact for 0/1 masks; gates
    # rounded to bf16 in combine)
    moe_bf16_dispatch: bool = False

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k contexts (SSM / hybrid-with-O(1)-state decode)."""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        """'attn' | 'local' | 'ssm' per layer index."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            p = max(self.hybrid_attn_period, 1)
            return "attn" if (i % p == p - 1) else "ssm"
        return (
            "local"
            if self.layer_pattern[i % len(self.layer_pattern)] == "local"
            else "attn"
        )

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        counted_shared = False
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind != "ssm" and self.shared_attn:
                if counted_shared:
                    continue  # weight-shared block counted once
                counted_shared = True
            if kind == "ssm":
                # matches models/mamba.py: single B/C group, conv over x only
                d_in = self.ssm_heads * self.ssm_head_dim
                conv = 4 * d_in
                total += d * (2 * d_in + 2 * self.ssm_state
                              + self.ssm_heads) + conv + d_in * d
            else:
                if self.mla:
                    total += d * self.q_lora_rank
                    total += self.q_lora_rank * self.n_heads * (
                        self.nope_head_dim + self.rope_head_dim
                    )
                    total += d * (self.kv_lora_rank + self.rope_head_dim)
                    total += self.kv_lora_rank * self.n_heads * (
                        self.nope_head_dim + self.v_head_dim
                    )
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * hd  # q
                    total += 2 * d * self.n_kv_heads * hd  # kv
                    total += self.n_heads * hd * d  # o
            # mlp / moe (ssm blocks are the whole mixer — no separate MLP)
            if kind == "ssm":
                continue
            gated = 3 if self.activation in ("silu", "geglu") else 2
            if self.is_moe and i >= self.first_dense_layers:
                total += self.n_experts * gated * d * ff
                total += self.n_shared_experts * gated * d * ff
                total += d * self.n_experts  # router
            else:
                dense_ff = ff if not self.is_moe else ff * max(
                    self.top_k + self.n_shared_experts, 1
                )
                total += gated * d * dense_ff
        return total

    def active_params(self) -> int:
        """Per-token active parameters (MoE: top-k + shared only)."""
        if not self.is_moe:
            return self.num_params()
        d, ff = self.d_model, self.d_ff
        gated = 3 if self.activation in ("silu", "geglu") else 2
        dense = self.num_params() - sum(
            self.n_experts * gated * d * ff
            for i in range(self.first_dense_layers, self.n_layers)
        ) // 1  # remove full expert banks
        moe_layers = self.n_layers - self.first_dense_layers
        dense = self.num_params() - moe_layers * self.n_experts * gated * d * ff
        return dense + moe_layers * self.top_k * gated * d * ff

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=2 if self.hybrid_attn_period <= 2 else self.hybrid_attn_period,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            q_lora_rank=min(self.q_lora_rank, 32) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 16) if self.kv_lora_rank else 0,
            rope_head_dim=8 if self.mla else self.rope_head_dim,
            nope_head_dim=8 if self.mla else self.nope_head_dim,
            v_head_dim=16 if self.mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_head_dim=16 if self.ssm_heads else self.ssm_head_dim,
            ssm_chunk=16,
            local_window=min(self.local_window, 32) if self.local_window else None,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 64), global_batch=min(self.global_batch, 2)
        )


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs.all_archs  # noqa: F401  (populate registry)

    return _REGISTRY[name]


def all_archs() -> List[str]:
    import repro.configs.all_archs  # noqa: F401

    return sorted(_REGISTRY)


def runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full attention: 500k context requires sub-quadratic arch"
    return True, ""


def runnable_cells() -> List[Tuple[str, str]]:
    cells = []
    for a in all_archs():
        cfg = get_arch(a)
        for s in SHAPES.values():
            ok, _ = runnable(cfg, s)
            if ok:
                cells.append((a, s.name))
    return cells
