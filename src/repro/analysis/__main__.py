"""``python -m repro.analysis`` — run quiplint over the repository.

Exit status: 0 when the tree is clean, 1 when any pass found a violation
(the CI quiplint job gates on this).  ``--write-env-docs`` regenerates
the ``ENV_REGISTRY`` knob table in docs/analysis.md in place.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.analysis import lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="quiplint: invariant lint passes over the QUIP tree",
    )
    ap.add_argument("--root", default=None,
                    help="repository root (default: inferred from the "
                         "installed package location)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON instead of text")
    ap.add_argument("--write-env-docs", action="store_true",
                    help="regenerate the ENV_REGISTRY table in "
                         "docs/analysis.md and exit")
    args = ap.parse_args(argv)
    root = args.root or lint.find_repo_root()
    if args.write_env_docs:
        changed = lint.write_env_docs(root)
        print("docs/analysis.md: table "
              + ("rewritten" if changed else "already in sync"))
        return 0
    findings = lint.lint_repo(root)
    if args.json:
        print(json.dumps([dataclasses.asdict(f) for f in findings],
                         indent=1))
    else:
        for f in findings:
            print(f)
        print(f"quiplint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
