"""quiplint: AST invariant passes over the QUIP tree (docs/analysis.md).

The serving stack's correctness rests on conventions no type checker sees:
every ``QUIP_*`` env read goes through ``core.env``, every counter bump
names a real :class:`~repro.core.stats.ExecutionCounters` field, every
mutation of a ``# guarded-by:`` attribute happens under its lock, tracer
``begin``/``end`` spans pair up, and every public kernel op carries the
numpy/ref/pallas triple behind an env knob.  This module turns each
convention into a lint pass so drift fails CI instead of fuzz runs.

Run ``python -m repro.analysis`` (exit nonzero on findings).  Passes
operate on a ``{relpath: source}`` mapping (``relpath`` relative to
``src/repro``) so tests can feed synthetic fixtures;
:func:`lint_repo` additionally checks the generated ``ENV_REGISTRY``
table in docs/analysis.md and that every registered knob is exercised
somewhere in ``src/`` or ``tests/``.

Annotation grammar (see docs/analysis.md for the full catalog):

* ``# guarded-by: A|B`` — trailing comment on a ``self.X = ...``
  declaration in ``__init__``: every non-``__init__`` mutation of ``X``
  must run inside ``with <A or B>`` (terminal name of the with-item).
* ``# requires: A|B`` — on (or directly above) a ``def`` line: the method
  is a documented must-hold-caller contract; its body is checked as if
  A and B were held.
* ``# unguarded: <reason>`` — trailing waiver on one mutation line.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.core.env import ENV_REGISTRY

__all__ = [
    "Finding",
    "PASSES",
    "counters_pass",
    "docs_pass",
    "env_pass",
    "env_registry_table",
    "find_repo_root",
    "lint_repo",
    "lint_sources",
    "locks_pass",
    "parity_pass",
    "render_env_docs",
    "spans_pass",
    "usage_pass",
    "write_env_docs",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation: ``path:line: [pass] message``."""

    path: str
    line: int
    pass_name: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_name}] {self.message}"


# --------------------------------------------------------------------------- #
# shared helpers
# --------------------------------------------------------------------------- #
_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_][A-Za-z0-9_|]*)")
_REQUIRES_RE = re.compile(r"requires:\s*([A-Za-z_][A-Za-z0-9_|]*)")
_UNGUARDED_RE = re.compile(r"unguarded:")
_QUIP_RE = re.compile(r"^QUIP_[A-Z0-9_]+$")

#: method names that mutate their receiver in place (the lock pass treats
#: ``self.attr.<mutator>(...)`` as a mutation of ``attr``)
MUTATORS = frozenset({
    "add", "append", "appendleft", "clear", "discard", "extend",
    "extendleft", "insert", "pop", "popitem", "popleft", "remove",
    "setdefault", "update",
})


def _comments_by_line(src: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(src).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass  # the AST pass reports the syntax error with a location
    return out


def _parse(path: str, src: str, pass_name: str,
           findings: List[Finding]) -> Optional[ast.Module]:
    try:
        return ast.parse(src)
    except SyntaxError as e:
        findings.append(Finding(path, e.lineno or 1, pass_name,
                                f"syntax error: {e.msg}"))
        return None


def _self_root_attr(node: ast.AST) -> Optional[str]:
    """First attribute hanging off ``self`` under any Subscript/Attribute
    chain: ``self.counters.imputations`` → ``counters``;
    ``self._owner[k][t]`` → ``_owner``; plain locals → None."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        node = node.value
    return None


def _terminal_name(expr: ast.AST) -> Optional[str]:
    """Terminal name of a with-item / receiver: strip one Call, then the
    final attribute — ``self.store.flush_lock(t, a)`` → ``flush_lock``."""
    if isinstance(expr, ast.Call):
        expr = expr.func
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _flat_targets(targets: Sequence[ast.AST]) -> List[ast.AST]:
    out: List[ast.AST] = []
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            out.extend(_flat_targets(t.elts))
        else:
            out.append(t)
    return out


def _parent_map(tree: ast.AST) -> Dict[int, ast.AST]:
    return {id(child): parent
            for parent in ast.walk(tree)
            for child in ast.iter_child_nodes(parent)}


def _const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


# --------------------------------------------------------------------------- #
# pass 1: env-discipline
# --------------------------------------------------------------------------- #
#: files allowed to touch os.environ for QUIP_* keys (the parsers)
ENV_PARSER_FILES = frozenset({"core/env.py"})
#: files allowed to *mutate* os.environ (import-time XLA host-device flag)
ENV_MUTATION_FILES = frozenset({"core/env.py", "launch/dryrun.py",
                                "launch/hillclimb.py"})
_ENV_PARSERS = frozenset({"env_flag", "env_choice", "env_int"})
_ENVIRON_MUTATORS = frozenset({"setdefault", "pop", "update", "clear"})


def _is_os_environ(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "environ"
            and isinstance(node.value, ast.Name) and node.value.id == "os")


def env_pass(sources: Dict[str, str]) -> List[Finding]:
    """``QUIP_*`` env reads only via ``core.env``; ``os.environ`` mutation
    only in the whitelisted import-time launch files; every knob literal
    registered in ``ENV_REGISTRY``."""
    findings: List[Finding] = []
    for path, src in sorted(sources.items()):
        tree = _parse(path, src, "env-discipline", findings)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Subscript) and _is_os_environ(node.value):
                key = _const_str(node.slice)
                if (isinstance(node.ctx, (ast.Store, ast.Del))
                        and path not in ENV_MUTATION_FILES):
                    findings.append(Finding(
                        path, node.lineno, "env-discipline",
                        f"os.environ mutation of {key or '<dynamic>'!s} "
                        f"outside the whitelisted launch files",
                    ))
                elif (isinstance(node.ctx, ast.Load) and key
                        and key.startswith("QUIP_")
                        and path not in ENV_PARSER_FILES):
                    findings.append(Finding(
                        path, node.lineno, "env-discipline",
                        f"direct os.environ read of {key} — use the "
                        f"core.env parsers (env_flag/env_choice/env_int)",
                    ))
            elif isinstance(node, ast.Call):
                fname = _terminal_name(node.func)
                recv_env = (isinstance(node.func, ast.Attribute)
                            and _is_os_environ(node.func.value))
                args0 = _const_str(node.args[0]) if node.args else None
                if recv_env and fname in _ENVIRON_MUTATORS | {"get"}:
                    if (fname != "get" and path not in ENV_MUTATION_FILES):
                        findings.append(Finding(
                            path, node.lineno, "env-discipline",
                            f"os.environ.{fname}() outside the whitelisted "
                            f"launch files",
                        ))
                    elif (fname == "get" and args0
                          and args0.startswith("QUIP_")
                          and path not in ENV_PARSER_FILES):
                        findings.append(Finding(
                            path, node.lineno, "env-discipline",
                            f"direct os.environ.get of {args0} — use the "
                            f"core.env parsers",
                        ))
                elif (fname == "getenv" and args0
                      and args0.startswith("QUIP_")
                      and path not in ENV_PARSER_FILES):
                    findings.append(Finding(
                        path, node.lineno, "env-discipline",
                        f"os.getenv of {args0} — use the core.env parsers",
                    ))
                elif fname in _ENV_PARSERS and args0 is not None:
                    if args0 not in ENV_REGISTRY:
                        findings.append(Finding(
                            path, node.lineno, "env-discipline",
                            f"env knob {args0} is not in ENV_REGISTRY "
                            f"(core/env.py)",
                        ))
            elif isinstance(node, ast.Constant):
                val = node.value
                if (isinstance(val, str) and _QUIP_RE.fullmatch(val)
                        and val not in ENV_REGISTRY):
                    findings.append(Finding(
                        path, node.lineno, "env-discipline",
                        f"QUIP_* literal {val} is not a registered knob",
                    ))
    return findings


# --------------------------------------------------------------------------- #
# pass 2: counter-discipline
# --------------------------------------------------------------------------- #
def _counter_fields() -> Set[str]:
    from repro.core.stats import ExecutionCounters
    return {f.name for f in dataclasses.fields(ExecutionCounters)}


def _attr_chain(node: ast.AST) -> List[str]:
    """``self.counters.imputations`` → ["self", "counters", "imputations"]
    (subscripts transparent; non-name roots contribute nothing)."""
    parts: List[str] = []
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return list(reversed(parts))


def counters_pass(sources: Dict[str, str]) -> List[Finding]:
    """Every ``counters.<field> += ...`` names a real ExecutionCounters
    field, and ``imputations`` only increments in a function that also
    calls ``provenance.on_flush`` — the reconciliation invariant the
    explain report is built on."""
    fields = _counter_fields()
    findings: List[Finding] = []
    for path, src in sorted(sources.items()):
        tree = _parse(path, src, "counter-discipline", findings)
        if tree is None:
            continue
        parents = _parent_map(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.AugAssign):
                continue
            if not isinstance(node.target, ast.Attribute):
                continue
            chain = _attr_chain(node.target)
            if "counters" not in chain[:-1]:
                continue
            field = node.target.attr
            if field not in fields:
                findings.append(Finding(
                    path, node.lineno, "counter-discipline",
                    f"counters.{field} is not an ExecutionCounters field",
                ))
                continue
            if field == "imputations":
                fn = parents.get(id(node))
                while fn is not None and not isinstance(
                        fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    fn = parents.get(id(fn))
                mirrored = fn is not None and any(
                    isinstance(c, ast.Call)
                    and _terminal_name(c.func) == "on_flush"
                    for c in ast.walk(fn)
                )
                if not mirrored:
                    findings.append(Finding(
                        path, node.lineno, "counter-discipline",
                        "counters.imputations increments without a "
                        "provenance.on_flush mirror in the same function",
                    ))
    return findings


# --------------------------------------------------------------------------- #
# pass 3: lock-discipline
# --------------------------------------------------------------------------- #
def _requires_for(fn: ast.FunctionDef, comments: Dict[int, str]) -> Set[str]:
    first_body = fn.body[0].lineno if fn.body else fn.lineno + 1
    req: Set[str] = set()
    for ln in range(fn.lineno - 1, first_body):
        m = _REQUIRES_RE.search(comments.get(ln, ""))
        if m:
            req |= set(m.group(1).split("|"))
    return req


def _guards_for(cls: ast.ClassDef, comments: Dict[int, str]
                ) -> Dict[str, Set[str]]:
    guards: Dict[str, Set[str]] = {}
    init = next((f for f in cls.body
                 if isinstance(f, ast.FunctionDef) and f.name == "__init__"),
                None)
    if init is None:
        return guards
    for node in ast.walk(init):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        m = _GUARDED_RE.search(comments.get(node.lineno, ""))
        if not m:
            continue
        alts = set(m.group(1).split("|"))
        for t in _flat_targets(targets):
            attr = _self_root_attr(t)
            if attr is not None:
                guards[attr] = alts
    return guards


def _scan_locked(node: ast.AST, held: Set[str], guards: Dict[str, Set[str]],
                 comments: Dict[int, str], path: str,
                 findings: List[Finding]) -> None:
    if isinstance(node, ast.With):
        names = {n for n in (_terminal_name(i.context_expr)
                             for i in node.items) if n}
        for item in node.items:
            _scan_locked(item, held, guards, comments, path, findings)
        inner = held | names
        for stmt in node.body:
            _scan_locked(stmt, inner, guards, comments, path, findings)
        return

    def flag(attr: str, lineno: int) -> None:
        if held & guards[attr]:
            return
        if _UNGUARDED_RE.search(comments.get(lineno, "")):
            return
        want = "|".join(sorted(guards[attr]))
        findings.append(Finding(
            path, lineno, "lock-discipline",
            f"mutation of {attr} (guarded-by: {want}) outside its lock "
            f"(held: {sorted(held) or 'none'}); wrap in `with`, add a "
            f"`# requires:` contract, or waive with `# unguarded: <why>`",
        ))

    if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in _flat_targets(targets):
            attr = _self_root_attr(t)
            if attr in guards:
                flag(attr, node.lineno)
    elif isinstance(node, ast.Delete):
        for t in _flat_targets(node.targets):
            attr = _self_root_attr(t)
            if attr in guards:
                flag(attr, node.lineno)
    elif isinstance(node, ast.Call):
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in MUTATORS):
            attr = _self_root_attr(node.func.value)
            if attr in guards:
                flag(attr, node.lineno)
    for child in ast.iter_child_nodes(node):
        _scan_locked(child, held, guards, comments, path, findings)


def locks_pass(sources: Dict[str, str]) -> List[Finding]:
    """Every mutation of a ``# guarded-by:`` attribute runs under one of
    its locks (lexically: a ``with`` whose item's terminal name matches),
    under a ``# requires:`` method contract, or carries an explicit
    ``# unguarded:`` waiver.  ``__init__`` (construction) is exempt."""
    findings: List[Finding] = []
    for path, src in sorted(sources.items()):
        tree = _parse(path, src, "lock-discipline", findings)
        if tree is None:
            continue
        comments = _comments_by_line(src)
        for cls in [n for n in ast.walk(tree)
                    if isinstance(n, ast.ClassDef)]:
            guards = _guards_for(cls, comments)
            if not guards:
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name == "__init__":
                    continue
                held = _requires_for(fn, comments)
                for stmt in fn.body:
                    _scan_locked(stmt, held, guards, comments, path,
                                 findings)
    return findings


# --------------------------------------------------------------------------- #
# pass 4: span-discipline
# --------------------------------------------------------------------------- #
def _tracerish(expr: ast.AST) -> bool:
    name = _terminal_name(expr)
    return name is not None and name.lower().endswith("tracer")


def _with_names(fn: ast.AST) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                if isinstance(item.context_expr, ast.Name):
                    names.add(item.context_expr.id)
    return names


def spans_pass(sources: Dict[str, str]) -> List[Finding]:
    """Tracer spans close: every ``tracer.span(...)`` is used as a context
    manager (directly, or assigned to a name later entered with ``with``);
    ``tracer.begin(...)`` results are consumed (an unpaired begin leaks an
    open span) and a module that begins spans also ends them."""
    findings: List[Finding] = []
    for path, src in sorted(sources.items()):
        tree = _parse(path, src, "span-discipline", findings)
        if tree is None:
            continue
        parents = _parent_map(tree)
        has_begin: Optional[ast.Call] = None
        has_end = False
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if not isinstance(node.func, ast.Attribute):
                continue
            if not _tracerish(node.func.value):
                continue
            meth = node.func.attr
            if meth == "end":
                has_end = True
            elif meth == "begin":
                if has_begin is None:
                    has_begin = node
                parent = parents.get(id(node))
                if isinstance(parent, ast.Expr):
                    findings.append(Finding(
                        path, node.lineno, "span-discipline",
                        "tracer.begin() result discarded — no id to "
                        "tracer.end() with; the span never closes",
                    ))
            elif meth == "span":
                cur: Optional[ast.AST] = node
                ok = False
                fn: Optional[ast.AST] = None
                while cur is not None:
                    parent = parents.get(id(cur))
                    if isinstance(parent, ast.withitem):
                        ok = True
                        break
                    if isinstance(parent, (ast.FunctionDef,
                                           ast.AsyncFunctionDef,
                                           ast.Module)):
                        fn = parent
                        break
                    if isinstance(parent, (ast.Assign, ast.AnnAssign)):
                        # find the function, then check the assigned name
                        # is entered via `with` somewhere in it
                        targets = (parent.targets
                                   if isinstance(parent, ast.Assign)
                                   else [parent.target])
                        names = {t.id for t in _flat_targets(targets)
                                 if isinstance(t, ast.Name)}
                        scope: Optional[ast.AST] = parent
                        while scope is not None and not isinstance(
                                scope, (ast.FunctionDef,
                                        ast.AsyncFunctionDef, ast.Module)):
                            scope = parents.get(id(scope))
                        if scope is not None and names & _with_names(scope):
                            ok = True
                        break
                    if isinstance(parent, ast.Return):
                        ok = True  # caller owns the context entry
                        break
                    cur = parent
                if not ok:
                    findings.append(Finding(
                        path, node.lineno, "span-discipline",
                        "tracer.span(...) not entered as a context "
                        "manager — the span would never close",
                    ))
        if has_begin is not None and not has_end:
            findings.append(Finding(
                path, has_begin.lineno, "span-discipline",
                "module calls tracer.begin() but never tracer.end()",
            ))
    return findings


# --------------------------------------------------------------------------- #
# pass 5: kernel-triple parity
# --------------------------------------------------------------------------- #
def _is_resolver(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and _terminal_name(node.func) == "env_choice"
                and node.args):
            knob = _const_str(node.args[0])
            if knob is not None and knob.startswith("QUIP_"):
                return True
    return False


def parity_pass(sources: Dict[str, str]) -> List[Finding]:
    """Every public op in ``kernels/ops.py`` (``__all__``) resolves its
    ``impl`` through an env-knobbed ``resolve_*`` (and then carries both a
    ``"numpy"`` and a ``"pallas"`` path) or forwards ``impl=impl`` to a
    public op that does."""
    findings: List[Finding] = []
    for path, src in sorted(sources.items()):
        if not path.endswith("kernels/ops.py"):
            continue
        tree = _parse(path, src, "kernel-parity", findings)
        if tree is None:
            continue
        exported: Set[str] = set()
        for node in tree.body:
            if (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name) and t.id == "__all__"
                            for t in node.targets)
                    and isinstance(node.value, (ast.List, ast.Tuple))):
                exported = {s for s in (
                    _const_str(e) for e in node.value.elts) if s}
        fns = {f.name: f for f in tree.body
               if isinstance(f, ast.FunctionDef)}
        resolvers = {name for name, f in fns.items() if _is_resolver(f)}
        for name in sorted(exported):
            fn = fns.get(name)
            if fn is None or name in resolvers:
                continue
            all_args = fn.args.args + fn.args.kwonlyargs
            if not any(a.arg == "impl" for a in all_args):
                continue  # impl-less exports (e.g. default_impl) are free
            calls_resolver = any(
                isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id in resolvers
                for n in ast.walk(fn)
            )
            forwards = any(
                isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id in exported and n.func.id != name
                and any(kw.arg == "impl"
                        and isinstance(kw.value, ast.Name)
                        and kw.value.id == "impl"
                        for kw in n.keywords)
                for n in ast.walk(fn)
            )
            if not calls_resolver and not forwards:
                findings.append(Finding(
                    path, fn.lineno, "kernel-parity",
                    f"op {name} neither resolves impl via an env-knobbed "
                    f"resolve_* nor forwards impl= to a public op",
                ))
                continue
            if calls_resolver:
                consts = {n.value for n in ast.walk(fn)
                          if isinstance(n, ast.Constant)
                          and isinstance(n.value, str)}
                for required in ("numpy", "pallas"):
                    if required not in consts:
                        findings.append(Finding(
                            path, fn.lineno, "kernel-parity",
                            f"op {name} has no {required!r} path — the "
                            f"numpy/ref/pallas triple is incomplete",
                        ))
    return findings


# --------------------------------------------------------------------------- #
# repo-level passes: docs sync + registry usage
# --------------------------------------------------------------------------- #
DOCS_BEGIN = "<!-- ENV_REGISTRY:begin -->"
DOCS_END = "<!-- ENV_REGISTRY:end -->"
DOCS_FILE = os.path.join("docs", "analysis.md")


def env_registry_table() -> str:
    """The knob table generated from ``ENV_REGISTRY`` — the docs between
    the markers in docs/analysis.md must equal this exactly."""
    lines = [
        "| knob | kind | default | owner | doc |",
        "| --- | --- | --- | --- | --- |",
    ]
    for name in sorted(ENV_REGISTRY):
        k = ENV_REGISTRY[name]
        kind = k.kind
        if k.choices:
            kind += " (" + " \\| ".join(k.choices) + ")"
        lines.append(
            f"| `{name}` | {kind} | {k.default} | {k.owner} | {k.doc} |"
        )
    return "\n".join(lines)


def render_env_docs(text: str) -> Optional[str]:
    """``text`` with the generated table spliced between the markers;
    None when a marker is missing."""
    try:
        head, rest = text.split(DOCS_BEGIN, 1)
        _stale, tail = rest.split(DOCS_END, 1)
    except ValueError:
        return None
    return head + DOCS_BEGIN + "\n" + env_registry_table() + "\n" \
        + DOCS_END + tail


def docs_pass(root: str) -> List[Finding]:
    path = os.path.join(root, DOCS_FILE)
    if not os.path.exists(path):
        return [Finding(DOCS_FILE, 1, "docs-sync",
                        "docs/analysis.md is missing")]
    with open(path) as fh:
        text = fh.read()
    rendered = render_env_docs(text)
    if rendered is None:
        return [Finding(DOCS_FILE, 1, "docs-sync",
                        f"missing {DOCS_BEGIN} / {DOCS_END} markers")]
    if rendered != text:
        line = text[:text.index(DOCS_BEGIN)].count("\n") + 1
        return [Finding(DOCS_FILE, line, "docs-sync",
                        "ENV_REGISTRY table is stale — run "
                        "`python -m repro.analysis --write-env-docs`")]
    return []


def write_env_docs(root: str) -> bool:
    """Rewrite the generated table in docs/analysis.md; True if changed."""
    path = os.path.join(root, DOCS_FILE)
    with open(path) as fh:
        text = fh.read()
    rendered = render_env_docs(text)
    if rendered is None:
        raise RuntimeError(f"{DOCS_FILE} lacks the ENV_REGISTRY markers")
    if rendered == text:
        return False
    with open(path, "w") as fh:
        fh.write(rendered)
    return True


def usage_pass(root: str, sources: Dict[str, str]) -> List[Finding]:
    """Every registered knob appears as a literal somewhere in src/ or
    tests/ — an unused registry entry is doc rot waiting to mislead."""
    # the registry entry itself (core/env.py) doesn't count as usage
    corpora = [src for path, src in sources.items() if path != "core/env.py"]
    tests_dir = os.path.join(root, "tests")
    if os.path.isdir(tests_dir):
        for name in sorted(os.listdir(tests_dir)):
            if name.endswith(".py"):
                with open(os.path.join(tests_dir, name)) as fh:
                    corpora.append(fh.read())
    env_src = sources.get("core/env.py", "")
    findings: List[Finding] = []
    for knob in sorted(ENV_REGISTRY):
        quoted = f'"{knob}"'
        if not any(quoted in text for text in corpora):
            line = next(
                (i + 1 for i, ln in enumerate(env_src.splitlines())
                 if quoted in ln), 1,
            )
            findings.append(Finding(
                "core/env.py", line, "registry-usage",
                f"registered knob {knob} is never read in src/ or tests/",
            ))
    return findings


# --------------------------------------------------------------------------- #
# drivers
# --------------------------------------------------------------------------- #
#: the source-level passes, by name (tests index this)
PASSES: Dict[str, Callable[[Dict[str, str]], List[Finding]]] = {
    "env-discipline": env_pass,
    "counter-discipline": counters_pass,
    "lock-discipline": locks_pass,
    "span-discipline": spans_pass,
    "kernel-parity": parity_pass,
}


def lint_sources(sources: Dict[str, str]) -> List[Finding]:
    """Run every source-level pass over ``{relpath: source}``."""
    findings: List[Finding] = []
    for fn in PASSES.values():
        findings.extend(fn(sources))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_name))
    return findings


def find_repo_root() -> str:
    """<root>/src/repro/analysis/lint.py → <root>."""
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def load_sources(root: str) -> Dict[str, str]:
    """All of ``src/repro`` as ``{relpath-from-src/repro: source}``."""
    pkg = os.path.join(root, "src", "repro")
    out: Dict[str, str] = {}
    for dirpath, dirnames, filenames in os.walk(pkg):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            full = os.path.join(dirpath, name)
            rel = os.path.relpath(full, pkg).replace(os.sep, "/")
            with open(full) as fh:
                out[rel] = fh.read()
    return out


def lint_repo(root: Optional[str] = None) -> List[Finding]:
    """The full quiplint run: source passes over ``src/repro`` plus the
    docs-sync and registry-usage repo passes."""
    root = root or find_repo_root()
    sources = load_sources(root)
    findings = lint_sources(sources)
    findings.extend(docs_pass(root))
    findings.extend(usage_pass(root, sources))
    return findings
