"""Runtime lock-order sanitizer for the threaded serving stack.

Every lock in the serving/imputation/observability layers is created
through :func:`make_lock` / :func:`make_rlock` / :func:`make_condition`
with a stable name ("QuipService._lock", "ImputeStore.key", ...).  With
``QUIP_SANITIZE`` unset (or ``off``) the factories return plain
``threading`` primitives — zero overhead, byte-identical behaviour.
Under ``QUIP_SANITIZE=locks`` they return instrumented wrappers that
record, into one process-global :class:`LockOrderGraph`:

* **acquisition-order edges** — whenever a thread acquires lock B while
  holding lock A, the edge A→B is recorded with the acquiring stack the
  first time it is seen.  A cycle in this graph (A→B somewhere, B→A
  somewhere else) is a *potential deadlock* even if the fuzzer's
  interleavings never tripped it — that is the whole point: the graph
  turns "we happened not to deadlock" into "no acquisition-order cycle
  exists over everything the tests executed";
* **potential-deadlock reports** — detected online: the acquire that
  closes a cycle records the full cycle with the first-observed stack of
  every edge on it (both sides of an AB/BA inversion included);
* **contention telemetry** — per lock: acquisitions, contended acquires
  (the uncontended fast path is a single try-lock), and
  *held-while-blocking* events (blocking on this lock while holding at
  least one other — the shape every real deadlock is made of).

The wrappers implement the private ``threading.Condition`` protocol
(``_release_save`` / ``_acquire_restore`` / ``_is_owned``), so
``make_condition(sanitized_rlock)`` waits and notifies exactly like a
plain Condition while the held-set bookkeeping stays accurate across
``wait()``'s release/reacquire.

Tests drive this via the autouse fixtures in ``tests/test_workers.py`` /
``tests/test_serving_fuzz.py`` (fast profiles) and CI runs the serving
fuzz smoke under ``QUIP_SANITIZE=locks``; :func:`assert_acyclic` writes
the JSON report to ``benchmarks/artifacts/lock_sanitizer_report.json``
on failure (uploaded as a CI artifact).  See docs/analysis.md.
"""

from __future__ import annotations

import json
import os
import threading
import traceback
from typing import Dict, List, Optional, Tuple

from repro.core.env import env_choice

__all__ = [
    "SANITIZE_MODES",
    "LockOrderGraph",
    "assert_acyclic",
    "graph",
    "make_condition",
    "make_lock",
    "make_rlock",
    "report",
    "reset",
    "resolve_sanitize",
]

SANITIZE_MODES = ("off", "locks")

#: default artifact path for assert_acyclic failures (CI uploads it)
REPORT_PATH = os.path.join("benchmarks", "artifacts",
                           "lock_sanitizer_report.json")

_STACK_LIMIT = 16  # frames captured per first-observed edge


def resolve_sanitize() -> str:
    """``QUIP_SANITIZE`` (``off`` | ``locks``, via :func:`env_choice`;
    garbage raises) — read at lock *construction* time, so a service built
    under the sanitizer stays sanitized for its lifetime."""
    return env_choice("QUIP_SANITIZE", SANITIZE_MODES, "off")


class LockOrderGraph:
    """Process-global acquisition-order graph + contention telemetry.

    Nodes are lock *names* (several instances may share one — e.g. every
    per-(table, attr) flush lock is "ImputeStore.key"), edges are
    first-observed held→acquired pairs with captured stacks.  All methods
    are called from the lock wrappers; the graph's own mutex is a raw
    ``threading.Lock`` (never wrapped — it must not observe itself)."""

    def __init__(self):
        self._mu = threading.Lock()
        self._tls = threading.local()
        # (src, dst) -> {count, thread, stack (first observation)}
        self._edges: Dict[Tuple[str, str], Dict] = {}
        # name -> {acquisitions, contended, held_while_blocking}
        self._nodes: Dict[str, Dict] = {}
        self._deadlocks: List[Dict] = []

    # -- per-thread held set ----------------------------------------------#
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _node(self, name: str) -> Dict:
        node = self._nodes.get(name)
        if node is None:
            node = self._nodes[name] = {
                "acquisitions": 0, "contended": 0, "held_while_blocking": 0,
            }
        return node

    # -- wrapper hooks -----------------------------------------------------#
    def note_blocking(self, name: str) -> None:
        """About to block on ``name`` (the try-lock fast path failed)."""
        holding = len(self._held()) > 0
        with self._mu:
            node = self._node(name)
            node["contended"] += 1
            if holding:
                node["held_while_blocking"] += 1

    def note_acquired(self, name: str, contended: bool = False) -> None:
        """``name`` acquired by this thread; record held→name edges."""
        held = self._held()
        stack: Optional[List[str]] = None
        with self._mu:
            node = self._node(name)
            node["acquisitions"] += 1
            # (contended acquires were counted in note_blocking, pre-block)
            for src in dict.fromkeys(held):  # unique, insertion order
                if src == name:
                    continue  # same-name instances (key locks) — no edge
                key = (src, name)
                edge = self._edges.get(key)
                if edge is not None:
                    edge["count"] += 1
                    continue
                if stack is None:
                    stack = traceback.format_stack(limit=_STACK_LIMIT)[:-1]
                self._edges[key] = {
                    "src": src, "dst": name, "count": 1,
                    "thread": threading.current_thread().name,
                    "stack": stack,
                }
                cycle = self._path(name, src)
                if cycle is not None:
                    # path name→…→src already existed; this new src→name
                    # edge closes it.  Keep every on-cycle edge's
                    # first-observed stack (both sides of an AB/BA
                    # inversion included).
                    edge_keys = [(cycle[i], cycle[i + 1])
                                 for i in range(len(cycle) - 1)]
                    edge_keys.append(key)
                    self._deadlocks.append({
                        "cycle": cycle + [name],
                        "edges": [dict(self._edges[k]) for k in edge_keys
                                  if k in self._edges],
                    })
        held.append(name)

    def note_released(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    # -- graph queries -----------------------------------------------------#
    def _path(self, src: str, dst: str) -> Optional[List[str]]:
        """Node path src→…→dst over recorded edges (call under _mu);
        None if unreachable."""
        if src == dst:
            return [src]
        adj: Dict[str, List[str]] = {}
        for (a, b) in self._edges:
            adj.setdefault(a, []).append(b)
        prev: Dict[str, str] = {}
        frontier = [src]
        seen = {src}
        while frontier:
            nxt = []
            for node in frontier:
                for child in adj.get(node, ()):
                    if child in seen:
                        continue
                    seen.add(child)
                    prev[child] = node
                    if child == dst:
                        path = [dst]
                        while path[-1] != src:
                            path.append(prev[path[-1]])
                        return list(reversed(path))
                    nxt.append(child)
            frontier = nxt
        return None

    def cycles(self) -> List[List[str]]:
        """Every recorded edge that closes a cycle, as the node cycle it
        closes (deduplicated by node set)."""
        out: List[List[str]] = []
        seen_sets = set()
        with self._mu:
            for (a, b) in list(self._edges):
                path = self._path(b, a)
                if path is None:
                    continue
                cyc = path + [b]
                key = frozenset(cyc)
                if key not in seen_sets:
                    seen_sets.add(key)
                    out.append(cyc)
        return out

    def report(self) -> Dict:
        cycles = self.cycles()
        with self._mu:
            return {
                "mode": "locks",
                "locks": {k: dict(v) for k, v in sorted(self._nodes.items())},
                "edges": [dict(e) for e in self._edges.values()],
                "cycles": cycles,
                "potential_deadlocks": [dict(d) for d in self._deadlocks],
            }

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self._nodes.clear()
            self._deadlocks.clear()
        self._tls = threading.local()


_GRAPH = LockOrderGraph()


def graph() -> LockOrderGraph:
    return _GRAPH


def report() -> Dict:
    return _GRAPH.report()


def reset() -> None:
    _GRAPH.reset()


def assert_acyclic(artifact_path: Optional[str] = REPORT_PATH) -> Dict:
    """Raise ``AssertionError`` if the recorded acquisition-order graph
    has a cycle (a potential deadlock), writing the full JSON report to
    ``artifact_path`` first so CI can upload it.  Returns the report."""
    rep = _GRAPH.report()
    if rep["cycles"] or rep["potential_deadlocks"]:
        if artifact_path is not None:
            os.makedirs(os.path.dirname(artifact_path) or ".", exist_ok=True)
            with open(artifact_path, "w") as fh:
                json.dump(rep, fh, indent=1)
        names = " ; ".join("->".join(c) for c in rep["cycles"]) or \
            " ; ".join("->".join(d["cycle"])
                       for d in rep["potential_deadlocks"])
        raise AssertionError(
            f"lock-order cycle detected (potential deadlock): {names}"
            + (f" — report written to {artifact_path}"
               if artifact_path is not None else "")
        )
    return rep


# --------------------------------------------------------------------------- #
# instrumented wrappers
# --------------------------------------------------------------------------- #
class _SanLock:
    """Drop-in ``threading.Lock`` feeding the lock-order graph.

    The uncontended path is one extra try-lock plus the held-set/edge
    bookkeeping; the contended path records contention (and
    held-while-blocking) *before* blocking, so a real deadlock still
    leaves its telemetry behind."""

    __slots__ = ("_name", "_graph", "_lock")

    def __init__(self, name: str, g: LockOrderGraph):
        self._name = name
        self._graph = g
        self._lock = threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        contended = not self._lock.acquire(False)
        if contended:
            self._graph.note_blocking(self._name)
            if not blocking:
                return False
            if not self._lock.acquire(True, timeout):
                return False
        self._graph.note_acquired(self._name, contended)
        return True

    def release(self) -> None:
        self._graph.note_released(self._name)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "_SanLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self):
        return f"<SanLock {self._name} {self._lock!r}>"


class _SanRLock:
    """Drop-in ``threading.RLock`` feeding the lock-order graph.

    Reentrant acquires (depth > 1) record no edges — the lock is already
    in the thread's held set, so only the 0→1 transition orders against
    other locks.  Implements the private ``threading.Condition`` protocol
    (``_release_save``/``_acquire_restore``/``_is_owned``) so a Condition
    built over this wrapper keeps the held set honest across ``wait()``."""

    __slots__ = ("_name", "_graph", "_lock", "_owner", "_depth")

    def __init__(self, name: str, g: LockOrderGraph):
        self._name = name
        self._graph = g
        self._lock = threading.RLock()
        self._owner: Optional[int] = None
        self._depth = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        if self._owner == me:  # reentrant: no edges, just depth
            self._lock.acquire()
            self._depth += 1
            return True
        contended = not self._lock.acquire(False)
        if contended:
            self._graph.note_blocking(self._name)
            if not blocking:
                return False
            if not self._lock.acquire(True, timeout):
                return False
        self._owner = me
        self._depth = 1
        self._graph.note_acquired(self._name, contended)
        return True

    def release(self) -> None:
        if self._owner != threading.get_ident():
            raise RuntimeError(
                f"cannot release un-acquired sanitized lock {self._name}"
            )
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
            self._graph.note_released(self._name)
        self._lock.release()

    def __enter__(self) -> "_SanRLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # -- threading.Condition protocol -------------------------------------#
    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    def _release_save(self):
        depth = self._depth
        self._depth = 0
        self._owner = None
        self._graph.note_released(self._name)
        for _ in range(depth):
            self._lock.release()
        return depth

    def _acquire_restore(self, state) -> None:
        contended = not self._lock.acquire(False)
        if contended:
            self._graph.note_blocking(self._name)
            self._lock.acquire()
        for _ in range(state - 1):
            self._lock.acquire()
        self._owner = threading.get_ident()
        self._depth = state
        self._graph.note_acquired(self._name, contended)

    def __repr__(self):
        return f"<SanRLock {self._name} depth={self._depth}>"


# --------------------------------------------------------------------------- #
# factories — the only API lock sites use
# --------------------------------------------------------------------------- #
def make_lock(name: str):
    """A ``threading.Lock`` (or its sanitized wrapper under
    ``QUIP_SANITIZE=locks``) registered under ``name`` in the lock-order
    graph.  Instances may share a name (the per-(table, attr) flush locks
    all report as "ImputeStore.key")."""
    if resolve_sanitize() == "locks":
        return _SanLock(name, _GRAPH)
    return threading.Lock()


def make_rlock(name: str):
    """A ``threading.RLock`` (or its sanitized wrapper) named ``name``."""
    if resolve_sanitize() == "locks":
        return _SanRLock(name, _GRAPH)
    return threading.RLock()


def make_condition(lock):
    """A ``threading.Condition`` over ``lock`` — works identically for
    plain and sanitized locks (the wrappers implement the Condition
    protocol, so ``wait()`` releases/reacquires through the graph)."""
    return threading.Condition(lock)
