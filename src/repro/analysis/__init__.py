"""Static invariant lints + runtime sanitizers for the QUIP tree.

Two halves (docs/analysis.md):

* **quiplint** (:mod:`repro.analysis.lint`, ``python -m repro.analysis``)
  — AST passes enforcing the conventions the serving stack's correctness
  rests on: env-discipline (every ``QUIP_*`` read goes through
  ``core.env`` against :data:`repro.core.env.ENV_REGISTRY`),
  counter-discipline (``counters.<field> +=`` sites the provenance
  recorder mirrors), lock-discipline (``# guarded-by:`` annotations),
  span-discipline (tracer begin/end pairing), and kernel-triple parity
  (numpy/ref/Pallas + env knob per op).  Exit nonzero on findings.
* **lockcheck** (:mod:`repro.analysis.lockcheck`) — the
  ``QUIP_SANITIZE=locks`` runtime lock-order sanitizer; drop-in lock
  factories recording a global acquisition-order graph with cycle
  detection (potential-deadlock reports) plus contention telemetry.

This package stays import-light: lock sites across the tree import the
factories below at module import time, so nothing here may pull in the
executor/serving stack.
"""

from repro.analysis.lockcheck import (
    LockOrderGraph,
    assert_acyclic,
    graph,
    make_condition,
    make_lock,
    make_rlock,
    report,
    reset,
    resolve_sanitize,
)

__all__ = [
    "LockOrderGraph",
    "assert_acyclic",
    "graph",
    "make_condition",
    "make_lock",
    "make_rlock",
    "report",
    "reset",
    "resolve_sanitize",
]
