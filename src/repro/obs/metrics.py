"""Metrics registry: JSON snapshot + Prometheus text exposition.

The serving stack already keeps every number that matters —
:class:`~repro.core.stats.ExecutionCounters`,
:class:`~repro.core.stats.ServingStats`, the LRU caches' ``stats()``, the
scheduler's tenant accounting, the worker pool's busy/step counters.  This
module deliberately adds **no duplicate bookkeeping**: a metric is a *name*
plus a collector callable that reads the live objects at render time.
``QuipService.metrics()`` holds the service lock while collecting, so a
snapshot is internally consistent.

Two render formats:

* ``snapshot()`` — a JSON-able dict ``{name: {type, help, value|values|…}}``;
* ``prometheus()`` — the Prometheus text exposition format
  (``# HELP`` / ``# TYPE`` + samples), validated by
  ``benchmarks/exp13_obs.py`` and the CI smoke step.

The full metric-name catalog lives in docs/observability.md.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

__all__ = ["MetricsRegistry", "build_service_metrics"]

_LATENCY_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0)
_BATCH_BUCKETS = (1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0)
_STEP_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def _fmt(v) -> str:
    """Prometheus sample value: integral floats render as integers."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        if v == int(v) and abs(v) < 1e15:
            return str(int(v))
        return repr(v)
    return str(v)


def _escape_help(text: str) -> str:
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label(text: str) -> str:
    return (text.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class _Metric:
    __slots__ = ("name", "kind", "help", "collect", "label", "buckets")

    def __init__(self, name: str, kind: str, help_text: str,
                 collect: Callable, label: Optional[str] = None,
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.kind = kind  # "counter" | "gauge" | "histogram"
        self.help = help_text
        self.collect = collect
        self.label = label
        self.buckets = tuple(buckets) if buckets is not None else None


class MetricsRegistry:
    """Ordered set of named collectors over live stats objects."""

    def __init__(self):
        self._metrics: List[_Metric] = []
        self._names: set = set()

    def _add(self, metric: _Metric) -> None:
        if metric.name in self._names:
            raise ValueError(f"duplicate metric name {metric.name!r}")
        self._names.add(metric.name)
        self._metrics.append(metric)

    def counter(self, name: str, help_text: str, collect: Callable,
                label: Optional[str] = None) -> None:
        """Monotonic total.  ``collect`` returns a number, or — with
        ``label`` — a ``{label_value: number}`` dict."""
        self._add(_Metric(name, "counter", help_text, collect, label))

    def gauge(self, name: str, help_text: str, collect: Callable,
              label: Optional[str] = None) -> None:
        self._add(_Metric(name, "gauge", help_text, collect, label))

    def histogram(self, name: str, help_text: str,
                  collect_values: Callable[[], Sequence[float]],
                  buckets: Sequence[float]) -> None:
        """Cumulative-bucket histogram over ``collect_values()`` (the raw
        observations are re-read from the live objects at render time)."""
        self._add(_Metric(name, "histogram", help_text, collect_values,
                          buckets=buckets))

    def names(self) -> List[str]:
        return [m.name for m in self._metrics]

    # -- rendering --------------------------------------------------------#
    def snapshot(self) -> Dict[str, Dict]:
        out: Dict[str, Dict] = {}
        for m in self._metrics:
            entry: Dict = {"type": m.kind, "help": m.help}
            if m.kind == "histogram":
                values = [float(v) for v in m.collect()]
                entry["count"] = len(values)
                entry["sum"] = sum(values)
                entry["buckets"] = {
                    _fmt(b): sum(1 for v in values if v <= b)
                    for b in m.buckets
                }
            elif m.label is not None:
                entry["label"] = m.label
                entry["values"] = {str(k): v for k, v in m.collect().items()}
            else:
                entry["value"] = m.collect()
            out[m.name] = entry
        return out

    def prometheus(self) -> str:
        lines: List[str] = []
        for m in self._metrics:
            lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if m.kind == "histogram":
                values = [float(v) for v in m.collect()]
                acc = 0
                for b in m.buckets:
                    acc = sum(1 for v in values if v <= b)
                    lines.append(
                        f'{m.name}_bucket{{le="{_fmt(b)}"}} {acc}'
                    )
                lines.append(f'{m.name}_bucket{{le="+Inf"}} {len(values)}')
                lines.append(f"{m.name}_sum {_fmt(sum(values))}")
                lines.append(f"{m.name}_count {len(values)}")
            elif m.label is not None:
                for k in sorted(m.collect().keys(), key=str):
                    v = m.collect()[k]
                    lines.append(
                        f'{m.name}{{{m.label}="{_escape_label(str(k))}"}} '
                        f"{_fmt(v)}"
                    )
            else:
                lines.append(f"{m.name} {_fmt(m.collect())}")
        return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------- #
# the QuipService metric catalog (docs/observability.md)
# --------------------------------------------------------------------------- #
def _tenant_key(tenant) -> str:
    return "none" if tenant is None else str(tenant)


def build_service_metrics(svc) -> MetricsRegistry:
    """Wire the full catalog for one ``QuipService``.  Collectors close
    over the service and read its live objects; ``QuipService.metrics()``
    holds the service lock while rendering."""
    reg = MetricsRegistry()
    serving = svc.serving

    def _total():
        return serving.total_counters()

    # -- query stream ------------------------------------------------------#
    reg.counter("quip_queries_total", "Finished queries (failures included).",
                lambda: len(serving.records))
    reg.counter("quip_queries_failed_total", "Finished queries that failed.",
                lambda: sum(1 for r in serving.records if r.failed))
    reg.counter("quip_admission_queued_total",
                "Submissions that had to wait for an admission slot.",
                lambda: serving.admission_queued)
    reg.counter("quip_morsel_steps_total",
                "Scheduler-granted morsel steps across finished queries.",
                lambda: sum(r.steps for r in serving.records))
    reg.counter("quip_sched_cost_total",
                "Total scheduler-charged cost (cost-model units).",
                lambda: sum(r.sched_cost for r in serving.records))
    reg.counter("quip_exec_dispatch_total",
                "Finished queries by executor implementation.",
                lambda: _count_by(serving.records,
                                  lambda r: r.counters.exec_impl),
                label="impl")
    reg.gauge("quip_inflight", "Currently admitted (running) sessions.",
              lambda: svc.scheduler.running)
    reg.gauge("quip_waiting", "Sessions queued for admission.",
              lambda: len(svc._waiting))
    reg.gauge("quip_max_concurrent", "Peak concurrently admitted sessions.",
              lambda: serving.max_concurrent)
    reg.gauge("quip_sched_clock",
              "Scheduler cost clock (cost-model units).",
              lambda: svc.scheduler.clock)
    reg.histogram("quip_query_latency_seconds",
                  "Submit-to-result latency of finished queries.",
                  lambda: [r.latency_s for r in serving.records],
                  _LATENCY_BUCKETS)
    reg.histogram("quip_query_steps",
                  "Morsel steps per finished query.",
                  lambda: [float(r.steps) for r in serving.records],
                  _STEP_BUCKETS)

    # -- caches ------------------------------------------------------------#
    reg.counter("quip_plan_cache_hits_total", "Plan-cache hits.",
                lambda: svc.plan_cache.hits)
    reg.counter("quip_plan_cache_misses_total", "Plan-cache misses.",
                lambda: svc.plan_cache.misses)
    reg.gauge("quip_plan_cache_size", "Cached plan signatures.",
              lambda: len(svc.plan_cache))
    reg.gauge("quip_plan_cache_compiled",
              "Live compiled artifacts riding on cached plans.",
              lambda: svc.plan_cache.compiled_count())
    reg.gauge("quip_plan_cache_hit_rate",
              "Plan-cache hits / lookups (0 before any lookup).",
              lambda: _rate(svc.plan_cache.hits, svc.plan_cache.misses))
    if svc.result_cache is not None:
        reg.counter("quip_result_cache_hits_total", "Result-cache hits.",
                    lambda: svc.result_cache.hits)
        reg.counter("quip_result_cache_misses_total", "Result-cache misses.",
                    lambda: svc.result_cache.misses)
        reg.gauge("quip_result_cache_size", "Cached answers.",
                  lambda: len(svc.result_cache))
        reg.gauge("quip_result_cache_hit_rate",
                  "Result-cache hits / lookups (0 before any lookup).",
                  lambda: _rate(svc.result_cache.hits,
                                svc.result_cache.misses))

    # -- imputation --------------------------------------------------------#
    reg.counter("quip_imputations_total",
                "Cells actually imputed (model evaluations).",
                lambda: _total().imputations)
    reg.counter("quip_impute_batches_total",
                "Deduplicated imputer invocations.",
                lambda: _total().impute_batches)
    reg.counter("quip_impute_flushes_total",
                "Imputation service flushes that had queued work.",
                lambda: _total().impute_flushes)
    reg.counter("quip_impute_cross_hits_total",
                "Cells served from another query's shared-store fill.",
                lambda: _total().impute_cross_hits)
    reg.counter("quip_compiled_hits_total",
                "Executions served by a compiled tensor plan.",
                lambda: _total().compiled_hits)
    reg.counter("quip_compile_fallbacks_total",
                "Compiled dispatch requested but the interpreter ran.",
                lambda: _total().compile_fallbacks)
    reg.histogram("quip_impute_batch_size",
                  "Mean deduplicated imputation batch size per query.",
                  lambda: [
                      r.counters.imputations / r.counters.impute_batches
                      for r in serving.records if r.counters.impute_batches
                  ],
                  _BATCH_BUCKETS)
    if svc.store is not None:
        reg.gauge("quip_store_filled_cells",
                  "Imputed cells resident in the shared store.",
                  lambda: svc.store.filled_cells())

    # -- invalidation / registry -------------------------------------------#
    reg.counter("quip_invalidation_events_total",
                "Registry mutations observed by this service.",
                lambda: serving.invalidation_events)
    reg.counter("quip_plans_invalidated_total",
                "Plan-cache entries evicted by mutations.",
                lambda: serving.plans_invalidated)
    reg.counter("quip_results_invalidated_total",
                "Cached answers purged by mutations.",
                lambda: serving.results_invalidated)
    reg.counter("quip_store_cells_invalidated_total",
                "Shared-store cells dropped by mutations.",
                lambda: serving.store_cells_invalidated)
    reg.counter("quip_results_patched_total",
                "Cached answers patched in place by IVM (QUIP_IVM).",
                lambda: serving.results_patched)
    reg.counter("quip_ivm_fallbacks_total",
                "IVM maintenance attempts that fell back to eviction.",
                lambda: serving.ivm_fallbacks)
    reg.gauge("quip_registry_epoch", "Registry global mutation epoch.",
              lambda: svc.registry.global_epoch)

    # -- per-tenant residency ----------------------------------------------#
    reg.counter("quip_tenant_queries_total", "Finished queries per tenant.",
                lambda: _count_by(serving.records,
                                  lambda r: _tenant_key(r.tenant)),
                label="tenant")
    reg.counter("quip_tenant_sched_cost_total",
                "Scheduler-charged cost per tenant.",
                lambda: _sum_by(serving.records,
                                lambda r: _tenant_key(r.tenant),
                                lambda r: r.sched_cost),
                label="tenant")
    reg.gauge("quip_tenant_cost_share",
              "Tenant's fraction of all scheduler-charged cost.",
              lambda: _shares(serving.records),
              label="tenant")

    # -- worker pool -------------------------------------------------------#
    if svc._pool is not None:
        pool = svc._pool
        reg.gauge("quip_worker_pool_size", "Worker threads.",
                  lambda: pool.size)
        reg.gauge("quip_worker_busy",
                  "Workers currently stepping a session or unit.",
                  lambda: pool.busy)
        reg.counter("quip_worker_steps_total",
                    "Morsel steps executed on worker threads.",
                    lambda: pool.steps_done)
        reg.counter("quip_worker_units_total",
                    "Intra-query fan-out units executed by the pool.",
                    lambda: pool.units_done)
        reg.gauge("quip_worker_utilization",
                  "Busy workers / pool size.",
                  lambda: pool.busy / pool.size)
    return reg


def _rate(hits: int, misses: int) -> float:
    total = hits + misses
    return hits / total if total else 0.0


def _count_by(records, key) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for r in records:
        k = key(r)
        out[k] = out.get(k, 0) + 1
    return out


def _sum_by(records, key, value) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for r in records:
        k = key(r)
        out[k] = out.get(k, 0.0) + value(r)
    return out


def _shares(records) -> Dict[str, float]:
    cost = _sum_by(records, lambda r: _tenant_key(r.tenant),
                   lambda r: r.sched_cost)
    total = sum(cost.values())
    if total <= 0:
        return {k: 0.0 for k in cost}
    return {k: v / total for k, v in cost.items()}
