"""Impute provenance: why each cell was imputed where (docs/observability.md).

The paper's §6/§9.2 decision function is the heart of QUIP — impute this
morsel-group's attribute *now* at the operator, or delay it to ρ — yet
before this module its verdicts were invisible at runtime.  A
:class:`ProvenanceRecorder` rides on one query's
:class:`~repro.imputers.base.ImputationService` and records two streams:

* **decisions** — every decision-function evaluation
  (:func:`repro.core.operators.decide_groups`, and the compiled path's
  constant-eager equivalents): operator kind, plan node, attribute,
  the group's missing-attribute pattern and row count, the verdict, the
  §9.2 expected costs when the adaptive strategy computed them, and the
  reason (``strategy:eager``, ``obligated``, ``cost:delay``, ...).
* **sites** — every actual imputation flush, attributed to the operator
  context that requested it.  The executor wraps each
  ``_request_values`` call in :meth:`at`, and
  ``ImputationService._flush_key`` calls :meth:`on_flush` at the *exact*
  line where ``ExecutionCounters.imputations`` increments — so the
  report's per-operator ``computed`` totals reconcile with the query's
  counters by construction (asserted in tests/test_obs.py).

Thread safety: the operator context is thread-local (sibling parallel
morsels each carry their own), the accumulators are lock-guarded.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

from repro.analysis.lockcheck import make_lock
from repro.core.env import env_flag

__all__ = ["ProvenanceRecorder", "render_explain", "resolve_explain"]

# site context when a flush arrives outside any operator scope (direct
# engine.impute calls, warm-up traffic): still recorded, never dropped —
# the reconciliation invariant must hold over *all* imputations
_UNATTRIBUTED = ("unattributed", -1)


def resolve_explain(explain=None) -> bool:
    """Explicit argument > ``QUIP_EXPLAIN`` env (truthy/falsy via
    :func:`env_flag`, garbage raises) > off."""
    if explain is not None:
        return bool(explain)
    return env_flag("QUIP_EXPLAIN", False)


class ProvenanceRecorder:
    """Per-query impute-provenance accumulator (one per engine)."""

    def __init__(self):
        self._lock = make_lock("ProvenanceRecorder._lock")
        self._tls = threading.local()
        self.decisions: List[Dict] = []  # guarded-by: _lock
        # (op, node_id, table, attr) -> accumulated site telemetry
        self.sites: Dict[Tuple[str, int, str, str], Dict] = {}  # guarded-by: _lock

    # -- operator context --------------------------------------------------#
    @contextmanager
    def at(self, op: str, node_id: int):
        """Attribute every flush inside the block to ``(op, node_id)`` —
        wrapped around each operator-boundary ``_request_values`` call."""
        prev = getattr(self._tls, "ctx", None)
        self._tls.ctx = (op, int(node_id))
        try:
            yield
        finally:
            self._tls.ctx = prev

    def _ctx(self) -> Tuple[str, int]:
        return getattr(self._tls, "ctx", None) or _UNATTRIBUTED

    # -- recording ---------------------------------------------------------#
    def record_decision(self, op: str, node_id: int, attr: str,
                        pattern: Optional[Tuple[str, ...]], rows: int,
                        impute: bool, costs: Optional[Dict[str, float]],
                        reason: str) -> None:
        entry = {
            "op": op,
            "node": int(node_id),
            "attr": attr,
            "pattern": list(pattern) if pattern is not None else None,
            "rows": int(rows),
            "impute": bool(impute),
            "reason": reason,
        }
        if costs is not None:
            entry.update(costs)
        with self._lock:
            self.decisions.append(entry)

    def on_flush(self, table: str, attr: str, requested: int, computed: int,
                 hits: int, cross_hits: int, seconds: float) -> None:
        """One ``_flush_key`` outcome: ``requested`` queued tids, of which
        ``computed`` actually invoked the model (``counters.imputations``
        increments by exactly this), ``hits`` were already cached
        (``cross_hits`` of them paid for by *another* query via the shared
        store), costing ``seconds`` wall+simulated."""
        key = self._ctx() + (table, attr)
        with self._lock:
            site = self.sites.get(key)
            if site is None:
                site = self.sites[key] = {
                    "op": key[0], "node": key[1],
                    "table": table, "attr": attr,
                    "flushes": 0, "requested": 0, "computed": 0,
                    "cache_hits": 0, "cross_hits": 0, "seconds": 0.0,
                }
            site["flushes"] += 1
            site["requested"] += int(requested)
            site["computed"] += int(computed)
            site["cache_hits"] += int(hits)
            site["cross_hits"] += int(cross_hits)
            site["seconds"] += float(seconds)

    # -- report ------------------------------------------------------------#
    def report(self) -> Dict:
        """The explain report: decision log, per-site imputation
        attribution, per-operator rollup, and totals.  ``totals['imputed_cells']``
        equals the query's ``ExecutionCounters.imputations`` exactly (each
        ``on_flush(computed=n)`` mirrors one ``imputations += n``)."""
        with self._lock:
            decisions = list(self.decisions)
            sites = [dict(s) for s in self.sites.values()]
        sites.sort(key=lambda s: (s["op"], s["node"], s["table"], s["attr"]))
        per_op: Dict[str, int] = {}
        for s in sites:
            per_op[s["op"]] = per_op.get(s["op"], 0) + s["computed"]
        return {
            "decisions": decisions,
            "sites": sites,
            "per_op_imputed": per_op,
            "totals": {
                "decisions": len(decisions),
                "impute_now": sum(1 for d in decisions if d["impute"]),
                "delayed": sum(1 for d in decisions if not d["impute"]),
                "imputed_cells": sum(s["computed"] for s in sites),
                "cache_hits": sum(s["cache_hits"] for s in sites),
                "cross_hits": sum(s["cross_hits"] for s in sites),
                "impute_seconds": sum(s["seconds"] for s in sites),
            },
        }


def render_explain(report: Dict) -> str:
    """Human-readable explain report (``QuipService.explain_text``)."""
    lines: List[str] = []
    ticket = report.get("ticket")
    head = f"explain ticket={ticket}" if ticket is not None else "explain"
    if report.get("strategy"):
        head += f" strategy={report['strategy']}"
    if report.get("result_cache_hit"):
        return head + "  (result-cache hit: no relational work ran)"
    lines.append(head)
    totals = report.get("totals", {})
    lines.append(
        "  totals: {imputed_cells} cells imputed in {sites} site(s), "
        "{cache_hits} cache hits ({cross_hits} cross-query), "
        "{impute_now}/{decisions} decisions imputed now".format(
            sites=len(report.get("sites", [])),
            **{k: totals.get(k, 0) for k in (
                "imputed_cells", "cache_hits", "cross_hits",
                "impute_now", "decisions")},
        )
    )
    if report.get("sites"):
        lines.append("  imputation sites (op/node  attr  "
                     "computed/requested  cross  seconds):")
        for s in report["sites"]:  # attrs are already table-qualified
            lines.append(
                f"    {s['op']}@{s['node']:<4d} {s['attr']:<14s}"
                f" {s['computed']}/{s['requested']}"
                f"  cross={s['cross_hits']}  {s['seconds']:.6f}s"
            )
    if report.get("decisions"):
        lines.append("  decision-function log (op/node attr rows -> verdict"
                     " [reason]  est imp/qp deltas):")
        for d in report["decisions"]:
            verdict = "impute" if d["impute"] else "delay"
            est = ""
            if "est_imp_impute" in d:
                d_imp = d["est_imp_impute"] - d["est_imp_delay"]
                d_qp = d["est_qp_impute"] - d["est_qp_delay"]
                est = f"  dImp={d_imp:+.3e} dQP={d_qp:+.3e}"
            lines.append(
                f"    {d['op']}@{d['node']:<4d} {d['attr']:<14s}"
                f" rows={d['rows']:<6d} -> {verdict:<6s}"
                f" [{d['reason']}]{est}"
            )
    return "\n".join(lines)
