"""Observability for the QUIP serving stack: spans, metrics, provenance.

See docs/observability.md.  Gates: ``QUIP_TRACE`` / ``QUIP_TRACE_CLOCK``
(span recording), ``QUIP_EXPLAIN`` (impute provenance); both off by
default with a zero-allocation no-op path.
"""

from repro.obs.metrics import MetricsRegistry, build_service_metrics
from repro.obs.provenance import (
    ProvenanceRecorder,
    render_explain,
    resolve_explain,
)
from repro.obs.trace import (
    NULL_SPAN,
    NULL_TRACER,
    Span,
    TRACE_CLOCKS,
    Tracer,
    resolve_tracer,
)

__all__ = [
    "MetricsRegistry",
    "NULL_SPAN",
    "NULL_TRACER",
    "ProvenanceRecorder",
    "Span",
    "TRACE_CLOCKS",
    "Tracer",
    "build_service_metrics",
    "render_explain",
    "resolve_explain",
    "resolve_tracer",
]
