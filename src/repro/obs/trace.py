"""Structured span tracing for the QUIP serving stack (docs/observability.md).

One :class:`Tracer` per :class:`~repro.service.server.QuipService` records a
per-query span tree — submit → admission → scheduler checkout/checkin →
morsel step → operator → impute flush → kernel dispatch — and exports it as
Chrome trace-event JSON (loadable in ``chrome://tracing`` / Perfetto).

Design constraints, in order:

* **Zero-allocation no-op mode.**  A disabled tracer must be free on the
  morsel hot path.  ``Tracer.span(...)`` returns the shared
  :data:`NULL_SPAN` singleton when disabled, and every hot call site
  additionally guards with ``if tracer.enabled`` so the keyword-argument
  dict is never even built.  The overhead gate in ``benchmarks/exp13_obs.py``
  asserts this contract.
* **Deterministic structure.**  ``clock="unit"`` replaces ``perf_counter``
  with a lock-guarded monotone tick, so CI asserts on span *counts and
  nesting* (:meth:`span_counts`, :meth:`span_tree`), never on wall time.
* **Thread safety.**  Spans nest through a thread-local parent stack
  (worker threads each get their own); the record list and the unit tick
  are guarded by one lock.  Cross-thread spans (a query's submit→finalize
  lifetime) use the explicit :meth:`begin`/:meth:`end` pair, which does not
  touch any thread's stack.

Per-query attribution: a span created with ``ticket=`` stamps it; nested
spans without one inherit the nearest enclosing span's ticket on the same
thread.  ``chrome_trace(ticket=...)`` exports one query's tree.
"""

from __future__ import annotations

import threading
import time
from collections import Counter
from typing import Dict, List, Optional

from repro.analysis.lockcheck import make_lock
from repro.core.env import env_choice, env_flag

__all__ = [
    "NULL_SPAN",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "TRACE_CLOCKS",
    "resolve_tracer",
]

TRACE_CLOCKS = ("wall", "unit")


class _NullSpan:
    """The shared no-op span: context manager + ``set`` sink.

    A singleton (:data:`NULL_SPAN`) so the disabled path allocates
    nothing — every ``with tracer.span(...)`` site reuses this object."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One recorded event: a completed span (``ph="X"``) or an instant
    (``ph="i"``).  ``t0``/``t1`` are seconds under the wall clock and bare
    ticks under the unit clock."""

    __slots__ = ("span_id", "parent_id", "name", "cat", "ticket",
                 "thread", "t0", "t1", "args", "ph")

    def __init__(self, span_id: int, parent_id: Optional[int], name: str,
                 cat: str, ticket: Optional[int], thread: str,
                 t0: float, args: Dict[str, object], ph: str = "X"):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.cat = cat
        self.ticket = ticket
        self.thread = thread
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args
        self.ph = ph


class _LiveSpan:
    """Context-manager handle for one open span on the current thread."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def set(self, **attrs) -> "_LiveSpan":
        self._span.args.update(attrs)
        return self

    def __enter__(self) -> "_LiveSpan":
        self._tracer._push(self._span)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.args.setdefault("error", exc_type.__name__)
        self._tracer._pop(self._span)
        return False


class Tracer:
    """Thread-safe span recorder with a wall or deterministic unit clock.

    ``enabled=False`` (the default of :func:`resolve_tracer` without
    ``QUIP_TRACE``) makes every recording call a no-op returning
    :data:`NULL_SPAN`."""

    def __init__(self, enabled: bool = True, clock: str = "wall"):
        if clock not in TRACE_CLOCKS:
            raise ValueError(f"unknown trace clock {clock!r}; "
                             f"expected one of {TRACE_CLOCKS}")
        self.enabled = bool(enabled)
        self.clock = clock
        self._lock = make_lock("Tracer._lock")
        self._records: List[Span] = []  # guarded-by: _lock
        self._open: Dict[int, Span] = {}  # guarded-by: _lock
        self._next_id = 0  # guarded-by: _lock
        self._tick = 0  # guarded-by: _lock
        self._origin = time.perf_counter()
        self._tls = threading.local()

    # -- clock / ids ------------------------------------------------------#
    def now(self) -> float:
        if self.clock == "unit":
            with self._lock:
                self._tick += 1
                return float(self._tick)
        return time.perf_counter() - self._origin

    def _new_id(self) -> int:
        with self._lock:
            self._next_id += 1
            return self._next_id

    # -- thread-local span stack ------------------------------------------#
    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        span.t1 = self.now()
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        with self._lock:
            self._records.append(span)

    def _parent(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    # -- recording API ----------------------------------------------------#
    def span(self, name: str, cat: str = "exec",
             ticket: Optional[int] = None,
             parent: Optional[int] = None, **args):
        """Open a nested span on this thread; use as a context manager.
        Disabled tracers return :data:`NULL_SPAN` (shared, allocation-free).
        ``parent`` overrides the thread-local nesting (e.g. to hang morsel
        steps under a cross-thread :meth:`begin` query span)."""
        if not self.enabled:
            return NULL_SPAN
        top = self._parent()
        if parent is None and top is not None:
            parent = top.span_id
        if ticket is None and top is not None:
            ticket = top.ticket
        return _LiveSpan(self, Span(
            self._new_id(), parent, name, cat, ticket,
            threading.current_thread().name, self.now(), args,
        ))

    def instant(self, name: str, cat: str = "event",
                ticket: Optional[int] = None,
                parent: Optional[int] = None, **args) -> None:
        """Record a zero-duration event (scheduler checkout/checkin,
        admission...).  ``parent`` hangs the event under a cross-thread
        :meth:`begin` span — the scheduler passes the query span so its
        instants join the ticket's tree instead of floating as roots."""
        if not self.enabled:
            return
        top = self._parent()
        if parent is None and top is not None:
            parent = top.span_id
        if ticket is None and top is not None:
            ticket = top.ticket
        span = Span(self._new_id(), parent, name, cat, ticket,
                    threading.current_thread().name, self.now(), args,
                    ph="i")
        span.t1 = span.t0
        with self._lock:
            self._records.append(span)

    def begin(self, name: str, cat: str = "query",
              ticket: Optional[int] = None, **args) -> Optional[int]:
        """Open a cross-thread span (no thread-local nesting); returns its
        span id for :meth:`end`.  None when disabled."""
        if not self.enabled:
            return None
        span = Span(self._new_id(), None, name, cat, ticket,
                    threading.current_thread().name, self.now(), args)
        with self._lock:
            self._open[span.span_id] = span
        return span.span_id

    def end(self, span_id: Optional[int], **args) -> None:
        """Close a :meth:`begin` span (id None — disabled begin — is a
        no-op)."""
        if not self.enabled or span_id is None:
            return
        with self._lock:
            span = self._open.pop(span_id, None)
        if span is None:
            return
        span.args.update(args)
        span.t1 = self.now()
        with self._lock:
            self._records.append(span)

    def clear(self) -> None:
        with self._lock:
            self._records = []
            self._open = {}
            self._tick = 0
            self._next_id = 0
        self._origin = time.perf_counter()

    # -- introspection ----------------------------------------------------#
    def spans(self, ticket: Optional[int] = None,
              name: Optional[str] = None) -> List[Span]:
        """Recorded spans, oldest first, optionally filtered by ticket
        and/or name."""
        with self._lock:
            records = list(self._records)
        records.sort(key=lambda s: (s.t0, s.span_id))
        if ticket is not None:
            records = [s for s in records if s.ticket == ticket]
        if name is not None:
            records = [s for s in records if s.name == name]
        return records

    def span_counts(self, ticket: Optional[int] = None) -> Dict[str, int]:
        """``{span name: count}`` — the structural fingerprint CI asserts
        on under the unit clock (no wall time anywhere)."""
        return dict(Counter(s.name for s in self.spans(ticket)))

    def span_tree(self, ticket: Optional[int] = None) -> List[Dict]:
        """Nested ``{"name", "children"}`` forest ordered by start time —
        deterministic under ``clock="unit"`` with a serial scheduler."""
        records = self.spans(ticket)
        ids = {s.span_id for s in records}
        nodes = {s.span_id: {"name": s.name, "children": []} for s in records}
        roots: List[Dict] = []
        for s in records:
            node = nodes[s.span_id]
            if s.parent_id in ids:
                nodes[s.parent_id]["children"].append(node)
            else:
                roots.append(node)
        return roots

    # -- Chrome trace-event export ----------------------------------------#
    def chrome_trace(self, ticket: Optional[int] = None) -> Dict:
        """The whole service's (or one ticket's) trace as a Chrome
        trace-event JSON document: ``ph="X"`` complete events with µs
        timestamps, pid = ticket (0 for service-level spans), tid = a
        stable per-thread integer, plus ``ph="M"`` metadata naming every
        process and thread.  Unit-clock ticks export as 1 µs each."""
        records = self.spans(ticket)
        threads = {name: i + 1 for i, name in enumerate(
            sorted({s.thread for s in records})
        )}
        scale = 1.0 if self.clock == "unit" else 1e6  # → microseconds
        events: List[Dict] = []
        pids = sorted({s.ticket or 0 for s in records})
        for pid in pids:
            events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": f"ticket {pid}" if pid else "service"},
            })
        for name, tid in threads.items():
            for pid in pids:
                events.append({
                    "name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": name},
                })
        for s in records:
            ev = {
                "name": s.name,
                "cat": s.cat,
                "ph": s.ph,
                "ts": s.t0 * scale,
                "pid": s.ticket or 0,
                "tid": threads[s.thread],
                "args": dict(s.args),
            }
            if s.ph == "X":
                ev["dur"] = max(((s.t1 or s.t0) - s.t0) * scale, 0.0)
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "metadata": {"clock": self.clock, "tracer": "quip-obs"},
        }


#: the shared disabled tracer — the default wiring when observability is
#: off, so layers can hold a tracer unconditionally (no None checks)
NULL_TRACER = Tracer(enabled=False)


def resolve_tracer(tracer=None) -> Tracer:
    """Explicit :class:`Tracer` > bool > ``QUIP_TRACE`` env (truthy/falsy
    via :func:`env_flag`, garbage raises) > off.  The clock comes from
    ``QUIP_TRACE_CLOCK`` (``wall`` | ``unit``, via :func:`env_choice`)
    unless an explicit Tracer is handed in."""
    if isinstance(tracer, Tracer):
        return tracer
    clock = env_choice("QUIP_TRACE_CLOCK", TRACE_CLOCKS, "wall")
    if tracer is None:
        enabled = env_flag("QUIP_TRACE", False)
    else:
        enabled = bool(tracer)
    if not enabled:
        return NULL_TRACER
    return Tracer(enabled=True, clock=clock)
