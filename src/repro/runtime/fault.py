"""Fault-tolerant step driver: heartbeat watchdog, failure injection, and
checkpoint/restart — the single-process simulation of the multi-host
controller loop (each real host runs this driver; the coordinator restarts
ranks that miss heartbeats).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint

__all__ = ["FaultConfig", "FaultTolerantDriver", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    heartbeat_timeout_s: float = 300.0
    max_restarts: int = 10
    fail_at_steps: tuple = ()  # failure injection for tests


class FaultTolerantDriver:
    """run(train_step, state, batches) with checkpoint/restart semantics.

    ``train_step`` must be a pure function (state, batch) → (state, metrics);
    on a (simulated) failure the driver restores the latest complete
    checkpoint and replays from there — the contract that makes preemption /
    node loss survivable at cluster scale.
    """

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.restarts = 0
        self.heartbeat = time.time()
        self.metrics_log: List[Dict[str, Any]] = []

    def beat(self) -> None:
        self.heartbeat = time.time()

    def stalled(self) -> bool:
        return (time.time() - self.heartbeat) > self.cfg.heartbeat_timeout_s

    def run(
        self,
        train_step: Callable,
        state: Any,
        batch_fn: Callable[[int], Any],
        num_steps: int,
        state_like: Optional[Any] = None,
    ) -> Any:
        state_like = state_like if state_like is not None else state
        step = 0
        # resume if a checkpoint exists
        if latest_step(self.cfg.ckpt_dir) is not None:
            state, step = restore_checkpoint(self.cfg.ckpt_dir, state_like)
        injected = set(self.cfg.fail_at_steps)
        while step < num_steps:
            try:
                if step in injected:
                    injected.discard(step)
                    raise SimulatedFailure(f"injected failure at step {step}")
                state, metrics = train_step(state, batch_fn(step))
                self.beat()
                self.metrics_log.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}}
                )
                step += 1
                if step % self.cfg.ckpt_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                if latest_step(self.cfg.ckpt_dir) is not None:
                    state, step = restore_checkpoint(
                        self.cfg.ckpt_dir, state_like
                    )
                else:
                    step = 0  # no checkpoint yet: restart from scratch
        self.ckpt.wait()
        return state
