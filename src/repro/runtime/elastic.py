"""Elastic scaling: re-mesh and reshard a training state between device
counts (grow after repair, shrink after eviction).

The state is brought to host (from the last checkpoint in the real flow),
the new mesh is built, and every leaf is re-placed under the sharding rules
for the new mesh.  Data-parallel batch is re-split by the caller (global
batch stays fixed; per-device batch changes).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding.axes import param_specs

__all__ = ["reshard_state", "elastic_remesh_plan"]


def elastic_remesh_plan(old_devices: int, new_devices: int,
                        model_parallel: int) -> Tuple[int, int]:
    """(data_parallel, model_parallel) for the new device count; model
    parallelism is preserved (weights layout), data parallelism absorbs the
    change."""
    assert new_devices % model_parallel == 0, (
        f"{new_devices} devices cannot keep model={model_parallel}"
    )
    return new_devices // model_parallel, model_parallel


def reshard_state(state: Any, new_mesh: Mesh) -> Any:
    """Re-place every leaf of ``state`` for ``new_mesh`` (host round-trip —
    the checkpoint path in production; device-to-device for tests)."""
    specs = param_specs(state, new_mesh)

    def place(leaf, sharding):
        host = np.asarray(leaf)
        return jax.device_put(host, sharding)

    return jax.tree_util.tree_map(place, state, specs)
