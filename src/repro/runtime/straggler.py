"""Straggler detection/mitigation: EWMA step-time model with outlier ranks.

At 1000+-node scale the slowest rank gates every synchronous collective.
The monitor keeps a per-rank EWMA of step times; ranks slower than
``threshold × median`` are flagged, and the mitigation hook (re-balance
batch shards away from the rank, or evict → elastic re-mesh) fires after
``patience`` consecutive flags.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class _RankState:
    ewma: Optional[float] = None
    flags: int = 0


class StragglerMonitor:
    def __init__(self, n_ranks: int, alpha: float = 0.2,
                 threshold: float = 1.5, patience: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ranks: List[_RankState] = [_RankState() for _ in range(n_ranks)]
        self.mitigations: List[Dict] = []

    def observe(self, step: int, step_times: np.ndarray,
                mitigate: Optional[Callable[[int], None]] = None
                ) -> List[int]:
        """Record one step's per-rank times; returns ranks mitigated."""
        for r, t in enumerate(step_times):
            st = self.ranks[r]
            st.ewma = t if st.ewma is None else (
                self.alpha * t + (1 - self.alpha) * st.ewma
            )
        med = float(np.median([s.ewma for s in self.ranks]))
        fired = []
        for r, st in enumerate(self.ranks):
            if st.ewma > self.threshold * med:
                st.flags += 1
                if st.flags >= self.patience:
                    fired.append(r)
                    st.flags = 0
                    self.mitigations.append(
                        {"step": step, "rank": r, "ewma": st.ewma,
                         "median": med}
                    )
                    if mitigate is not None:
                        mitigate(r)
            else:
                st.flags = 0
        return fired
