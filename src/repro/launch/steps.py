"""Jittable train / serve steps with sharding annotations.

``build_train_step`` returns (fn, state_spec, batch_spec_tree) ready for
``jax.jit(fn, in_shardings=..., out_shardings=...)`` — used by the real
trainer (examples/train_lm.py) and by the multi-pod dry-run (AOT
lower+compile against ShapeDtypeStructs).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    warmup_cosine,
)

__all__ = ["optimizer_for", "init_train_state", "build_train_step",
           "build_serve_step", "abstract_train_state"]


def optimizer_for(cfg: ArchConfig) -> str:
    # Adam moments for a 671B model exceed v5e HBM; use factored stats there.
    return "adafactor" if cfg.num_params() > 100e9 else "adamw"


def init_train_state(cfg: ArchConfig, params: Any) -> Dict[str, Any]:
    opt = optimizer_for(cfg)
    if opt == "adafactor":
        return {"params": params, "opt": adafactor_init(params),
                "step": jnp.zeros((), jnp.int32)}
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ArchConfig) -> Dict[str, Any]:
    return jax.eval_shape(
        lambda: init_train_state(cfg, M.init_params(cfg, jax.random.PRNGKey(0)))
    )


def build_train_step(cfg: ArchConfig, *, remat: str = "full",
                     peak_lr: float = 3e-4, warmup: int = 200,
                     total_steps: int = 10_000, clip_norm: float = 1.0,
                     scan_unroll: bool = False):
    opt = optimizer_for(cfg)

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        def loss(p):
            return M.loss_fn(p, cfg, batch, remat=remat,
                             scan_unroll=scan_unroll)

        loss_val, grads = jax.value_and_grad(loss)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = warmup_cosine(state["step"], peak_lr, warmup, total_steps)
        if opt == "adafactor":
            new_p, new_opt = adafactor_update(
                state["params"], grads, state["opt"], lr
            )
        else:
            new_p, new_opt = adamw_update(
                state["params"], grads, state["opt"], lr
            )
        new_state = {
            "params": new_p, "opt": new_opt, "step": state["step"] + 1
        }
        metrics = {"loss": loss_val, "gnorm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def build_serve_step(cfg: ArchConfig, kind: str, scan_unroll: bool = False):
    """kind: 'prefill' (full-sequence logits) or 'decode' (one token)."""
    if kind == "prefill":
        def serve_step(params, batch):
            return M.prefill(params, cfg, batch, remat="none",
                             scan_unroll=scan_unroll)
        return serve_step

    def serve_step(params, caches, batch):
        logits, new_caches = M.decode_step(
            params, caches, cfg, batch["tokens"], batch["pos"],
            scan_unroll=scan_unroll,
        )
        return logits, new_caches

    return serve_step
