"""End-to-end trainer: QUIP-cleaned data pipeline → sharded train steps with
fault tolerance (checkpoint/restart), straggler monitoring, and metrics.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 100 --batch 8 --seq 128

Single-host it uses a (1, n_devices) host mesh; on a real cluster the same
code runs under ``jax.distributed`` with ``make_production_mesh()``.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import QuipCleanStage
from repro.data.queries import workload
from repro.data.synthetic import wifi_dataset
from repro.launch import steps as S
from repro.models import init_params, uses_embeds
from repro.runtime.fault import FaultConfig, FaultTolerantDriver
from repro.runtime.straggler import StragglerMonitor
from repro.sharding.act import activation_sharding
from repro.sharding.axes import param_specs

__all__ = ["train_loop", "main"]


def _host_mesh():
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


def quip_batch_stream(cfg, batch: int, seq: int, strategy: str = "adaptive"
                      ) -> Iterator[Dict[str, np.ndarray]]:
    tables, _ = wifi_dataset(n_users=200, n_wifi=4000, n_occ=2000)
    queries = workload("wifi", tables, kind="random", n_queries=4, seed=3)
    stage = QuipCleanStage(
        tables=tables, queries=queries, vocab=cfg.vocab, seq_len=seq,
        global_batch=batch, strategy=strategy,
    )
    return stage.batches()


def train_loop(cfg, steps: int, batch: int, seq: int,
               ckpt_dir: Optional[str] = None,
               fail_at: tuple = (),
               log_every: int = 10) -> Dict[str, Any]:
    mesh = _host_mesh()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    state = S.init_train_state(cfg, params)
    s_specs = param_specs(state, mesh)
    step_fn = S.build_train_step(cfg, warmup=20, total_steps=max(steps, 2))

    with mesh, activation_sharding(mesh):
        jitted = jax.jit(step_fn, in_shardings=(s_specs, None),
                         out_shardings=(s_specs, None))

        stream = quip_batch_stream(cfg, batch, seq)
        batches = []

        def batch_fn(i):
            while len(batches) <= i % 64:
                b = next(stream)
                if uses_embeds(cfg):
                    rng = np.random.default_rng(len(batches))
                    batches.append({
                        "embeds": rng.normal(
                            0, 1, (batch, seq, cfg.d_model)
                        ).astype(np.float32),
                        "labels": b["labels"],
                    })
                else:
                    batches.append(b)
            return batches[i % 64]

        monitor = StragglerMonitor(n_ranks=jax.device_count())
        losses = []
        t_start = time.time()

        def stepper(state, batch_np):
            t0 = time.time()
            new_state, metrics = jitted(state, batch_np)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.observe(len(losses), np.full(jax.device_count(), dt))
            losses.append(loss)
            if len(losses) % log_every == 0:
                print(f"step {len(losses):4d}  loss {loss:.4f}  "
                      f"({dt*1e3:.0f} ms/step)", flush=True)
            return new_state, metrics

        if ckpt_dir:
            driver = FaultTolerantDriver(FaultConfig(
                ckpt_dir=ckpt_dir, ckpt_every=25, fail_at_steps=fail_at,
            ))
            state = driver.run(stepper, state, batch_fn, steps,
                               state_like=state)
            restarts = driver.restarts
        else:
            for i in range(steps):
                state, _ = stepper(state, batch_fn(i))
            restarts = 0

    return {
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "losses": losses,
        "restarts": restarts,
        "seconds": time.time() - t_start,
        "state": state,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = train_loop(cfg, args.steps, args.batch, args.seq,
                     ckpt_dir=args.ckpt)
    print(f"done: loss {out['first_loss']:.4f} → {out['final_loss']:.4f} "
          f"in {out['seconds']:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
