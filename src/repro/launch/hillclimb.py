import os
# idempotent: importing both launch modules (hillclimb imports dryrun)
# must not stack the flag — jax locks the device count on first init
_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count=512"
if _HOST_DEVICES_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        _HOST_DEVICES_FLAG + " " + os.environ.get("XLA_FLAGS", "")
    )

"""§Perf hillclimb driver: compile a cell under named config variants and
report the three roofline terms per variant (hypothesis → change → measure).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell deepseek-v3-671b:train_4k \
        --variants baseline,scatter_moe,scatter_moe+dots

Variant atoms (composable with '+'):
    naive_attn    S²-materializing attention (the measured baseline)
    scatter_moe   index-dispatch MoE (vs GShard one-hot einsum)
    pv_bf16       bf16 P·V matmul in flash attention
    dots          remat policy dots_with_no_batch_dims_saveable
    qc256/kc2048  flash q/k chunk-size overrides
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
from typing import Dict, Tuple  # noqa: E402

from repro.configs import SHAPES, get_arch  # noqa: E402
from repro.launch.dryrun import dryrun_cell  # noqa: E402


def apply_variant(cfg, name: str) -> Tuple[object, str]:
    remat = "full"
    for atom in name.split("+"):
        if atom in ("baseline", ""):
            continue
        elif atom == "naive_attn":
            cfg = dataclasses.replace(cfg, attn_impl="naive")
        elif atom == "scatter_moe":
            cfg = dataclasses.replace(cfg, moe_impl="scatter")
        elif atom == "moe_bf16":
            cfg = dataclasses.replace(cfg, moe_bf16_dispatch=True)
        elif atom == "pv_bf16":
            cfg = dataclasses.replace(cfg, attn_pv_bf16=True)
        elif atom == "dots":
            remat = "dots"
        elif atom == "noremat":
            remat = "none"
        elif atom.startswith("qc"):
            cfg = dataclasses.replace(cfg, attn_q_chunk=int(atom[2:]))
        elif atom.startswith("kc"):
            cfg = dataclasses.replace(cfg, attn_k_chunk=int(atom[2:]))
        else:
            raise ValueError(f"unknown variant atom {atom!r}")
    return cfg, remat


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    arch, shape = args.cell.split(":")
    results: Dict[str, Dict] = {}
    for name in args.variants.split(","):
        cfg, remat = apply_variant(get_arch(arch), name)
        print(f"--- {args.cell} [{name}] ---", flush=True)
        try:
            r = dryrun_cell(arch, shape, args.multi_pod, remat=remat,
                            cfg_override=cfg)
            results[name] = r
        except Exception as e:  # noqa: BLE001
            print(f"[FAIL] {name}: {e!r}", flush=True)
            results[name] = {"error": repr(e)}
    print("\nvariant, t_comp_ms, t_mem_ms, t_coll_ms, bottleneck, useful, "
          "roofline, peak_GB")
    for name, r in results.items():
        rf = r.get("roofline")
        if not rf:
            print(f"{name}, ERROR")
            continue
        peak = (r.get("memory", {}).get("peak_bytes") or 0) / 1e9
        print(f"{name}, {rf['t_compute_ms']:.1f}, {rf['t_memory_ms']:.1f}, "
              f"{rf['t_collective_ms']:.1f}, {rf['bottleneck']}, "
              f"{rf['useful_ratio']:.2f}, "
              f"{rf['roofline_fraction']*100:.1f}%, {peak:.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
