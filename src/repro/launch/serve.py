"""Batched serving driver: prefill + decode loop with KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import (
    decode_step,
    init_caches,
    init_params,
    prefill,
    uses_embeds,
)

__all__ = ["serve_batch", "main"]


def serve_batch(cfg, batch: int, prompt_len: int, gen: int,
                seed: int = 0) -> Dict:
    assert not cfg.encoder_only, "encoder-only archs have no decode path"
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    max_len = prompt_len + gen

    t0 = time.time()
    caches = init_caches(cfg, batch, max_len)
    # prefill by streaming the prompt through decode (cache warm-up), then
    # greedy-decode `gen` tokens.
    step = jax.jit(lambda p, c, t, q: decode_step(p, c, cfg, t, q))
    logits = None
    for t in range(prompt_len):
        logits, caches = step(
            params, caches, toks[:, t : t + 1],
            jnp.full((batch,), t, jnp.int32),
        )
    t_prefill = time.time() - t0

    out_tokens = []
    t0 = time.time()
    cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for g in range(gen):
        out_tokens.append(np.asarray(cur)[:, 0])
        logits, caches = step(
            params, caches, cur,
            jnp.full((batch,), prompt_len + g, jnp.int32),
        )
        cur = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0
    return {
        "tokens": np.stack(out_tokens, axis=1),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * gen / max(t_decode, 1e-9),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = serve_batch(cfg, args.batch, args.prompt_len, args.gen)
    print(f"generated {out['tokens'].shape} tokens; "
          f"prefill {out['prefill_s']:.2f}s, decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
