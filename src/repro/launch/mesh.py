"""Production mesh construction (function, not module-level constant — the
import must never touch jax device state)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths (same axis names as single-pod)."""
    return jax.make_mesh((1, 1), ("data", "model"))
