"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory term     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective term = collective_bytes / (chips × 50e9 B/s per ICI link)

``cost_analysis()`` supplies per-device FLOPs/bytes; collective bytes are
parsed from the compiled HLO text (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

__all__ = ["HW", "RooflineReport", "analyze", "collective_bytes_from_hlo",
           "model_flops"]

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE,
)
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")


def _line_output_bytes(line: str) -> int:
    """Bytes of the op's output tuple (per-device, SPMD-partitioned HLO)."""
    head = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes summed over ops (per device)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1).lower()
        b = _line_output_bytes(line)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    collective_bytes: float  # per device
    per_kind: Dict[str, int]
    model_flops: float  # analytic 6·N·D (whole step, global)
    bytes_per_device: Optional[float] = None  # memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the roofline bound the *useful* math achieves:
        (model_flops / chips / peak) / max(term)."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / bound if bound else 0.0

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
            f"{self.t_collective*1e3:.2f} | {self.bottleneck} | "
            f"{self.useful_ratio:.2f} | {self.roofline_fraction:.2%} |"
        )


def render_report(path: str, mesh_filter: Optional[str] = None) -> str:
    """Markdown §Roofline table from a dryrun --out JSON."""
    import json

    with open(path) as f:
        rows = json.load(f)
    out = [
        "| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
        "bottleneck | useful | roofline | peak mem (GB) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    for r in rows:
        if "skipped" in r:
            skips.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                         f"{r['skipped']} |")
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        rf = r.get("roofline", {})
        peak = r.get("memory", {}).get("peak_bytes")
        # sub-ms decode cells: depth-extrapolation noise can go negative
        clamp = lambda v: max(0.0, v)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{clamp(rf.get('t_compute_ms', 0)):.1f} | "
            f"{clamp(rf.get('t_memory_ms', 0)):.1f} | "
            f"{clamp(rf.get('t_collective_ms', 0)):.1f} | "
            f"{rf.get('bottleneck','-')} | "
            f"{clamp(rf.get('useful_ratio', 0)):.2f} | "
            f"{clamp(rf.get('roofline_fraction', 0))*100:.1f}% | "
            f"{(peak or 0)/1e9:.2f} |"
        )
    return "\n".join(out + [""] + sorted(set(skips)))


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step: 6·N_active·D for training, 2·N_active·D
    for inference (D = tokens processed), plus attention O(S²) term."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn_mult = 3.0  # fwd + bwd(2x)
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch * 1
        base = 2.0 * n_active * tokens
        attn_mult = 1.0

    # attention score/context FLOPs
    attn_flops = 0.0
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "ssm":
            continue
        s = shape.seq_len
        if shape.kind == "decode":
            q_len, k_len = 1, s
        else:
            q_len, k_len = s, s
        if kind == "local" and cfg.local_window:
            k_len = min(k_len, cfg.local_window)
        per_seq = 2.0 * 2.0 * cfg.n_heads * hd * q_len * k_len * 0.5
        attn_flops += per_seq * shape.global_batch * attn_mult
    return base + attn_flops


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="dryrun_report.json")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    print(render_report(args.report, args.mesh))
