import os
# idempotent: re-import (or hillclimb importing this module) must not
# stack the flag — jax locks the device count on first init anyway
_HOST_DEVICES_FLAG = "--xla_force_host_platform_device_count=512"
if _HOST_DEVICES_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        _HOST_DEVICES_FLAG + " " + os.environ.get("XLA_FLAGS", "")
    )

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device count
on first init).  Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out report.json

Each cell gets:

* a **check compile** at full depth (scanned layers — compact HLO) that
  proves sharding/lowering and yields ``memory_analysis()``;
* a **roofline estimate** via depth extrapolation: the same step is compiled
  *unrolled* at 1 and 2 periods of the dominant segment; per-period cost =
  the difference, total = base + per-period × repeats.  Exact for periodic
  stacks, and avoids both the scan cost-undercount (a while body is counted
  once) and minutes-long full-depth unrolled compiles.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402

from repro.configs import SHAPES, get_arch, runnable, all_archs  # noqa: E402
from repro.configs.base import ArchConfig, ShapeConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.launch import roofline as R  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.transformer import build_segments  # noqa: E402
from repro.sharding.act import activation_sharding  # noqa: E402
from repro.sharding.axes import batch_specs, cache_specs, param_specs  # noqa: E402

__all__ = ["dryrun_cell", "compile_cell"]


def compile_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
                 remat: str = "full", scan_unroll: bool = False):
    """AOT lower+compile one (cfg, shape) on ``mesh``; returns compiled."""
    batch = M.batch_spec(cfg, shape)
    b_specs = batch_specs(cfg, shape, batch, mesh)
    dp = [a for a in mesh.axis_names if a in ("pod", "data")]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    seq_parallel = shape.global_batch < dp_size
    if shape.kind == "train":
        state = S.abstract_train_state(cfg)
        s_specs = param_specs(state, mesh)
        fn = S.build_train_step(cfg, remat=remat, scan_unroll=scan_unroll)
        with mesh, activation_sharding(mesh, seq_parallel):
            lowered = jax.jit(
                fn, in_shardings=(s_specs, b_specs),
                out_shardings=(s_specs, None),
            ).lower(state, batch)
            return lowered.compile()
    if shape.kind == "prefill":
        params = M.abstract_params(cfg)
        p_specs = param_specs(params, mesh)
        fn = S.build_serve_step(cfg, "prefill", scan_unroll=scan_unroll)
        with mesh, activation_sharding(mesh, seq_parallel):
            lowered = jax.jit(
                fn, in_shardings=(p_specs, b_specs)
            ).lower(params, batch)
            return lowered.compile()
    params = M.abstract_params(cfg)
    p_specs = param_specs(params, mesh)
    caches = jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len)
    )
    c_specs = cache_specs(cfg, shape, caches, mesh)
    fn = S.build_serve_step(cfg, "decode", scan_unroll=scan_unroll)
    with mesh, activation_sharding(mesh, seq_parallel):
        lowered = jax.jit(
            fn,
            in_shardings=(p_specs, c_specs, b_specs),
            out_shardings=(None, c_specs),
        ).lower(params, caches, batch)
        return lowered.compile()


def _costs(compiled) -> Tuple[float, float, float, Dict[str, int]]:
    cost = compiled.cost_analysis()
    coll = R.collective_bytes_from_hlo(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(sum(coll.values())),
        coll,
    )


def _depth_variants(cfg: ArchConfig) -> Tuple[ArchConfig, ArchConfig, int]:
    segs = build_segments(cfg)
    main = max(segs, key=lambda s: s.n_layers)
    period = len(main.pattern)
    other = cfg.n_layers - main.n_layers
    c1 = dataclasses.replace(cfg, n_layers=other + period)
    c2 = dataclasses.replace(cfg, n_layers=other + 2 * period)
    return c1, c2, main.repeats


def roofline_estimate(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      remat: str = "full") -> Tuple[float, float, float, Dict]:
    c1, c2, repeats = _depth_variants(cfg)
    k1 = compile_cell(c1, shape, mesh, remat=remat, scan_unroll=True)
    f1, b1, cb1, pk1 = _costs(k1)
    k2 = compile_cell(c2, shape, mesh, remat=remat, scan_unroll=True)
    f2, b2, cb2, pk2 = _costs(k2)
    n = repeats - 1
    flops = f1 + (f2 - f1) * n
    bts = b1 + (b2 - b1) * n
    coll = cb1 + (cb2 - cb1) * n
    per_kind = {
        k: int(pk1.get(k, 0) + (pk2.get(k, 0) - pk1.get(k, 0)) * n)
        for k in set(pk1) | set(pk2)
    }
    return flops, bts, coll, per_kind


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool,
                remat: str = "full", verbose: bool = True,
                with_roofline: bool = True,
                cfg_override: Optional[ArchConfig] = None) -> Dict[str, Any]:
    cfg = cfg_override or get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()

    compiled = compile_cell(cfg, shape, mesh, remat=remat)
    mem = compiled.memory_analysis()
    out: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": mesh.devices.size,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }

    if with_roofline:
        flops, bts, coll, per_kind = roofline_estimate(
            cfg, shape, mesh, remat=remat
        )
        report = R.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name,
            chips=mesh.devices.size,
            hlo_flops=flops, hlo_bytes=bts, collective_bytes=coll,
            per_kind=per_kind, model_flops=R.model_flops(cfg, shape),
        )
        out["cost"] = {
            "flops_per_device": flops,
            "bytes_per_device": bts,
            "collective_bytes_per_device": coll,
            "collectives": per_kind,
        }
        out["roofline"] = {
            "t_compute_ms": report.t_compute * 1e3,
            "t_memory_ms": report.t_memory * 1e3,
            "t_collective_ms": report.t_collective * 1e3,
            "bottleneck": report.bottleneck,
            "model_flops": report.model_flops,
            "useful_ratio": report.useful_ratio,
            "roofline_fraction": report.roofline_fraction,
        }
        if verbose:
            print(
                f"[OK] {arch} × {shape_name} × {mesh_name}: "
                f"compile {out['compile_seconds']}s | "
                f"comp {report.t_compute*1e3:.1f} "
                f"mem {report.t_memory*1e3:.1f} "
                f"coll {report.t_collective*1e3:.1f} ms → "
                f"{report.bottleneck}; useful {report.useful_ratio:.2f}; "
                f"roofline {report.roofline_fraction:.1%}; "
                f"peak_mem {out['memory']['peak_bytes'] and out['memory']['peak_bytes']/1e9:.2f}GB",
                flush=True,
            )
    elif verbose:
        print(f"[OK] {arch} × {shape_name} × {mesh_name}: "
              f"compile {out['compile_seconds']}s, "
              f"peak_mem {out['memory']['peak_bytes'] and out['memory']['peak_bytes']/1e9:.2f}GB",
              flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-roofline", action="store_true",
                    help="pass/fail + memory only (faster)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = all_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            cfg = get_arch(arch)
            ok, why = runnable(cfg, SHAPES[shape])
            if not ok:
                print(f"[SKIP] {arch} × {shape}: {why}", flush=True)
                results.append({"arch": arch, "shape": shape, "skipped": why})
                continue
            for mp in meshes:
                try:
                    results.append(
                        dryrun_cell(arch, shape, mp, remat=args.remat,
                                    with_roofline=not args.no_roofline)
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[FAIL] {arch} × {shape} × "
                          f"{'multi' if mp else 'single'}: {e}", flush=True)
                    traceback.print_exc()
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=2)
    print(f"\n{len(results)} results, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
