"""Activation sharding constraints.

XLA SPMD propagation sometimes resolves an (FSDP-sharded weight ×
batch-sharded activation) matmul by all-gathering the *activation* batch —
e.g. a 40 GB gather of (B, S, V) logits instead of a 0.6 GB weight gather.
Model code calls :func:`constrain` at block boundaries with a semantic kind;
the active mesh (set by the trainer/dry-run via :func:`activation_sharding`)
turns that into ``with_sharding_constraint``.  Without an active context the
calls are no-ops (CPU smoke tests).

``seq_parallel`` switches batch-dim sharding to sequence-dim sharding for
the batch=1 long-context cells.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain"]

_CTX = threading.local()


@contextmanager
def activation_sharding(mesh: Mesh, seq_parallel: bool = False):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, seq_parallel)
    try:
        yield
    finally:
        _CTX.state = prev


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= mesh.shape[n]
        return s
    return mesh.shape[name]


# kind → per-dim logical roles; "b"=batch, "s"=sequence, "m"=model/TP, None
_KINDS = {
    "btd": ("b", "s", None),          # (B, S, d_model)
    "bshd": ("b", "s", "m", None),    # (B, S, heads, head_dim)
    "btf": ("b", "s", "m"),           # (B, S, d_ff | H*hd fused)
    "logits": ("b", "s", "m"),        # (B, S, vocab)
    "ged": ("b", "m", None, None),    # (G, E, C, d) moe expert buffers
    "gsd": ("b", None, None),         # (G, S_g, d) moe group tokens
    "bhst": ("b", "m", None, None),   # (B, H, Sq, Sk) attention scores
    "bshr": ("b", "s", "m", None),    # (B, S, H, latent) MLA q_eff/ctx
}


def constrain(x: jax.Array, kind: str) -> jax.Array:
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, seq_parallel = state
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data")) or None
    tp = "model" if "model" in mesh.axis_names else None
    roles = _KINDS[kind]
    if len(roles) != x.ndim:
        return x
    spec = []
    for dim, role in zip(x.shape, roles):
        name = None
        if role == "b":
            name = None if seq_parallel else dp
        elif role == "s":
            name = dp if seq_parallel else None
        elif role == "m":
            name = tp
        if name is not None and dim % _axis_size(mesh, name) != 0:
            name = None
        spec.append(name)
    if kind == "bshd" and tp is not None and spec[2] is None:
        # few-KV-head GQA: the heads axis does not divide TP — shard the
        # head_dim instead (keeps the projection reshape and the KV-cache
        # scatter on one consistent layout, no involuntary regather)
        if x.shape[3] % _axis_size(mesh, tp) == 0:
            spec[3] = tp
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
