"""Logical-axis sharding rules → PartitionSpec trees.

2-D parallelism: FSDP over ``(pod, data)`` (weights' non-TP dimension), TP/EP
over ``model``.  ``long_500k`` (batch=1) switches batch sharding to sequence
parallelism over the data axes.  Every rule is divisibility-checked against
the mesh; an axis that does not divide is dropped (e.g. hubert's 504-way
vocab is not sharded 16-way).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

__all__ = ["param_specs", "batch_specs", "cache_specs", "fit_spec",
           "dp_axes", "make_sharding"]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dimensions the mesh does not divide."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, name in zip(shape, parts):
        if name is not None and dim % _axis_size(mesh, name) == 0:
            out.append(name)
        else:
            out.append(None)
    return P(*out)


def make_sharding(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> NamedSharding:
    return NamedSharding(mesh, fit_spec(spec, shape, mesh))


# --------------------------------------------------------------------------- #
# parameter rules
# --------------------------------------------------------------------------- #
def _rule(path_names: Tuple[str, ...], ndim: int, fsdp, tp,
          expert_axes=None) -> P:
    leaf = path_names[-1]
    stacked = 1 if "segments" in path_names else 0

    def pad(spec: Sequence) -> P:
        return P(*([None] * stacked + list(spec)))

    base = ndim - stacked
    ep = expert_axes or tp
    if leaf in ("wo",) and base == 3:  # moe out: (E, ff, d)
        return pad((ep, None, fsdp))
    if leaf in ("wi", "wg") and base == 3:  # moe in: (E, d, ff)
        return pad((ep, fsdp, None))
    if leaf == "embed":
        return P(tp, fsdp)
    if leaf == "lm_head":
        return P(fsdp, tp)
    if leaf == "router":
        return pad((fsdp, None))
    if leaf in ("wq", "wk", "wv", "wi", "wg", "wx", "wz", "wdt",
                "wq_a", "wq_b", "wkv_a", "wkv_b"):
        return pad((fsdp, tp))
    if leaf in ("wo",):
        return pad((tp, fsdp))
    if leaf in ("wB", "wC"):
        return pad((fsdp, None))
    if leaf == "conv":
        return pad((None, tp))
    if leaf in ("bq", "bk", "bv") and base == 1:
        return pad((tp,))
    # norms, scalars, biases: replicated (stacked dim unsharded)
    return pad([None] * base)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(f"[{p.idx}]")
        else:
            names.append(str(p))
    return tuple(n for n in names if not n.startswith("["))


def param_specs(params: Any, mesh: Mesh, multi_pod: Optional[bool] = None,
                serving: bool = False) -> Any:
    """Training: FSDP over (pod, data) × TP over model.  Serving
    (``serving=True``): weights are TP-sharded only — no per-step FSDP
    gathers — and MoE experts shard over (data × model) jointly (full
    expert parallelism), the standard inference topology."""
    fsdp = None if serving else (tuple(dp_axes(mesh)) or None)
    tp = "model" if "model" in mesh.axis_names else None
    expert_axes = None
    if serving and tp is not None:
        expert_axes = tuple(
            a for a in mesh.axis_names if a in ("data", "model")
        )

    def assign(path, leaf):
        spec = _rule(_path_names(path), len(leaf.shape), fsdp, tp,
                     expert_axes=expert_axes)
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, params)


# --------------------------------------------------------------------------- #
# batch / cache rules
# --------------------------------------------------------------------------- #
def batch_specs(cfg: ArchConfig, shape: ShapeConfig, batch: Any, mesh: Mesh
                ) -> Any:
    dp = dp_axes(mesh)
    seq_parallel = shape.global_batch < _axis_size(mesh, dp)

    def assign(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        if seq_parallel:
            # batch too small: shard sequence dim (SP) instead
            if nd >= 2:
                spec = P(None, dp, *([None] * (nd - 2)))
            else:
                spec = P(None)
        else:
            spec = P(dp, *([None] * (nd - 1)))
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map(
        lambda leaf: None, batch
    ) if batch is None else jax.tree_util.tree_map_with_path(assign, batch)


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, caches: Any, mesh: Mesh
                ) -> Any:
    """Decode caches: batch over dp, heads over model; for batch=1 long
    contexts, shard the time dimension over dp (sequence parallelism)."""
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    seq_parallel = shape.global_batch < _axis_size(mesh, dp)

    def assign(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        leaf_name = names[-1] if names else ""
        if leaf_name == "state":  # (r, B, h, p, n)
            spec = P(None, None if seq_parallel else dp, tp, None, None)
        elif leaf_name == "conv":  # (r, B, W-1, d_in)
            spec = P(None, None if seq_parallel else dp, None, tp)
        elif nd == 6:  # gqa kv cache (r, 2, B, T, kv, hd)
            kv, hd = leaf.shape[4], leaf.shape[5]
            tp_size = _axis_size(mesh, tp)
            # few-KV-head GQA: shard head_dim over TP instead (matches the
            # activation-side fallback; keeps the cache 16-way sharded)
            heads_ok = tp is not None and kv % tp_size == 0
            kv_s = tp if heads_ok else None
            hd_s = None if heads_ok else (
                tp if tp is not None and hd % tp_size == 0 else None
            )
            spec = (
                P(None, None, None, dp, kv_s, hd_s)
                if seq_parallel
                else P(None, None, dp, None, kv_s, hd_s)
            )
        elif nd == 4:  # mla latent cache (r, B, T, w) — width over TP
            w_s = tp if tp is not None and leaf.shape[3] % _axis_size(
                mesh, tp) == 0 else None
            spec = (
                P(None, None, dp, w_s)
                if seq_parallel
                else P(None, dp, None, w_s)
            )
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, caches)
