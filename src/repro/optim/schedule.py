"""LR schedules + global-norm clipping."""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["warmup_cosine", "clip_by_global_norm"]


def warmup_cosine(step: jnp.ndarray, peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = peak * (s + 1.0) / jnp.maximum(warmup, 1)
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(s < warmup, warm, cos)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    total = jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), total
