"""Int8 gradient compression with error feedback.

At multi-pod scale the cross-pod (DCI) all-reduce is the thinnest link; 4×
compression of the gradient payload with per-tensor scale + residual error
feedback is the standard trick (1-bit Adam / DALL·E-style EF).  The codec is
exposed as a pure transform so the trainer can apply it to the cross-pod
segment of the reduction; tests assert the EF residual keeps the compressed
sum unbiased over steps.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "ef_compress_grads"]


def compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Quantize (grads + residual) to int8; returns (dequantized grads for
    the optimizer, new residual).  Residual carries quantization error to
    the next step (error feedback) so the long-run update is unbiased."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = compress(x)
        deq = decompress(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
        jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
    )


def init_residual(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, dtype=jnp.float32), grads_like
    )
