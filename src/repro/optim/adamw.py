"""AdamW (decoupled weight decay, f32 moments, arbitrary param dtype)."""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update"]


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adamw_update(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    lr: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        mhat = m2 / (1 - b1**c)
        vhat = v2 / (1 - b2**c)
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
