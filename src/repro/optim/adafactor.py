"""Adafactor (factored second moment, β1=0) — O(sum-of-dims) optimizer state,
used for the 671B-scale config where Adam moments would not fit HBM."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adafactor_init", "adafactor_update"]


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params: Any) -> Dict[str, Any]:
    def init(p):
        if _factored(p.shape):
            row = jnp.zeros(p.shape[:-1], dtype=jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=jnp.float32)
            return {"row": row, "col": col}
        return {"v": jnp.zeros(p.shape, dtype=jnp.float32)}

    return {
        "stats": jax.tree_util.tree_map(
            init, params, is_leaf=lambda x: hasattr(x, "shape")
        ),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def adafactor_update(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    lr: jnp.ndarray,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Tuple[Any, Dict[str, Any]]:
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** -decay

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if _factored(p.shape):
            row = beta2 * s["row"] + (1 - beta2) * g2.mean(axis=-1)
            col = beta2 * s["col"] + (1 - beta2) * g2.mean(axis=-2)
            row_mean = row.mean(axis=-1, keepdims=True)
            vhat = (row / jnp.maximum(row_mean, eps))[..., None] * col[..., None, :]
            new_s = {"row": row, "col": col}
        else:
            vhat = beta2 * s["v"] + (1 - beta2) * g2
            new_s = {"v": vhat}
        u = g32 * jax.lax.rsqrt(vhat + eps)
        norm = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, norm / clip_threshold)
        step = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_s

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    stats_leaves = []
    # stats tree has dict leaves; re-flatten against params structure
    def collect(s):
        stats_leaves.append(s)
    jax.tree_util.tree_map(
        lambda p: None, params
    )
    flat_s = _flatten_stats(state["stats"], params)
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_stats = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_p, {"stats": new_stats, "count": count}


def _flatten_stats(stats: Any, params: Any):
    flat_p, _ = jax.tree_util.tree_flatten(params)
    is_stat = lambda x: isinstance(x, dict) and ("v" in x or "row" in x)
    flat_s = jax.tree_util.tree_leaves(stats, is_leaf=is_stat)
    assert len(flat_s) == len(flat_p)
    return flat_s
