from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.schedule import clip_by_global_norm, warmup_cosine
from repro.optim.compression import (
    compress,
    decompress,
    ef_compress_grads,
    init_residual,
)

__all__ = [
    "adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
    "clip_by_global_norm", "warmup_cosine",
    "compress", "decompress", "ef_compress_grads", "init_residual",
]
