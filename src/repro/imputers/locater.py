"""LOCATER-style time-series imputation (non-blocking, expensive per value).

LOCATER [Lin et al., VLDB'21] imputes a device's missing location at time t
from the device's *historical* pattern.  We reproduce that shape: per-entity
(e.g. mac address) empirical distribution of the target attribute keyed by a
coarse time slot; fallback to the entity's global mode, then the column
mode.  One tuple at a time ⇒ non-blocking (paper §2.1); inference is
expensive ⇒ ``cost_per_value`` models the per-call latency the paper
measures for LOCATER.
"""

from __future__ import annotations

from collections import Counter, defaultdict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.relation import MaskedRelation
from repro.imputers.base import Imputer

__all__ = ["LocaterImputer"]


class LocaterImputer(Imputer):
    blocking = False

    def __init__(self, entity_attr: Optional[str] = None,
                 time_attr: Optional[str] = None, slot: int = 4,
                 cost_per_value: float = 2e-3):
        self.entity_attr = entity_attr
        self.time_attr = time_attr
        self.slot = slot
        self.cost_per_value = cost_per_value
        self._by_slot: Dict[str, Dict[Tuple[int, int], float]] = {}
        self._by_entity: Dict[str, Dict[int, float]] = {}
        self._global: Dict[str, float] = {}
        self._fitted_cols: set = set()

    # ------------------------------------------------------------------ #
    def _detect(self, table: MaskedRelation) -> Tuple[Optional[str], Optional[str]]:
        ent, tim = self.entity_attr, self.time_attr
        names = table.column_names()
        if ent is None:
            ent = next((n for n in names if "mac" in n or "user" in n or "id" in n), None)
        if tim is None:
            tim = next((n for n in names if "time" in n), None)
        return (ent if ent in names else None, tim if tim in names else None)

    def _fit_attr(self, table: MaskedRelation, attr: str) -> None:
        ent, tim = self._detect(table)
        present = table.is_present(attr)
        vals = table.values(attr)[present]
        if len(vals):
            uniq, counts = np.unique(vals, return_counts=True)
            self._global[attr] = float(uniq[np.argmax(counts)])
        else:
            self._global[attr] = 0.0
        if ent is not None:
            rows = np.nonzero(present & table.is_present(ent))[0]
            ents = table.values(ent)[rows]
            targ = table.values(attr)[rows]
            slot_counter: Dict[Tuple[int, int], Counter] = defaultdict(Counter)
            ent_counter: Dict[int, Counter] = defaultdict(Counter)
            if tim is not None and table.is_present(tim)[rows].all():
                slots = (table.values(tim)[rows] // max(self.slot, 1)).astype(np.int64)
            else:
                slots = np.zeros(len(rows), dtype=np.int64)
            for e, s, v in zip(ents.tolist(), slots.tolist(), targ.tolist()):
                slot_counter[(int(e), int(s))][v] += 1
                ent_counter[int(e)][v] += 1
            self._by_slot[attr] = {
                k: float(c.most_common(1)[0][0]) for k, c in slot_counter.items()
            }
            self._by_entity[attr] = {
                k: float(c.most_common(1)[0][0]) for k, c in ent_counter.items()
            }
        self._fitted_cols.add(attr)

    # ------------------------------------------------------------------ #
    def impute_attr(self, table: MaskedRelation, attr: str, tids: np.ndarray
                    ) -> np.ndarray:
        if attr not in self._fitted_cols:
            self._fit_attr(table, attr)
        ent, tim = self._detect(table)
        out = np.full(len(tids), self._global.get(attr, 0.0))
        if ent is None:
            return out
        ents = table.values(ent)[tids]
        e_present = table.is_present(ent)[tids]
        if tim is not None:
            slots = (table.values(tim)[tids] // max(self.slot, 1)).astype(np.int64)
        else:
            slots = np.zeros(len(tids), dtype=np.int64)
        by_slot = self._by_slot.get(attr, {})
        by_ent = self._by_entity.get(attr, {})
        for i in range(len(tids)):
            if not e_present[i]:
                continue
            key = (int(ents[i]), int(slots[i]))
            if key in by_slot:
                out[i] = by_slot[key]
            elif int(ents[i]) in by_ent:
                out[i] = by_ent[int(ents[i])]
        return out
