"""Masked-KNN imputation (blocking; sklearn.impute.KNNImputer semantics).

The reference matrix is the whole table (standardized numeric view, missing
cells masked).  Inference computes partial L2 distances over co-observed
dimensions — the imputation hot spot the paper measures (Fig. 2: KNN
inference dominates query time) — via the Pallas masked-distance kernel on
TPU (pure-jnp oracle on CPU; see ``repro.kernels``).  Neighbour aggregation
(mean / categorical mode) is the vectorized ``kernels.ops.neighbor_aggregate``
op, dispatched with ``QUIP_KNN_IMPL`` (numpy | ref | pallas).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.relation import MaskedRelation
from repro.imputers.base import Imputer
from repro.kernels import ops as kops

__all__ = ["KnnImputer"]


class KnnImputer(Imputer):
    blocking = True

    def __init__(self, k: int = 5, cost_per_value: float = 0.0,
                 train_cost: float = 0.0, impl: Optional[str] = None,
                 agg_impl: Optional[str] = None, batch: int = 1024):
        self.k = k
        self.cost_per_value = cost_per_value
        self.train_cost = train_cost
        self.impl = impl  # masked-distance dispatch (None: backend default)
        self.agg_impl = agg_impl  # neighbour aggregation (None: QUIP_KNN_IMPL)
        self.batch = batch
        self._feat = None  # (n, d) float32, 0-filled
        self._mask = None  # (n, d) float32 observed mask
        self._mean = None
        self._std = None
        self._cols = None

    def fit(self, table: MaskedRelation) -> None:
        cols = table.column_names()
        n = table.num_rows
        feat = np.zeros((n, len(cols)), dtype=np.float32)
        mask = np.zeros((n, len(cols)), dtype=np.float32)
        for i, c in enumerate(cols):
            present = table.is_present(c)
            v = table.values(c).astype(np.float32)
            feat[:, i] = np.where(present, v, 0.0)
            mask[:, i] = present.astype(np.float32)
        denom = np.maximum(mask.sum(axis=0), 1.0)
        mean = (feat * mask).sum(axis=0) / denom
        var = ((feat - mean) ** 2 * mask).sum(axis=0) / denom
        std = np.sqrt(np.maximum(var, 1e-6))
        self._feat = ((feat - mean) / std) * mask
        self._mask = mask
        self._mean, self._std = mean, std
        self._cols = cols

    def impute_attr(self, table: MaskedRelation, attr: str, tids: np.ndarray
                    ) -> np.ndarray:
        ai = self._cols.index(attr)
        ref_rows = self._mask[:, ai] > 0  # neighbours must observe attr
        r, rm = self._feat[ref_rows], self._mask[ref_rows]
        tgt = table.values(attr)[ref_rows.nonzero()[0]]  # aligned targets
        # exclude attr itself from the distance features
        keep = np.ones(self._feat.shape[1], dtype=bool)
        keep[ai] = False
        out = np.zeros(len(tids), dtype=np.float64)
        is_int = not np.issubdtype(table.cols[attr].dtype, np.floating)
        for lo in range(0, len(tids), self.batch):
            idx = tids[lo : lo + self.batch]
            q, qm = self._feat[idx][:, keep], self._mask[idx][:, keep]
            _d, nn = kops.masked_knn(
                q, qm, r[:, keep], rm[:, keep],
                k=min(self.k, r.shape[0]), impl=self.impl,
            )
            nn = np.asarray(nn)
            neigh = tgt[nn]  # (b, k) raw target values
            # vectorized neighbour aggregation: bincount-argmax mode for
            # dictionary-coded categoricals, mean for floats (no per-row
            # Python loop — this is the Fig. 2 inference hot spot)
            out[lo : lo + len(idx)] = kops.neighbor_aggregate(
                neigh, categorical=is_int, impl=self.agg_impl
            )
        return out
