"""Imputer interface + the columnar imputation service QUIP operators call into.

Imputers follow the paper's blocking / non-blocking taxonomy (§2.1):

* non-blocking — impute per tuple(-batch) from local/streamed state
  (mean-by-histogram, LOCATER-style time series);
* blocking — require a training pass over the table first (KNN's reference
  matrix, GBDT).  Training cost is charged once on first use; inference cost
  per value afterwards.

The service is columnar and batched: per (table, attr) it keeps a dense
value array plus a filled-bitmask the size of the base table (no Python
dicts on the hot path), deduplicates requested tids with ``np.unique``
against the mask, and exposes a request-queue API — operators ``enqueue``
tid sets as they stream and the service coalesces them across morsels and
pipeline copies, computing each batch in a single ``impute_attr`` call at
``flush`` time.  The same missing value imputed through two pipeline copies
is computed (and counted) once, and all copies observe the same value —
this is what makes snapshot writeback consistent.

``cost_per_value`` lets benchmarks model expensive imputers (KNN inference,
LOCATER) without wall-clock sleeps: simulated seconds flow into both the
decision-function statistics and the reported runtimes.

The dense caches and fitted models live in an :class:`ImputeStore`.  Each
service creates a private store by default (per-query isolation — seed
semantics); the serving layer (``repro.service``) injects one shared store
into many per-query services so values imputed by query A are visible to
query B (see ``docs/serving.md`` for the consistency argument).
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.lockcheck import make_lock
from repro.core.env import env_flag
from repro.core.relation import MaskedRelation
from repro.core.stats import ExecutionCounters, RuntimeStats
from repro.obs.trace import NULL_SPAN, NULL_TRACER

__all__ = ["Imputer", "ImputeStore", "ImputationService", "ImputationEngine"]


class Imputer:
    """Per-(table) imputation model; ``impute_attr`` fills one attribute.

    ``impute_attr`` receives a *deduplicated, sorted* int64 batch of base-row
    ids and must return one value per id (any float/int array — the service
    owns the final cast to the column dtype).  Implementations should be
    batched/vectorized: the service calls them once per flush, not per row.
    """

    blocking: bool = False
    cost_per_value: float = 0.0  # simulated seconds per imputed value
    train_cost: float = 0.0  # simulated seconds, charged once (blocking)

    def fit(self, table: MaskedRelation) -> None:  # pragma: no cover
        pass

    def impute_attr(
        self, table: MaskedRelation, attr: str, tids: np.ndarray
    ) -> np.ndarray:
        raise NotImplementedError


def _resolve_batching(batching: Optional[bool]) -> bool:
    """Explicit argument > ``QUIP_IMPUTE_BATCH`` env (truthy/falsy via
    :func:`env_flag`) > on."""
    if batching is not None:
        return bool(batching)
    return env_flag("QUIP_IMPUTE_BATCH", True)


class _KeyLock:
    """Non-reentrant per-(table, attr) flush lock.

    Serializes cross-thread flushes of one column (the worker pool's
    "computed once" guarantee) while failing loud — instead of
    deadlocking — if an imputer recursively requests the very attribute
    it is computing on the same thread."""

    __slots__ = ("_lock", "_owner")

    def __init__(self):
        # every (table, attr) key lock shares one sanitizer node: the
        # acquisition *order* discipline is per-class, not per-instance
        self._lock = make_lock("ImputeStore.key")
        # reentrancy tattle only; reads race benignly (a stale non-match
        # just proceeds to the blocking acquire)
        self._owner: Optional[int] = None  # guarded-by: _lock

    def __enter__(self) -> "_KeyLock":  # requires: _lock
        me = threading.get_ident()
        if self._owner == me:
            raise RuntimeError(
                "reentrant flush of one (table, attr) — an imputer must "
                "not request the attribute it is currently computing"
            )
        self._lock.acquire()
        self._owner = me
        return self

    def __exit__(self, *exc) -> None:  # requires: _lock
        self._owner = None
        self._lock.release()


class ImputeStore:
    """Dense imputation state, extracted from the service so it can outlive
    (and be shared between) queries.

    Owns, per ``(table, attr)``: the float64 value column, the filled
    bitmask, the fitted model, and — when ``track_owners`` — an int32 array
    recording which service (``owner_id``) filled each cell, the basis of
    the serving layer's cross-query-hit telemetry.  By default every
    :class:`ImputationService` creates a private store (per-query isolation,
    seed semantics); ``repro.service.impute_store.SharedImputeStore`` binds
    one store to many per-query services.

    Flush discipline (thread-safe since the worker pool): store writes
    happen only under a per-(table, attr) :class:`_KeyLock`
    (:meth:`flush_lock`), so two worker threads flushing the same column
    serialize — the second finds the cells filled and computes nothing —
    while different columns flush in parallel.  Multi-key queue flushes
    (``ImputationService.flush``) additionally serialize store-wide through
    ``begin_flush``/``end_flush``, now a real :class:`threading.Lock`:
    a concurrent flush *blocks* and a same-thread reentrant flush (an
    imputer calling ``flush`` from inside ``impute_attr``) still raises
    loudly instead of deadlocking.  Registry metadata (cache / model /
    lock registries) is guarded by a separate meta lock."""

    def __init__(self, tables: Dict[str, MaskedRelation],
                 track_owners: bool = False):
        self.tables = tables
        self.track_owners = bool(track_owners)
        # dict *shape* mutates under the meta lock; the element writes of
        # one column happen under that key's flush lock (``fill``)
        self._values: Dict[Tuple[str, str], np.ndarray] = {}  # guarded-by: _meta_lock|flush_lock
        self._filled: Dict[Tuple[str, str], np.ndarray] = {}  # guarded-by: _meta_lock|flush_lock
        self._owner: Dict[Tuple[str, str], np.ndarray] = {}  # guarded-by: _meta_lock|flush_lock
        self._models: Dict[Tuple[str, str], Imputer] = {}  # guarded-by: _meta_lock
        self._fitted: set = set()  # guarded-by: _meta_lock
        # registry metadata guard: dict/set mutation only, never held
        # across model fits or imputations
        self._meta_lock = make_lock("ImputeStore._meta_lock")
        # store-wide multi-key flush serialization + reentrancy detection
        self._flush_serial = make_lock("ImputeStore._flush_serial")
        self._flush_owner: Optional[int] = None  # guarded-by: _flush_serial
        self._key_locks: Dict[Tuple[str, str], _KeyLock] = {}  # guarded-by: _meta_lock

    # -- column caches ----------------------------------------------------#
    def column_cache(self, table: str, attr: str
                     ) -> Tuple[np.ndarray, np.ndarray]:
        key = (table, attr)
        vals = self._values.get(key)
        if vals is not None:
            return vals, self._filled[key]
        with self._meta_lock:
            if key not in self._values:
                n = self.tables[table].num_rows
                self._values[key] = np.zeros(n, dtype=np.float64)
                self._filled[key] = np.zeros(n, dtype=bool)
                if self.track_owners:
                    self._owner[key] = np.full(n, -1, dtype=np.int32)
            return self._values[key], self._filled[key]

    def owners(self, table: str, attr: str) -> Optional[np.ndarray]:
        return self._owner.get((table, attr))

    def fill(self, table: str, attr: str, tids: np.ndarray,
             values: np.ndarray, owner_id: int) -> None:  # requires: flush_lock
        vals, filled = self.column_cache(table, attr)
        vals[tids] = values
        filled[tids] = True
        if self.track_owners:
            self._owner[(table, attr)][tids] = owner_id

    def filled_cells(self) -> int:
        """Total imputed cells in the store (serving telemetry)."""
        with self._meta_lock:
            masks = list(self._filled.values())
        return int(sum(m.sum() for m in masks))

    def snapshot_tids(self, table: Optional[str] = None
                      ) -> Dict[Tuple[str, str], np.ndarray]:
        """Filled base-row ids per ``(table, attr)`` (uncast values live in
        the dense cache; callers cast via the service)."""
        out: Dict[Tuple[str, str], np.ndarray] = {}
        with self._meta_lock:
            items = list(self._filled.items())
        for (t, a), filled in items:
            if table is not None and t != table:
                continue
            tids = np.nonzero(filled)[0].astype(np.int64)
            if len(tids):
                out[(t, a)] = tids
        return out

    def values_at(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        return self._values[(table, attr)][tids]

    def invalidate(self, table: str) -> int:
        """Drop everything derived from ``table``: the dense value/filled
        (/owner) caches for each of its attrs and its fitted models.

        Called by the serving layer when the registry mutates the table —
        cached cells were imputed from (and models fitted on) the old rows,
        and the dense arrays are sized to the old row count.  The caches
        rebuild lazily at the *new* row count on the next ``column_cache``
        touch, and models refit on the mutated table.  Returns the number
        of cached cells dropped (invalidation telemetry)."""
        dropped = 0
        with self._meta_lock:
            for key in [k for k in self._values if k[0] == table]:
                dropped += int(self._filled[key].sum())
                del self._values[key]
                del self._filled[key]
                self._owner.pop(key, None)
            for key in [k for k in self._models if k[0] == table]:
                del self._models[key]
            self._fitted = {fk for fk in self._fitted if fk[0] != table}
        return dropped

    # -- flush locks ------------------------------------------------------#
    def flush_lock(self, table: str, attr: str) -> _KeyLock:
        """The per-(table, attr) lock every store write of that column
        must run under — same-key flushes serialize (and re-dedup against
        the filled mask, so each cell is computed once), different keys
        proceed in parallel."""
        key = (table, attr)
        lock = self._key_locks.get(key)
        if lock is not None:
            return lock
        with self._meta_lock:
            return self._key_locks.setdefault(key, _KeyLock())

    def begin_flush(self) -> None:  # requires: _flush_serial
        """Serialize a store-wide (multi-key) flush.  A concurrent flush
        from another thread blocks; a *reentrant* flush on the same thread
        (an imputer calling ``flush`` from inside ``impute_attr``) raises
        loudly — the pre-pool guard, now backed by a real lock instead of
        a boolean."""
        me = threading.get_ident()
        if self._flush_owner == me:
            raise RuntimeError(
                "concurrent/reentrant flush against a shared ImputeStore — "
                "flushes must be serialized (one scheduler step at a time)"
            )
        self._flush_serial.acquire()
        self._flush_owner = me

    def end_flush(self) -> None:  # requires: _flush_serial
        self._flush_owner = None
        self._flush_serial.release()

    # -- model registry ---------------------------------------------------#
    def model_for(self, table: str, attr: str,
                  default: Callable[[], "Imputer"],
                  per_attr: Dict[str, "Imputer"]
                  ) -> Tuple["Imputer", Optional[float]]:
        """Fitted model for ``table.attr``; returns ``(model, train_wall)``
        where ``train_wall`` is the fit's wall seconds on the call that
        trained it and ``None`` otherwise (the caller charges training cost
        to its own query's counters — under a shared store only the first
        query pays).

        Callers hold the key's :meth:`flush_lock`, which serializes the
        fit of a given (table, attr) model; only the registry dicts need
        the meta lock.  (A single ``per_attr`` Imputer instance shared
        across *tables* would fit concurrently — per-attr injection is a
        per-table construct; don't share instances across threads.)"""
        key = (table, attr)
        with self._meta_lock:
            model = self._models.get(key)
            if model is None:
                model = per_attr.get(attr) or default()
                self._models[key] = model
            fit_key = (table, id(model))
            need_fit = fit_key not in self._fitted
            if need_fit:
                self._fitted.add(fit_key)
        train_wall: Optional[float] = None
        if need_fit:
            t0 = time.perf_counter()
            model.fit(self.tables[table])
            train_wall = time.perf_counter() - t0
        return model, train_wall


class ImputationService:
    """Columnar, request-queued imputation engine.

    Lifecycle per (table, attr):

    1. operators ``enqueue(table, attr, tids)`` — O(1) append, no dedup yet;
    2. ``flush()`` at a decision point concatenates the queue, vectorized-
       dedups it (``np.unique`` + the dense filled mask), runs the model
       once over the still-missing tids, and writes the results into the
       dense column cache;
    3. ``lookup(table, attr, tids)`` gathers values (cast to the column
       dtype, round-half-even for integer columns).

    ``impute`` = enqueue + flush + lookup, the synchronous convenience the
    seed engine exposed; dedup/caching semantics are identical, so answers
    and ``counters.imputations`` are unchanged — only the *number of model
    invocations* (``counters.impute_batches``) shrinks when call sites
    enqueue several morsels before flushing.

    :meth:`request` is the thread-safe form of that triple: one (table,
    attr) batch deduplicated, computed, and gathered atomically under the
    store's per-key flush lock.  The queue API is *not* safe under
    concurrent sibling morsels (thread B's ``flush`` could swap the queue
    and still be computing when thread A's ``lookup`` runs), so the
    morsel-parallel executor routes every operator-boundary imputation
    through ``request``; the queue remains for single-threaded
    cross-operator coalescing (``execute_offline``).
    """

    def __init__(
        self,
        tables: Dict[str, MaskedRelation],
        default: Callable[[], Imputer],
        per_attr: Optional[Dict[str, Imputer]] = None,
        stats: Optional[RuntimeStats] = None,
        counters: Optional[ExecutionCounters] = None,
        batching: Optional[bool] = None,
        store: Optional[ImputeStore] = None,
        owner_id: int = 0,
        tracer=None,
        provenance=None,
    ):
        # with an injected (shared) store, all dense state lives there and
        # ``tables`` must be the store's registry for tids to line up
        self.store = store if store is not None else ImputeStore(tables)
        self.tables = self.store.tables if store is not None else tables
        self.owner_id = int(owner_id)
        self._default = default
        self._per_attr = dict(per_attr or {})
        self.stats = stats or RuntimeStats()
        self.counters = counters or ExecutionCounters()  # guarded-by: _tel_lock
        self.batching = _resolve_batching(batching)
        # observability (repro.obs): the span tracer is never None (the
        # shared NULL_TRACER is a zero-allocation no-op); the provenance
        # recorder is None unless the serving layer asked for explain
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.provenance = provenance
        # request queue: (table, attr) -> list of enqueued tid arrays
        # (always per-service — only flushed results land in the store)
        self._queue: Dict[Tuple[str, str], List[np.ndarray]] = {}  # guarded-by: _qlock
        self.simulated_seconds: float = 0.0  # guarded-by: _tel_lock
        # queue swap guard + telemetry guard: intra-query parallel morsels
        # share this service, and lost counter updates would corrupt the
        # imputations/flushes accounting the benchmarks assert on
        self._qlock = make_lock("ImputationService._qlock")
        self._tel_lock = make_lock("ImputationService._tel_lock")

    # ------------------------------------------------------------------ #
    def _model_for(self, table: str, attr: str) -> Imputer:
        model, train_wall = self.store.model_for(
            table, attr, self._default, self._per_attr
        )
        if train_wall is not None and model.blocking:
            with self._tel_lock:
                self.simulated_seconds += model.train_cost
                self.counters.imputation_seconds += (
                    train_wall + model.train_cost
                )
        return model

    def _column_cache(self, table: str, attr: str
                      ) -> Tuple[np.ndarray, np.ndarray]:
        return self.store.column_cache(table, attr)

    def _cast(self, table: str, attr: str, values: np.ndarray) -> np.ndarray:
        dtype = self.tables[table].cols[attr].dtype
        if np.issubdtype(dtype, np.floating):
            return values.astype(dtype)
        if not np.isfinite(values).all():
            # np.round(nan).astype(int) would silently yield INT64_MIN; the
            # seed engine's per-element cast raised here, so keep failing loud
            raise ValueError(
                f"non-finite imputation for int column {table}.{attr}"
            )
        # round-half-even before the integer cast: a float imputation (KNN
        # mean 2.7) must round, not truncate, into an int column
        return np.round(values).astype(dtype)

    # ------------------------------------------------------------------ #
    # request-queue API
    # ------------------------------------------------------------------ #
    def enqueue(self, table: str, attr: str, tids: np.ndarray) -> None:
        """Queue base-row ids of ``table.attr`` for the next ``flush``."""
        tids = np.asarray(tids, dtype=np.int64)
        if len(tids) == 0:
            return
        with self._qlock:
            self._queue.setdefault((table, attr), []).append(tids)

    def pending_requests(self) -> int:
        """Queued (pre-dedup) request count — flush/batch telemetry."""
        with self._qlock:
            return sum(
                len(t) for parts in self._queue.values() for t in parts
            )

    def _flush_key(self, table: str, attr: str, tids: np.ndarray) -> None:
        """Dedup-compute-fill one (table, attr) batch.  Caller holds the
        store's per-key flush lock; the dedup against the filled mask runs
        *under* it, so a concurrent same-key flush that lost the race finds
        the cells filled and computes nothing — each cell is paid for once
        no matter how many threads request it."""
        requested = len(tids)
        values, filled = self._column_cache(table, attr)
        uniq = np.unique(tids)  # vectorized dedup (sorted, unique)
        hit_mask = filled[uniq]
        todo = uniq[~hit_mask]
        hits = int(hit_mask.sum())
        cross = 0
        owners = self.store.owners(table, attr)
        if owners is not None and hits:
            # cells another query already paid for (serving telemetry)
            hit_tids = uniq[hit_mask]
            cross = int((owners[hit_tids] != self.owner_id).sum())
            with self._tel_lock:
                self.counters.impute_cross_hits += cross
        if len(todo) == 0:
            if self.provenance is not None:
                # fully-cached batch: still provenance (cross-hit telemetry
                # and the explain report's requested/hit attribution)
                self.provenance.on_flush(table, attr, requested, 0,
                                         hits, cross, 0.0)
            return
        tracer = self.tracer
        span = tracer.span(
            "impute_flush", cat="impute", table=table, attr=attr,
            requested=requested,
        ) if tracer.enabled else NULL_SPAN
        with span:
            model = self._model_for(table, attr)
            t0 = time.perf_counter()
            vals = np.asarray(
                model.impute_attr(self.tables[table], attr, todo),
                dtype=np.float64,
            )
            wall = time.perf_counter() - t0
            sim = model.cost_per_value * len(todo)
            span.set(computed=len(todo), cache_hits=hits)
        with self._tel_lock:
            self.simulated_seconds += sim
            # the ONE place imputations increments — ProvenanceRecorder
            # mirrors exactly this amount below, which is why the explain
            # report reconciles with ExecutionCounters by construction
            self.counters.imputations += len(todo)
            self.counters.impute_batches += 1
            self.counters.imputation_seconds += wall + sim
            self.stats.record_imputation(attr, len(todo), wall + sim)
            self.stats.record_flush(attr, requested, len(todo))
        if self.provenance is not None:
            self.provenance.on_flush(table, attr, requested, len(todo),
                                     hits, cross, wall + sim)
        self.store.fill(table, attr, todo, vals, self.owner_id)

    def flush(self) -> None:
        """Coalesce the queue: per (table, attr), one deduplicated batch
        through the model; results land in the dense column cache (the
        service's private store, or an injected shared one)."""
        with self._qlock:
            if not self._queue:
                return
            queue, self._queue = self._queue, {}
        with self._tel_lock:
            self.counters.impute_flushes += 1
        self.store.begin_flush()
        try:
            for (table, attr), parts in queue.items():
                tids = parts[0] if len(parts) == 1 else np.concatenate(parts)
                with self.store.flush_lock(table, attr):
                    self._flush_key(table, attr, tids)
        finally:
            self.store.end_flush()

    def lookup(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Cached values for ``tids`` (all must have been flushed)."""
        tids = np.asarray(tids, dtype=np.int64)
        values, filled = self._column_cache(table, attr)
        if len(tids) and not filled[tids].all():
            raise KeyError(
                f"lookup of unimputed tids for {table}.{attr}: "
                f"{tids[~filled[tids]][:8].tolist()} (flush() missing?)"
            )
        return self._cast(table, attr, values[tids])

    def request(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Atomic enqueue+flush+lookup for one ``(table, attr)`` batch.

        The morsel-parallel executor's operator boundary: sibling morsels
        of one query — and sessions running on other worker threads over a
        shared store — may impute concurrently, and the shared request
        queue cannot give read-your-writes under that interleaving (a
        sibling's ``flush`` can swap the queue and still be mid-compute at
        this thread's ``lookup``).  Here dedup, model invocation, fill,
        and the gather all run under the store's per-key flush lock, with
        counter semantics identical to the serial triple."""
        tids = np.asarray(tids, dtype=np.int64)
        if len(tids) == 0:
            return self.lookup(table, attr, tids)
        with self.store.flush_lock(table, attr):
            with self._tel_lock:
                self.counters.impute_flushes += 1
            self._flush_key(table, attr, tids)
            values, filled = self._column_cache(table, attr)
            if not filled[tids].all():  # pragma: no cover - invariant
                raise KeyError(
                    f"request left unimputed tids for {table}.{attr}"
                )
            return self._cast(table, attr, values[tids])

    # ------------------------------------------------------------------ #
    def impute(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Values for base-row ids ``tids`` of ``table.attr`` (deduplicated).

        Synchronous convenience: enqueue + flush + lookup in one call."""
        self.enqueue(table, attr, tids)
        self.flush()
        return self.lookup(table, attr, np.asarray(tids, dtype=np.int64))

    # ------------------------------------------------------------------ #
    def writeback_snapshot(
        self, table: Optional[str] = None
    ) -> Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]]:
        """Every imputed cell in this service's store:
        ``{(table, attr): (tids, values)}``.

        Values are dtype-cast exactly as ``lookup`` returns them, so a
        caller materializing them into base tables observes the same values
        every pipeline copy saw — the consistency guarantee of the dedup
        cache, preserved across the batched refactor.  With a private store
        (the default) that is exactly this query's imputations; bound to a
        shared store it is the *store-wide* snapshot — cells other queries
        paid for included, which is sound because imputers are
        deterministic over the immutable registry (every query would have
        computed identical values; see docs/serving.md)."""
        out: Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]] = {}
        for (t, a), tids in self.store.snapshot_tids(table).items():
            out[(t, a)] = (
                tids, self._cast(t, a, self.store.values_at(t, a, tids))
            )
        return out

    # ------------------------------------------------------------------ #
    def total_missing(self, tables: Optional[Dict[str, MaskedRelation]] = None
                      ) -> int:
        tables = tables or self.tables
        return int(
            sum(
                rel.is_missing(a).sum()
                for rel in tables.values()
                for a in rel.column_names()
            )
        )


# The seed engine's name; the service is a drop-in replacement.
ImputationEngine = ImputationService
