"""Imputer interface + the engine QUIP operators call into.

Imputers follow the paper's blocking / non-blocking taxonomy (§2.1):

* non-blocking — impute per tuple(-batch) from local/streamed state
  (mean-by-histogram, LOCATER-style time series);
* blocking — require a training pass over the table first (KNN's reference
  matrix, GBDT).  Training cost is charged once on first use; inference cost
  per value afterwards.

The engine deduplicates by (table, attr, tid) — the same missing value
imputed through two pipeline copies is computed (and counted) once, and all
copies observe the same value (this is what makes snapshot writeback
consistent).  ``cost_per_value`` lets benchmarks model expensive imputers
(KNN inference, LOCATER) without wall-clock sleeps: simulated seconds flow
into both the decision-function statistics and the reported runtimes.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.relation import MaskedRelation
from repro.core.stats import ExecutionCounters, RuntimeStats

__all__ = ["Imputer", "ImputationEngine"]


class Imputer:
    """Per-(table) imputation model; ``impute_attr`` fills one attribute."""

    blocking: bool = False
    cost_per_value: float = 0.0  # simulated seconds per imputed value
    train_cost: float = 0.0  # simulated seconds, charged once (blocking)

    def fit(self, table: MaskedRelation) -> None:  # pragma: no cover
        pass

    def impute_attr(
        self, table: MaskedRelation, attr: str, tids: np.ndarray
    ) -> np.ndarray:
        raise NotImplementedError


class ImputationEngine:
    def __init__(
        self,
        tables: Dict[str, MaskedRelation],
        default: Callable[[], Imputer],
        per_attr: Optional[Dict[str, Imputer]] = None,
        stats: Optional[RuntimeStats] = None,
        counters: Optional[ExecutionCounters] = None,
    ):
        self.tables = tables
        self._default = default
        self._per_attr = dict(per_attr or {})
        self.stats = stats or RuntimeStats()
        self.counters = counters or ExecutionCounters()
        self._models: Dict[Tuple[str, str], Imputer] = {}
        self._fitted: set = set()
        self._cache: Dict[Tuple[str, str], Dict[int, float]] = {}
        self.simulated_seconds: float = 0.0

    # ------------------------------------------------------------------ #
    def _model_for(self, table: str, attr: str) -> Imputer:
        key = (table, attr)
        if key not in self._models:
            self._models[key] = self._per_attr.get(attr) or self._default()
        model = self._models[key]
        fit_key = (table, id(model))
        if fit_key not in self._fitted:
            t0 = time.perf_counter()
            model.fit(self.tables[table])
            train_wall = time.perf_counter() - t0
            self._fitted.add(fit_key)
            if model.blocking:
                self.simulated_seconds += model.train_cost
                self.counters.imputation_seconds += train_wall + model.train_cost
        return model

    # ------------------------------------------------------------------ #
    def impute(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Values for base-row ids ``tids`` of ``table.attr`` (deduplicated)."""
        tids = np.asarray(tids, dtype=np.int64)
        cache = self._cache.setdefault((table, attr), {})
        todo = np.array(
            sorted({int(t) for t in tids.tolist() if int(t) not in cache}),
            dtype=np.int64,
        )
        if len(todo):
            model = self._model_for(table, attr)
            t0 = time.perf_counter()
            vals = np.asarray(model.impute_attr(self.tables[table], attr, todo))
            wall = time.perf_counter() - t0
            sim = model.cost_per_value * len(todo)
            self.simulated_seconds += sim
            self.counters.imputations += len(todo)
            self.counters.imputation_seconds += wall + sim
            self.stats.record_imputation(attr, len(todo), wall + sim)
            for t, v in zip(todo.tolist(), vals.tolist()):
                cache[t] = v
        dtype = self.tables[table].cols[attr].dtype
        return np.asarray([cache[int(t)] for t in tids.tolist()], dtype=dtype)

    # ------------------------------------------------------------------ #
    def total_missing(self, tables: Optional[Dict[str, MaskedRelation]] = None
                      ) -> int:
        tables = tables or self.tables
        return int(
            sum(
                rel.is_missing(a).sum()
                for rel in tables.values()
                for a in rel.column_names()
            )
        )
