"""Imputer interface + the columnar imputation service QUIP operators call into.

Imputers follow the paper's blocking / non-blocking taxonomy (§2.1):

* non-blocking — impute per tuple(-batch) from local/streamed state
  (mean-by-histogram, LOCATER-style time series);
* blocking — require a training pass over the table first (KNN's reference
  matrix, GBDT).  Training cost is charged once on first use; inference cost
  per value afterwards.

The service is columnar and batched: per (table, attr) it keeps a dense
value array plus a filled-bitmask the size of the base table (no Python
dicts on the hot path), deduplicates requested tids with ``np.unique``
against the mask, and exposes a request-queue API — operators ``enqueue``
tid sets as they stream and the service coalesces them across morsels and
pipeline copies, computing each batch in a single ``impute_attr`` call at
``flush`` time.  The same missing value imputed through two pipeline copies
is computed (and counted) once, and all copies observe the same value —
this is what makes snapshot writeback consistent.

``cost_per_value`` lets benchmarks model expensive imputers (KNN inference,
LOCATER) without wall-clock sleeps: simulated seconds flow into both the
decision-function statistics and the reported runtimes.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.relation import MaskedRelation
from repro.core.stats import ExecutionCounters, RuntimeStats

__all__ = ["Imputer", "ImputationService", "ImputationEngine"]


class Imputer:
    """Per-(table) imputation model; ``impute_attr`` fills one attribute.

    ``impute_attr`` receives a *deduplicated, sorted* int64 batch of base-row
    ids and must return one value per id (any float/int array — the service
    owns the final cast to the column dtype).  Implementations should be
    batched/vectorized: the service calls them once per flush, not per row.
    """

    blocking: bool = False
    cost_per_value: float = 0.0  # simulated seconds per imputed value
    train_cost: float = 0.0  # simulated seconds, charged once (blocking)

    def fit(self, table: MaskedRelation) -> None:  # pragma: no cover
        pass

    def impute_attr(
        self, table: MaskedRelation, attr: str, tids: np.ndarray
    ) -> np.ndarray:
        raise NotImplementedError


def _resolve_batching(batching: Optional[bool]) -> bool:
    """Explicit argument > ``QUIP_IMPUTE_BATCH`` env ("0" disables) > on."""
    if batching is not None:
        return bool(batching)
    return os.environ.get("QUIP_IMPUTE_BATCH", "1") != "0"


class ImputationService:
    """Columnar, request-queued imputation engine.

    Lifecycle per (table, attr):

    1. operators ``enqueue(table, attr, tids)`` — O(1) append, no dedup yet;
    2. ``flush()`` at a decision point concatenates the queue, vectorized-
       dedups it (``np.unique`` + the dense filled mask), runs the model
       once over the still-missing tids, and writes the results into the
       dense column cache;
    3. ``lookup(table, attr, tids)`` gathers values (cast to the column
       dtype, round-half-even for integer columns).

    ``impute`` = enqueue + flush + lookup, the synchronous convenience the
    seed engine exposed; dedup/caching semantics are identical, so answers
    and ``counters.imputations`` are unchanged — only the *number of model
    invocations* (``counters.impute_batches``) shrinks when call sites
    enqueue several morsels before flushing.
    """

    def __init__(
        self,
        tables: Dict[str, MaskedRelation],
        default: Callable[[], Imputer],
        per_attr: Optional[Dict[str, Imputer]] = None,
        stats: Optional[RuntimeStats] = None,
        counters: Optional[ExecutionCounters] = None,
        batching: Optional[bool] = None,
    ):
        self.tables = tables
        self._default = default
        self._per_attr = dict(per_attr or {})
        self.stats = stats or RuntimeStats()
        self.counters = counters or ExecutionCounters()
        self.batching = _resolve_batching(batching)
        self._models: Dict[Tuple[str, str], Imputer] = {}
        self._fitted: set = set()
        # dense per-(table, attr) column caches: float64 values + filled mask
        self._values: Dict[Tuple[str, str], np.ndarray] = {}
        self._filled: Dict[Tuple[str, str], np.ndarray] = {}
        # request queue: (table, attr) -> list of enqueued tid arrays
        self._queue: Dict[Tuple[str, str], List[np.ndarray]] = {}
        self.simulated_seconds: float = 0.0

    # ------------------------------------------------------------------ #
    def _model_for(self, table: str, attr: str) -> Imputer:
        key = (table, attr)
        if key not in self._models:
            self._models[key] = self._per_attr.get(attr) or self._default()
        model = self._models[key]
        fit_key = (table, id(model))
        if fit_key not in self._fitted:
            t0 = time.perf_counter()
            model.fit(self.tables[table])
            train_wall = time.perf_counter() - t0
            self._fitted.add(fit_key)
            if model.blocking:
                self.simulated_seconds += model.train_cost
                self.counters.imputation_seconds += train_wall + model.train_cost
        return model

    def _column_cache(self, table: str, attr: str
                      ) -> Tuple[np.ndarray, np.ndarray]:
        key = (table, attr)
        if key not in self._values:
            n = self.tables[table].num_rows
            self._values[key] = np.zeros(n, dtype=np.float64)
            self._filled[key] = np.zeros(n, dtype=bool)
        return self._values[key], self._filled[key]

    def _cast(self, table: str, attr: str, values: np.ndarray) -> np.ndarray:
        dtype = self.tables[table].cols[attr].dtype
        if np.issubdtype(dtype, np.floating):
            return values.astype(dtype)
        if not np.isfinite(values).all():
            # np.round(nan).astype(int) would silently yield INT64_MIN; the
            # seed engine's per-element cast raised here, so keep failing loud
            raise ValueError(
                f"non-finite imputation for int column {table}.{attr}"
            )
        # round-half-even before the integer cast: a float imputation (KNN
        # mean 2.7) must round, not truncate, into an int column
        return np.round(values).astype(dtype)

    # ------------------------------------------------------------------ #
    # request-queue API
    # ------------------------------------------------------------------ #
    def enqueue(self, table: str, attr: str, tids: np.ndarray) -> None:
        """Queue base-row ids of ``table.attr`` for the next ``flush``."""
        tids = np.asarray(tids, dtype=np.int64)
        if len(tids) == 0:
            return
        self._queue.setdefault((table, attr), []).append(tids)

    def pending_requests(self) -> int:
        """Queued (pre-dedup) request count — flush/batch telemetry."""
        return sum(len(t) for parts in self._queue.values() for t in parts)

    def flush(self) -> None:
        """Coalesce the queue: per (table, attr), one deduplicated batch
        through the model; results land in the dense column cache."""
        if not self._queue:
            return
        queue, self._queue = self._queue, {}
        self.counters.impute_flushes += 1
        for (table, attr), parts in queue.items():
            tids = parts[0] if len(parts) == 1 else np.concatenate(parts)
            requested = len(tids)
            values, filled = self._column_cache(table, attr)
            uniq = np.unique(tids)  # vectorized dedup (sorted, unique)
            todo = uniq[~filled[uniq]]
            if len(todo) == 0:
                continue
            model = self._model_for(table, attr)
            t0 = time.perf_counter()
            vals = np.asarray(
                model.impute_attr(self.tables[table], attr, todo),
                dtype=np.float64,
            )
            wall = time.perf_counter() - t0
            sim = model.cost_per_value * len(todo)
            self.simulated_seconds += sim
            self.counters.imputations += len(todo)
            self.counters.impute_batches += 1
            self.counters.imputation_seconds += wall + sim
            self.stats.record_imputation(attr, len(todo), wall + sim)
            self.stats.record_flush(attr, requested, len(todo))
            values[todo] = vals
            filled[todo] = True

    def lookup(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Cached values for ``tids`` (all must have been flushed)."""
        tids = np.asarray(tids, dtype=np.int64)
        values, filled = self._column_cache(table, attr)
        if len(tids) and not filled[tids].all():
            raise KeyError(
                f"lookup of unimputed tids for {table}.{attr}: "
                f"{tids[~filled[tids]][:8].tolist()} (flush() missing?)"
            )
        return self._cast(table, attr, values[tids])

    # ------------------------------------------------------------------ #
    def impute(self, table: str, attr: str, tids: np.ndarray) -> np.ndarray:
        """Values for base-row ids ``tids`` of ``table.attr`` (deduplicated).

        Synchronous convenience: enqueue + flush + lookup in one call."""
        self.enqueue(table, attr, tids)
        self.flush()
        return self.lookup(table, attr, np.asarray(tids, dtype=np.int64))

    # ------------------------------------------------------------------ #
    def writeback_snapshot(
        self, table: Optional[str] = None
    ) -> Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]]:
        """Every imputed cell so far: ``{(table, attr): (tids, values)}``.

        Values are dtype-cast exactly as ``lookup`` returns them, so a
        caller materializing them into base tables observes the same values
        every pipeline copy saw — the consistency guarantee of the dedup
        cache, preserved across the batched refactor."""
        out: Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]] = {}
        for (t, a), filled in self._filled.items():
            if table is not None and t != table:
                continue
            tids = np.nonzero(filled)[0].astype(np.int64)
            if len(tids):
                out[(t, a)] = (tids, self._cast(t, a, self._values[(t, a)][tids]))
        return out

    # ------------------------------------------------------------------ #
    def total_missing(self, tables: Optional[Dict[str, MaskedRelation]] = None
                      ) -> int:
        tables = tables or self.tables
        return int(
            sum(
                rel.is_missing(a).sum()
                for rel in tables.values()
                for a in rel.column_names()
            )
        )


# The seed engine's name; the service is a drop-in replacement.
ImputationEngine = ImputationService
