from repro.imputers.base import (
    ImputationEngine,
    ImputationService,
    Imputer,
    ImputeStore,
)
from repro.imputers.mean import MeanImputer
from repro.imputers.knn import KnnImputer
from repro.imputers.gbdt import GbdtImputer
from repro.imputers.locater import LocaterImputer

__all__ = [
    "ImputationEngine",
    "ImputationService",
    "Imputer",
    "ImputeStore",
    "MeanImputer",
    "KnnImputer",
    "GbdtImputer",
    "LocaterImputer",
]
