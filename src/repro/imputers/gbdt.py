"""Histogram-GBDT imputation (blocking; XGBoost-style, JAX-vectorized).

Boosted depth-1 regression trees (stumps) on per-feature histograms — the
histogram trick the paper cites as what makes XGBoost/LightGBM training fast
enough for online use (§2.1).  Training dominates inference (paper Fig. 2's
XGBoost profile): ``train_cost`` models it; per-value inference is cheap.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.relation import MaskedRelation
from repro.imputers.base import Imputer

__all__ = ["GbdtImputer"]


class GbdtImputer(Imputer):
    blocking = True

    def __init__(self, rounds: int = 24, bins: int = 32, lr: float = 0.3,
                 cost_per_value: float = 0.0, train_cost: float = 0.0):
        self.rounds = rounds
        self.bins = bins
        self.lr = lr
        self.cost_per_value = cost_per_value
        self.train_cost = train_cost
        self._models: Dict[str, Tuple[float, List[Tuple[int, float, float, float]]]] = {}
        self._feat = None
        self._cols = None

    # ------------------------------------------------------------------ #
    def fit(self, table: MaskedRelation) -> None:
        cols = table.column_names()
        n = table.num_rows
        feat = np.zeros((n, len(cols)), dtype=np.float64)
        for i, c in enumerate(cols):
            present = table.is_present(c)
            v = table.values(c).astype(np.float64)
            fill = v[present].mean() if present.any() else 0.0
            feat[:, i] = np.where(present, v, fill)
        self._feat = feat
        self._cols = cols

    def _train_attr(self, table: MaskedRelation, attr: str) -> None:
        ai = self._cols.index(attr)
        present = table.is_present(attr)
        y = table.values(attr)[present].astype(np.float64)
        X = self._feat[np.asarray(present)][:, :]
        keep = np.ones(X.shape[1], dtype=bool)
        keep[ai] = False
        X = X[:, keep]
        base = float(y.mean()) if len(y) else 0.0
        stumps: List[Tuple[int, float, float, float]] = []
        if len(y) > 4:
            resid = y - base
            for _ in range(self.rounds):
                f, thr, lo_v, hi_v, gain = self._best_stump(X, resid)
                if gain <= 1e-12:
                    break
                stumps.append((f, thr, self.lr * lo_v, self.lr * hi_v))
                pred = np.where(X[:, f] <= thr, self.lr * lo_v, self.lr * hi_v)
                resid = resid - pred
        self._models[attr] = (base, stumps)

    def _best_stump(self, X: np.ndarray, resid: np.ndarray):
        best = (0, 0.0, 0.0, 0.0, -1.0)
        total = resid.sum()
        n = len(resid)
        for f in range(X.shape[1]):
            x = X[:, f]
            lo, hi = x.min(), x.max()
            if hi <= lo:
                continue
            edges = np.linspace(lo, hi, self.bins + 1)[1:-1]
            b = np.clip(np.searchsorted(edges, x), 0, self.bins - 1)
            s = np.bincount(b, weights=resid, minlength=self.bins)
            c = np.bincount(b, minlength=self.bins)
            cs, cc = np.cumsum(s), np.cumsum(c)
            with np.errstate(divide="ignore", invalid="ignore"):
                lo_mean = np.where(cc > 0, cs / np.maximum(cc, 1), 0.0)
                hi_mean = np.where(
                    (n - cc) > 0, (total - cs) / np.maximum(n - cc, 1), 0.0
                )
            gain = cc * lo_mean**2 + (n - cc) * hi_mean**2
            gi = int(np.argmax(gain[:-1])) if self.bins > 1 else 0
            g = float(gain[gi])
            if g > best[4]:
                thr = edges[gi] if gi < len(edges) else x.max()
                best = (f, float(thr), float(lo_mean[gi]), float(hi_mean[gi]), g)
        return best

    # ------------------------------------------------------------------ #
    def impute_attr(self, table: MaskedRelation, attr: str, tids: np.ndarray
                    ) -> np.ndarray:
        tids = np.asarray(tids, dtype=np.int64)
        if len(tids) == 0:  # batched interface: empty flush batch
            return np.zeros(0, dtype=np.float64)
        if attr not in self._models:
            self._train_attr(table, attr)
        base, stumps = self._models[attr]
        ai = self._cols.index(attr)
        keep = np.ones(self._feat.shape[1], dtype=bool)
        keep[ai] = False
        X = self._feat[tids][:, keep]
        pred = np.full(len(tids), base, dtype=np.float64)
        for f, thr, lo_v, hi_v in stumps:
            pred += np.where(X[:, f] <= thr, lo_v, hi_v)
        if not np.issubdtype(table.cols[attr].dtype, np.floating):
            present = table.is_present(attr)
            vocab = np.unique(table.values(attr)[present])
            if len(vocab):
                nearest = np.searchsorted(vocab, pred)
                nearest = np.clip(nearest, 0, len(vocab) - 1)
                lower = np.clip(nearest - 1, 0, len(vocab) - 1)
                pick_lower = np.abs(vocab[lower] - pred) < np.abs(vocab[nearest] - pred)
                pred = np.where(pick_lower, vocab[lower], vocab[nearest])
        return pred
