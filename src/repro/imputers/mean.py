"""Histogram-based mean/mode imputation (non-blocking; ImputeDB's method)."""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.relation import MaskedRelation
from repro.imputers.base import Imputer

__all__ = ["MeanImputer"]


class MeanImputer(Imputer):
    """Replace a missing value with the histogram mean (float columns) or the
    histogram mode (dictionary-coded columns) of the attribute.  Histograms
    are the database's existing optimizer statistics → non-blocking."""

    blocking = False
    cost_per_value = 0.0

    def __init__(self, bins: int = 64):
        self.bins = bins
        self._fill: Dict[str, float] = {}

    def fit(self, table: MaskedRelation) -> None:
        for name in table.column_names():
            present = table.is_present(name)
            vals = table.values(name)[present]
            if len(vals) == 0:
                self._fill[name] = 0.0
                continue
            if np.issubdtype(vals.dtype, np.floating):
                hist, edges = np.histogram(vals[np.isfinite(vals)], bins=self.bins)
                if hist.sum() == 0:
                    self._fill[name] = 0.0
                else:
                    centers = (edges[:-1] + edges[1:]) / 2
                    self._fill[name] = float((hist * centers).sum() / hist.sum())
            else:
                uniq, counts = np.unique(vals, return_counts=True)
                self._fill[name] = float(uniq[np.argmax(counts)])

    def impute_attr(self, table: MaskedRelation, attr: str, tids: np.ndarray
                    ) -> np.ndarray:
        # batched interface: one constant per attribute, broadcast over the
        # whole deduplicated tid batch in a single allocation
        if attr not in self._fill:
            self.fit(table)
        return np.full(len(tids), self._fill[attr], dtype=np.float64)
