"""Sharded checkpointing with async write, integrity digests, and
latest-valid discovery — the fault-tolerance substrate (restart after node
failure resumes from the last *complete* checkpoint).

Layout::

    <dir>/step_000120/
        shard_000.npz ... shard_NNN.npz   (one per host in a real cluster)
        MANIFEST.json                      (tree structure + digests)
        COMMIT                             (written last — atomicity marker)

A checkpoint without COMMIT is treated as torn and ignored by
``latest_step`` (crash-during-write safety).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]


def _flatten(tree: Any) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    shards: int = 1) -> str:
    """Write a complete checkpoint; returns its directory."""
    leaves, treedef = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:06d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "num_leaves": len(leaves),
                                "shards": shards, "digests": {}}
    per_shard: List[Dict[str, np.ndarray]] = [dict() for _ in range(shards)]
    for i, leaf in enumerate(leaves):
        per_shard[i % shards][f"leaf_{i:05d}"] = leaf
        manifest["digests"][f"leaf_{i:05d}"] = _digest(leaf)
    for s, payload in enumerate(per_shard):
        np.savez(os.path.join(tmp_dir, f"shard_{s:03d}.npz"), **payload)
    with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "COMMIT"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Largest step with a COMMIT marker (torn checkpoints skipped)."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        if not os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            continue
        step = int(name.split("_")[1])
        best = step if best is None else max(best, step)
    return best


def restore_checkpoint(ckpt_dir: str, tree_like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``; verifies digests."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no complete checkpoint under {ckpt_dir}"
    step_dir = os.path.join(ckpt_dir, f"step_{step:06d}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)
    leaves_by_name: Dict[str, np.ndarray] = {}
    for s in range(manifest["shards"]):
        with np.load(os.path.join(step_dir, f"shard_{s:03d}.npz")) as z:
            for k in z.files:
                leaves_by_name[k] = z[k]
    _, treedef = jax.tree_util.tree_flatten(tree_like)
    leaves = []
    for i in range(manifest["num_leaves"]):
        arr = leaves_by_name[f"leaf_{i:05d}"]
        assert _digest(arr) == manifest["digests"][f"leaf_{i:05d}"], (
            f"checkpoint corruption in leaf_{i:05d} of step {step}"
        )
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread; ``wait()``
    joins before the next save (bounded staleness of 1)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.ckpt_dir, n, "COMMIT"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.ckpt_dir, f"step_{s:06d}"), ignore_errors=True
            )
