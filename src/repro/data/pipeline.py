"""QUIP as a training-data pipeline stage.

At cluster scale the training corpus is materialized by relational queries
over feature/event tables that contain missing values; ``QuipCleanStage``
runs those queries through the QUIP executor (lazy/adaptive imputation) and
tokenizes the result into fixed-shape global batches for the LM trainer.
This is the integration point between the paper's technique and the
distributed substrate (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

from repro.core.executor import ExecutionResult, execute_quip
from repro.core.plan import Query
from repro.core.relation import MaskedRelation
from repro.imputers.base import ImputationEngine
from repro.imputers.mean import MeanImputer

__all__ = ["QuipCleanStage", "rows_to_tokens"]


def rows_to_tokens(rel: MaskedRelation, vocab: int, seq_len: int,
                   rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Serialize answer rows into token sequences (value-bucket encoding):
    each cell becomes a token ``hash(col, bucket(value)) % vocab``; rows are
    concatenated and chunked to seq_len."""
    rng = rng or np.random.default_rng(0)
    toks: List[int] = []
    for ci, name in enumerate(rel.column_names()):
        pass
    cols = rel.column_names()
    n = rel.num_rows
    stream = np.zeros((n, len(cols)), dtype=np.int64)
    for ci, name in enumerate(cols):
        v = rel.values(name).astype(np.float64)
        v = np.nan_to_num(v)
        bucket = np.floor(v).astype(np.int64)
        stream[:, ci] = (bucket * 1315423911 + ci * 2654435761) % max(vocab - 2, 1) + 1
    flat = stream.reshape(-1)
    n_seq = max(len(flat) // seq_len, 1)
    if len(flat) < n_seq * seq_len:
        flat = np.pad(flat, (0, n_seq * seq_len - len(flat)))
    return flat[: n_seq * seq_len].reshape(n_seq, seq_len)


@dataclasses.dataclass
class QuipCleanStage:
    """Materializes QUIP query answers into LM token batches."""

    tables: Dict[str, MaskedRelation]
    queries: List[Query]
    vocab: int
    seq_len: int
    global_batch: int
    strategy: str = "adaptive"
    engine_factory: Optional[Callable[[], ImputationEngine]] = None
    seed: int = 0

    def _engine(self) -> ImputationEngine:
        if self.engine_factory is not None:
            return self.engine_factory()
        return ImputationEngine(
            {t: r.copy() for t, r in self.tables.items()},
            default=MeanImputer,
        )

    def run_queries(self) -> List[ExecutionResult]:
        out = []
        for q in self.queries:
            eng = self._engine()
            out.append(
                execute_quip(q, self.tables, eng, strategy=self.strategy)
            )
        return out

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite stream of {'tokens','labels'} global batches built from
        the (lazily cleaned) query answers."""
        rng = np.random.default_rng(self.seed)
        seqs: List[np.ndarray] = []
        for res in self.run_queries():
            if res.relation.num_rows:
                seqs.append(
                    rows_to_tokens(res.relation, self.vocab, self.seq_len + 1, rng)
                )
        assert seqs, "QUIP pipeline produced no rows"
        pool = np.concatenate(seqs, axis=0)
        while True:
            idx = rng.integers(0, len(pool), self.global_batch)
            chunk = pool[idx]
            yield {
                "tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32),
            }
