"""Synthetic data sets mirroring the paper's three benchmarks (§7.1).

* ``wifi_dataset``      — UCI-WiFi-like: users / wifi / occupancy with
  missing mac_addr, lid, occupancy, type (Table 6 rates).
* ``cdc_dataset``       — CDC-NHANES-like: demo / exams / labs, 10 numeric
  attrs each, per-attr missing rates from Table 5.
* ``smartcampus_dataset`` — SmartBench-like: semantic + sensor tables.

All string values are dictionary-encoded int64 codes; ground truth is
retained so experiments can use oracle or learned imputers and score SMAPE.
Scales are configurable (default sizes keep CI fast; benchmarks scale up).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema

__all__ = ["wifi_dataset", "cdc_dataset", "smartcampus_dataset", "mask_values"]


def mask_values(rng, values: np.ndarray, rate: float) -> Tuple[np.ndarray, np.ndarray]:
    m = rng.random(len(values)) < rate
    out = values.copy()
    out[m] = 0
    return out, m


def _relation(name: str, cols: Dict[str, np.ndarray],
              missing: Dict[str, np.ndarray],
              kinds: Dict[str, str]) -> MaskedRelation:
    schema = Schema(
        name, [ColumnSpec(c, kinds.get(c, "int")) for c in cols]
    )
    return MaskedRelation.from_columns(
        schema, cols, missing=missing, base_table=name
    )


def wifi_dataset(rng=None, n_users: int = 400, n_wifi: int = 8000,
                 n_occ: int = 4000, n_rooms: int = 60):
    """Returns (tables, clean_tables)."""
    rng = rng or np.random.default_rng(0)
    tables, clean = {}, {}

    # device pool ≫ registered users (real data: 60k devices vs 4k users):
    # most wifi events belong to unregistered devices, so the users-join
    # eliminates them — the elimination QUIP's delaying exploits (paper §1).
    n_devices = n_users * 3
    device_pool = np.arange(1, n_devices + 1, dtype=np.int64)
    macs_all = device_pool[:n_users]
    u_mac = macs_all.copy()
    u_mac_m = rng.random(n_users) < 0.1995
    u_group = rng.integers(0, 12, n_users).astype(np.int64)
    u_group_m = rng.random(n_users) < 0.8977
    cols = {
        "users.name": np.arange(n_users, dtype=np.int64),
        "users.mac_addr": np.where(u_mac_m, 0, u_mac),
        "users.email": np.arange(n_users, dtype=np.int64),
        "users.group": np.where(u_group_m, 0, u_group),
    }
    missing = {"users.mac_addr": u_mac_m, "users.group": u_group_m}
    tables["users"] = _relation("users", cols, missing, {})
    clean["users"] = _relation(
        "users",
        {**cols, "users.mac_addr": u_mac, "users.group": u_group},
        {}, {},
    )

    # wifi(start_time, end_time, lid, duration, mac_addr)
    start = rng.integers(0, 720, n_wifi).astype(np.int64)
    dur = rng.integers(1, 180, n_wifi).astype(np.int64)
    lid = rng.integers(1, n_rooms + 1, n_wifi).astype(np.int64)
    lid_m = rng.random(n_wifi) < 0.5138
    # device visits follow per-device room preferences (LOCATER's signal)
    mac = device_pool[rng.integers(0, n_devices, n_wifi)]
    pref = rng.integers(1, n_rooms + 1, n_devices + 1).astype(np.int64)
    lid = np.where(rng.random(n_wifi) < 0.6, pref[mac], lid)
    cols = {
        "wifi.start_time": start,
        "wifi.end_time": start + dur,
        "wifi.lid": np.where(lid_m, 0, lid),
        "wifi.duration": dur,
        "wifi.mac_addr": mac,
    }
    missing = {"wifi.lid": lid_m}
    tables["wifi"] = _relation("wifi", cols, missing, {})
    clean["wifi"] = _relation("wifi", {**cols, "wifi.lid": lid}, {}, {})

    # occupancy(lid, start_time, end_time, occupancy, type) — covers only a
    # subset of rooms (sensored spaces), so the lid-join is selective too
    o_lid = rng.integers(1, n_rooms // 2 + 1, n_occ).astype(np.int64)
    o_start = rng.integers(0, 720, n_occ).astype(np.int64)
    occ = np.maximum(
        0, (20 - np.abs(o_lid - 30)) + rng.integers(0, 8, n_occ)
    ).astype(np.int64)
    occ_m = rng.random(n_occ) < 0.7117
    typ = (o_lid % 5).astype(np.int64)
    typ_m = rng.random(n_occ) < 0.6150
    cols = {
        "occupancy.lid": o_lid,
        "occupancy.start_time": o_start,
        "occupancy.end_time": o_start + rng.integers(1, 60, n_occ),
        "occupancy.occupancy": np.where(occ_m, 0, occ),
        "occupancy.type": np.where(typ_m, 0, typ),
    }
    missing = {"occupancy.occupancy": occ_m, "occupancy.type": typ_m}
    tables["occupancy"] = _relation("occupancy", cols, missing, {})
    clean["occupancy"] = _relation(
        "occupancy",
        {**cols, "occupancy.occupancy": occ, "occupancy.type": typ},
        {}, {},
    )
    return tables, clean


_CDC_RATES = {
    "demo": {"age_months": 0.9339, "age_yrs": 0.0, "gender": 0.0,
             "income": 0.0131, "is_citizen": 0.0004, "marital_status": 0.4330,
             "num_people_household": 0.0, "time_in_us": 0.8125,
             "years_edu_children": 0.7245},
    "labs": {"albumin": 0.1795, "blood_lead": 0.4686,
             "blood_selenium": 0.4686, "cholesterol": 0.2231,
             "creatine": 0.7259, "hematocrit": 0.1293,
             "triglyceride": 0.6794, "vitamin_b12": 0.4583,
             "white_blood_cell_ct": 0.1293},
    "exams": {"arm_circumference": 0.0522, "blood_pressure_secs": 0.0311,
              "blood_pressure_systolic": 0.2691, "body_mass_index": 0.0772,
              "cuff_size": 0.2314, "head_circumference": 0.9767,
              "height": 0.0, "waist_circumference": 0.1174, "weight": 0.0092},
}


def cdc_dataset(rng=None, n_demo: int = 2000, n_labs: int = 1900,
                n_exams: int = 1900):
    """CDC-NHANES-like: joined on id; numeric attrs correlated with a latent
    health factor so learned imputers beat the mean."""
    rng = rng or np.random.default_rng(1)
    tables, clean = {}, {}
    sizes = {"demo": n_demo, "labs": n_labs, "exams": n_exams}
    latent = rng.normal(0, 1, n_demo)
    for t, n in sizes.items():
        ids = np.arange(n, dtype=np.int64)
        lat = latent[:n]
        cols: Dict[str, np.ndarray] = {f"{t}.id": ids}
        missing: Dict[str, np.ndarray] = {}
        kinds: Dict[str, str] = {}
        truth_cols: Dict[str, np.ndarray] = {f"{t}.id": ids}
        for a, rate in _CDC_RATES[t].items():
            q = f"{t}.{a}"
            base = rng.normal(50, 10, n) + 12.0 * lat + rng.normal(0, 3, n)
            vals = np.round(base, 1)
            kinds[q] = "float"
            m = rng.random(n) < rate
            cols[q] = np.where(m, 0.0, vals)
            missing[q] = m
            truth_cols[q] = vals
        tables[t] = _relation(t, cols, missing, kinds)
        clean[t] = _relation(t, truth_cols, {}, kinds)
    return tables, clean


def smartcampus_dataset(rng=None, scale: int = 1):
    """SmartBench-like: location/user semantic tables + wifi/bluetooth/
    temperature/camera sensor tables (scaled-down Smart Campus)."""
    rng = rng or np.random.default_rng(2)
    n_rooms, n_users = 80 * scale, 300 * scale
    n_sensor = 6000 * scale
    tables, clean = {}, {}

    rooms = np.arange(1, n_rooms + 1, dtype=np.int64)
    floor = (rooms % 6).astype(np.int64)
    bld = (rooms % 4).astype(np.int64)
    bld_m = rng.random(n_rooms) < 0.3
    cols = {"location.room": rooms, "location.floor": floor,
            "location.building": np.where(bld_m, 0, bld)}
    tables["location"] = _relation(
        "location", cols, {"location.building": bld_m}, {}
    )
    clean["location"] = _relation(
        "location", {**cols, "location.building": bld}, {}, {}
    )

    macs = np.arange(1, n_users + 1, dtype=np.int64)
    mac_m = rng.random(n_users) < 0.2
    cols = {"user.uid": np.arange(n_users, dtype=np.int64),
            "user.mac": np.where(mac_m, 0, macs)}
    tables["user"] = _relation("user", cols, {"user.mac": mac_m}, {})
    clean["user"] = _relation("user", {**cols, "user.mac": macs}, {}, {})

    for sensor, val_rate in (("swifi", 0.45), ("bluetooth", 0.35),
                             ("temperature", 0.25), ("camera", 0.55)):
        t = sensor
        room = rng.integers(1, n_rooms + 1, n_sensor).astype(np.int64)
        ts = rng.integers(0, 1440, n_sensor).astype(np.int64)
        mac = macs[rng.integers(0, n_users, n_sensor)]
        val = (room * 3 + ts // 60).astype(np.int64)
        v_m = rng.random(n_sensor) < val_rate
        room_m = rng.random(n_sensor) < 0.15
        cols = {
            f"{t}.room": np.where(room_m, 0, room),
            f"{t}.time": ts,
            f"{t}.mac": mac,
            f"{t}.value": np.where(v_m, 0, val),
        }
        missing = {f"{t}.room": room_m, f"{t}.value": v_m}
        tables[t] = _relation(t, cols, missing, {})
        clean[t] = _relation(
            t, {**cols, f"{t}.room": room, f"{t}.value": val}, {}, {}
        )
    return tables, clean
