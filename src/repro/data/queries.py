"""Query workloads (paper §7.2): random / low-selectivity / high-selectivity
sets of 20 SPJ(+aggregate) queries per data set, from the paper's template

    SELECT a, AGG(b) FROM R1..Rn WHERE [Pred_J] [Pred_S] GROUP BY a
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation

__all__ = ["workload", "serving_workload", "JOIN_GRAPHS"]

# join graphs per data set (chain joins over shared keys)
JOIN_GRAPHS: Dict[str, List[Tuple[str, str]]] = {
    "wifi": [("users.mac_addr", "wifi.mac_addr"),
             ("wifi.lid", "occupancy.lid")],
    "cdc": [("demo.id", "labs.id"), ("labs.id", "exams.id")],
    "smartcampus": [("user.mac", "swifi.mac"),
                    ("swifi.room", "location.room")],
}

_AGG_OPS = ("count", "sum", "avg", "max", "min")


def _numeric_attrs(tables: Dict[str, MaskedRelation], t: str) -> List[str]:
    rel = tables[t]
    out = []
    for c in rel.schema.columns:
        if c.name.endswith(".id"):
            continue
        out.append(c.name)
    return out


def _sel_pred(rng, tables, attr: str, selectivity: Optional[float]
              ) -> SelectionPredicate:
    rel = tables[attr.split(".")[0]]
    present = rel.is_present(attr)
    vals = np.sort(rel.values(attr)[present])
    if len(vals) == 0:
        return SelectionPredicate(attr, ">=", 0)
    if selectivity is None:
        selectivity = float(rng.uniform(0.05, 0.95))
    uniq = np.unique(vals)
    # categorical-ish attrs get the paper's "in {rooms of interest}" form
    if len(uniq) <= 128 and not np.issubdtype(vals.dtype, np.floating):
        k = max(1, int(round(selectivity * len(uniq))))
        pick = rng.choice(uniq, size=min(k, len(uniq)), replace=False)
        return SelectionPredicate(attr, "in", frozenset(int(v) for v in pick))
    # choose x with P(v >= x) ≈ selectivity
    idx = int((1.0 - selectivity) * (len(vals) - 1))
    return SelectionPredicate(attr, ">=", float(vals[idx])
                              if np.issubdtype(vals.dtype, np.floating)
                              else int(vals[idx]))


def workload(
    dataset: str,
    tables: Dict[str, MaskedRelation],
    kind: str = "random",
    n_queries: int = 20,
    seed: int = 0,
) -> List[Query]:
    """kind: 'random' | 'low' (selective preds) | 'high' (loose preds)."""
    rng = np.random.default_rng(seed)
    joins_all = JOIN_GRAPHS[dataset]
    sel_target = {"random": None, "low": 0.1, "high": 0.9}[kind]
    queries: List[Query] = []
    for qi in range(n_queries):
        n_tables = int(rng.integers(2, len(joins_all) + 2))
        joins = joins_all[: n_tables - 1]
        tabs: List[str] = []
        for j in joins:
            for a in j:
                t = a.split(".")[0]
                if t not in tabs:
                    tabs.append(t)
        sels = []
        for t in tabs:
            if rng.random() < 0.75:
                attrs = _numeric_attrs(tables, t)
                attr = attrs[rng.integers(0, len(attrs))]
                sels.append(_sel_pred(rng, tables, attr, sel_target))
        agg = None
        projection: Tuple[str, ...] = ()
        if rng.random() < 0.7:  # majority are SPJ-aggregate (paper §7.2)
            t_a = tabs[rng.integers(0, len(tabs))]
            attrs = _numeric_attrs(tables, t_a)
            attr = attrs[rng.integers(0, len(attrs))]
            op = _AGG_OPS[rng.integers(0, len(_AGG_OPS))]
            gb = None
            if rng.random() < 0.5:
                t_g = tabs[rng.integers(0, len(tabs))]
                gbs = _numeric_attrs(tables, t_g)
                gb = gbs[rng.integers(0, len(gbs))]
            agg = Aggregate(op, attr, group_by=gb)
        else:
            proj = []
            for t in tabs:
                attrs = _numeric_attrs(tables, t)
                proj.append(attrs[rng.integers(0, len(attrs))])
            projection = tuple(proj)
        queries.append(Query(
            tables=tuple(tabs),
            selections=tuple(sels),
            joins=tuple(
                JoinPredicate(l, r) for l, r in joins
            ),
            projection=projection,
            aggregate=agg,
        ))
    return queries


def serving_workload(
    dataset: str,
    tables: Dict[str, MaskedRelation],
    n_queries: int = 20,
    n_templates: int = 6,
    n_tenants: int = 4,
    skew: float = 1.1,
    kind: str = "random",
    seed: int = 0,
):
    """Skewed multi-tenant query stream for the QuipService serving layer.

    Yields ``(tenant, Query)`` pairs.  Queries are drawn (with repetition)
    from a pool of ``n_templates`` templates under a Zipf-like distribution
    with exponent ``skew`` — hot templates recur, so a serving engine sees
    plan-cache hits and overlapping imputation requests, the two kinds of
    cross-query sharing QUIP's serving layer amortizes.  Tenants are drawn
    uniformly and are labels only (admission/fairness experiments); two
    tenants issuing the same template share plan and imputation state.
    """
    templates = workload(dataset, tables, kind=kind,
                         n_queries=n_templates, seed=seed)
    rng = np.random.default_rng(seed + 7)
    ranks = np.arange(1, n_templates + 1, dtype=np.float64)
    probs = ranks ** -float(skew)
    probs /= probs.sum()
    for _ in range(n_queries):
        t_idx = int(rng.choice(n_templates, p=probs))
        tenant = int(rng.integers(0, n_tenants))
        yield tenant, templates[t_idx]
