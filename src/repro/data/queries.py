"""Query workloads (paper §7.2): random / low-selectivity / high-selectivity
sets of 20 SPJ(+aggregate) queries per data set, from the paper's template

    SELECT a, AGG(b) FROM R1..Rn WHERE [Pred_J] [Pred_S] GROUP BY a
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation

__all__ = ["workload", "serving_workload", "mutating_workload", "Mutation",
           "JOIN_GRAPHS"]

# join graphs per data set (chain joins over shared keys)
JOIN_GRAPHS: Dict[str, List[Tuple[str, str]]] = {
    "wifi": [("users.mac_addr", "wifi.mac_addr"),
             ("wifi.lid", "occupancy.lid")],
    "cdc": [("demo.id", "labs.id"), ("labs.id", "exams.id")],
    "smartcampus": [("user.mac", "swifi.mac"),
                    ("swifi.room", "location.room")],
}

_AGG_OPS = ("count", "sum", "avg", "max", "min")


def _numeric_attrs(tables: Dict[str, MaskedRelation], t: str) -> List[str]:
    rel = tables[t]
    out = []
    for c in rel.schema.columns:
        if c.name.endswith(".id"):
            continue
        out.append(c.name)
    return out


def _sel_pred(rng, tables, attr: str, selectivity: Optional[float]
              ) -> SelectionPredicate:
    rel = tables[attr.split(".")[0]]
    present = rel.is_present(attr)
    vals = np.sort(rel.values(attr)[present])
    if len(vals) == 0:
        return SelectionPredicate(attr, ">=", 0)
    if selectivity is None:
        selectivity = float(rng.uniform(0.05, 0.95))
    uniq = np.unique(vals)
    # categorical-ish attrs get the paper's "in {rooms of interest}" form
    if len(uniq) <= 128 and not np.issubdtype(vals.dtype, np.floating):
        k = max(1, int(round(selectivity * len(uniq))))
        pick = rng.choice(uniq, size=min(k, len(uniq)), replace=False)
        return SelectionPredicate(attr, "in", frozenset(int(v) for v in pick))
    # choose x with P(v >= x) ≈ selectivity
    idx = int((1.0 - selectivity) * (len(vals) - 1))
    return SelectionPredicate(attr, ">=", float(vals[idx])
                              if np.issubdtype(vals.dtype, np.floating)
                              else int(vals[idx]))


def workload(
    dataset: str,
    tables: Dict[str, MaskedRelation],
    kind: str = "random",
    n_queries: int = 20,
    seed: int = 0,
) -> List[Query]:
    """kind: 'random' | 'low' (selective preds) | 'high' (loose preds)."""
    rng = np.random.default_rng(seed)
    joins_all = JOIN_GRAPHS[dataset]
    sel_target = {"random": None, "low": 0.1, "high": 0.9}[kind]
    queries: List[Query] = []
    for qi in range(n_queries):
        n_tables = int(rng.integers(2, len(joins_all) + 2))
        joins = joins_all[: n_tables - 1]
        tabs: List[str] = []
        for j in joins:
            for a in j:
                t = a.split(".")[0]
                if t not in tabs:
                    tabs.append(t)
        sels = []
        for t in tabs:
            if rng.random() < 0.75:
                attrs = _numeric_attrs(tables, t)
                attr = attrs[rng.integers(0, len(attrs))]
                sels.append(_sel_pred(rng, tables, attr, sel_target))
        agg = None
        projection: Tuple[str, ...] = ()
        if rng.random() < 0.7:  # majority are SPJ-aggregate (paper §7.2)
            t_a = tabs[rng.integers(0, len(tabs))]
            attrs = _numeric_attrs(tables, t_a)
            attr = attrs[rng.integers(0, len(attrs))]
            op = _AGG_OPS[rng.integers(0, len(_AGG_OPS))]
            gb = None
            if rng.random() < 0.5:
                t_g = tabs[rng.integers(0, len(tabs))]
                gbs = _numeric_attrs(tables, t_g)
                gb = gbs[rng.integers(0, len(gbs))]
            agg = Aggregate(op, attr, group_by=gb)
        else:
            proj = []
            for t in tabs:
                attrs = _numeric_attrs(tables, t)
                proj.append(attrs[rng.integers(0, len(attrs))])
            projection = tuple(proj)
        queries.append(Query(
            tables=tuple(tabs),
            selections=tuple(sels),
            joins=tuple(
                JoinPredicate(l, r) for l, r in joins
            ),
            projection=projection,
            aggregate=agg,
        ))
    return queries


def _zipf_probs(n: int, skew: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    probs = ranks ** -float(skew)
    return probs / probs.sum()


def serving_workload(
    dataset: str,
    tables: Dict[str, MaskedRelation],
    n_queries: int = 20,
    n_templates: int = 6,
    n_tenants: int = 4,
    skew: float = 1.1,
    kind: str = "random",
    seed: int = 0,
    tenant_skew: Optional[float] = None,
    tenant_mix: Optional[Dict[int, Tuple[int, ...]]] = None,
):
    """Skewed multi-tenant query stream for the QuipService serving layer.

    Yields ``(tenant, Query)`` pairs.  Queries are drawn (with repetition)
    from a pool of ``n_templates`` templates under a Zipf-like distribution
    with exponent ``skew`` — hot templates recur, so a serving engine sees
    plan-cache hits and overlapping imputation requests, the two kinds of
    cross-query sharing QUIP's serving layer amortizes.  Two tenants
    issuing the same template share plan and imputation state.

    Tenants default to uniform draws (labels only).  For QoS/fairness
    experiments:

    * ``tenant_skew`` — Zipf exponent over tenant ids: tenant 0 becomes
      the heavy "aggressor" issuing most of the stream while the high
      ranks are low-traffic "victims" (exp10's scenario);
    * ``tenant_mix`` — per-tenant template pools (tenant → tuple of
      template indices): each tenant draws only from its pool, with the
      global Zipf weights renormalized over it, so e.g. an aggressor can
      be pinned to the expensive multi-join templates while a victim runs
      cheap scans.  Tenants absent from the mix use the full pool.

    Both default to off, and the default stream is **byte-identical** to
    the pre-QoS generator for a fixed seed (regression-tested) — the
    legacy draw order is preserved exactly when neither knob is set.

    A misconfigured ``tenant_mix`` raises at *call* time (this is an
    eager wrapper around the generator), not at first iteration.
    """
    probs = _zipf_probs(n_templates, skew)
    mix_probs = {}  # tenant -> (pool array, renormalized zipf weights)
    if tenant_mix:
        for tenant, pool in tenant_mix.items():
            if not 0 <= tenant < n_tenants:
                raise ValueError(
                    f"tenant_mix key {tenant} outside range({n_tenants}) — "
                    f"the pinning would silently never apply"
                )
            if not pool or not all(0 <= i < n_templates for i in pool):
                raise ValueError(
                    f"tenant_mix[{tenant}] must be non-empty template "
                    f"indices < n_templates, got {pool!r}"
                )
            arr = np.asarray(pool, dtype=np.int64)
            sub = probs[arr]
            mix_probs[tenant] = (arr, sub / sub.sum())
    templates = workload(dataset, tables, kind=kind,
                         n_queries=n_templates, seed=seed)

    def _gen():
        rng = np.random.default_rng(seed + 7)
        if tenant_skew is None and tenant_mix is None:
            # legacy draw order — keep existing fixed-seed streams unchanged
            for _ in range(n_queries):
                t_idx = int(rng.choice(n_templates, p=probs))
                tenant = int(rng.integers(0, n_tenants))
                yield tenant, templates[t_idx]
            return
        tenant_probs = (
            _zipf_probs(n_tenants, tenant_skew)
            if tenant_skew is not None
            else np.full(n_tenants, 1.0 / n_tenants)
        )
        for _ in range(n_queries):
            tenant = int(rng.choice(n_tenants, p=tenant_probs))
            if tenant in mix_probs:
                arr, sub = mix_probs[tenant]
                t_idx = int(arr[int(rng.choice(len(arr), p=sub))])
            else:
                t_idx = int(rng.choice(n_templates, p=probs))
            yield tenant, templates[t_idx]

    return _gen()


# --------------------------------------------------------------------------- #
# mutation-interleaved serving workload (TableRegistry staleness testing)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Mutation:
    """One registry mutation, self-applying against any object exposing the
    :class:`repro.service.registry.TableRegistry` mutation API (duck-typed,
    so this module stays free of a service dependency)."""

    kind: str  # "update_rows" | "delete_rows"
    table: str
    rows: Tuple[int, ...]
    values: Optional[Dict[str, Tuple]] = None  # update_rows only

    def apply(self, registry) -> None:
        rows = np.asarray(self.rows, dtype=np.int64)
        if self.kind == "update_rows":
            registry.update_rows(self.table, rows, {
                a: np.asarray(v) for a, v in self.values.items()
            })
        elif self.kind == "delete_rows":
            registry.delete_rows(self.table, rows)
        else:  # pragma: no cover - generator only emits the two kinds
            raise ValueError(f"unknown mutation kind {self.kind!r}")


def mutating_workload(
    dataset: str,
    tables: Dict[str, MaskedRelation],
    n_queries: int = 20,
    mutate_every: int = 5,
    n_templates: int = 6,
    n_tenants: int = 4,
    skew: float = 1.1,
    kind: str = "random",
    seed: int = 0,
    tenant_skew: Optional[float] = None,
    tenant_mix: Optional[Dict[int, Tuple[int, ...]]] = None,
) -> Iterator[Tuple]:
    """The serving stream with registry mutations interleaved.

    Yields ``("query", tenant, Query)`` events from the same skewed
    template pool as :func:`serving_workload`, with a
    ``("mutate", Mutation)`` event after every ``mutate_every`` queries —
    alternating row updates (plausible values drawn from the column's
    observed domain) and small deletions.  Deterministic for a fixed seed;
    row ids stay valid by tracking each table's row count as deletions
    shrink it.  This is the workload the staleness tests and
    ``benchmarks/exp9_result_cache.py`` replay: every mutation bumps the
    table's epoch, so a correct service must re-plan, re-impute, and
    re-answer — while a stale cache would keep serving the old epoch.
    """
    stream = serving_workload(dataset, tables, n_queries=n_queries,
                              n_templates=n_templates, n_tenants=n_tenants,
                              skew=skew, kind=kind, seed=seed,
                              tenant_skew=tenant_skew, tenant_mix=tenant_mix)
    rng = np.random.default_rng(seed + 13)
    mut_tables = sorted({t for j in JOIN_GRAPHS[dataset] for a in j
                         for t in (a.split(".")[0],)})
    row_counts = {t: tables[t].num_rows for t in mut_tables}
    n_mut = 0
    for i, (tenant, q) in enumerate(stream, 1):
        yield ("query", tenant, q)
        if mutate_every and i % mutate_every == 0:
            t = mut_tables[int(rng.integers(0, len(mut_tables)))]
            n = row_counts[t]
            if n <= 4:
                continue  # table mutated down to nearly nothing
            k = int(rng.integers(1, max(2, n // 20)))
            rows = rng.choice(n, size=min(k, n - 1), replace=False)
            if n_mut % 2 == 0:
                attr = _numeric_attrs(tables, t)[0]
                rel = tables[t]
                domain = rel.values(attr)[rel.is_present(attr)]
                if len(domain) == 0:
                    domain = np.zeros(1, dtype=rel.cols[attr].dtype)
                vals = rng.choice(domain, size=len(rows), replace=True)
                mut = Mutation("update_rows", t,
                               tuple(int(r) for r in rows),
                               {attr: tuple(vals.tolist())})
            else:
                mut = Mutation("delete_rows", t,
                               tuple(int(r) for r in rows))
                row_counts[t] -= len(rows)
            n_mut += 1
            yield ("mutate", mut)
