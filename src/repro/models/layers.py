"""Shared model layers (pure functions over param pytrees, no framework)."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm", "rope", "apply_rope", "mlp_params", "mlp_apply",
    "softcap", "dense_init", "Params",
]

Params = Dict[str, Any]


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.bfloat16):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #
def rope(positions: jnp.ndarray, head_dim: int, theta: float
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int32 → cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(dt)


# --------------------------------------------------------------------------- #
# MLP (gated / plain)
# --------------------------------------------------------------------------- #
def mlp_params(key, d: int, ff: int, activation: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"wo": dense_init(ks[2], (ff, d), dtype=dtype)}
    if activation in ("silu", "geglu"):
        p["wi"] = dense_init(ks[0], (d, ff), dtype=dtype)
        p["wg"] = dense_init(ks[1], (d, ff), dtype=dtype)
    else:
        p["wi"] = dense_init(ks[0], (d, ff), dtype=dtype)
    return p


def mlp_apply(p: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    from repro.sharding.act import constrain

    h = constrain(x @ p["wi"], "btf")
    if activation == "silu":
        h = jax.nn.silu(h) * constrain(x @ p["wg"], "btf")
    elif activation == "geglu":
        h = jax.nn.gelu(h) * constrain(x @ p["wg"], "btf")
    else:
        h = jax.nn.gelu(h)
    return constrain(h @ p["wo"], "btd")
