"""Block/segment assembly: every assigned architecture is a stack of
homogeneous *segments* scanned with ``jax.lax.scan`` (compile-time O(1) in
depth).  A segment repeats a short *period* of blocks — e.g. gemma2 scans 23
(local, global) periods, zamba2 scans (5×ssm, attn) periods — so mixed-kind
architectures still scan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba
from repro.models import moe as moe_mod
from repro.models.layers import Params, mlp_apply, mlp_params, rms_norm

__all__ = ["BlockSpec", "build_segments", "segment_params", "forward_segments",
           "decode_segments", "init_segment_caches"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str  # "attn" | "local" | "ssm"
    moe: bool
    mlp: bool


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    pattern: Tuple[BlockSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


def _block_spec(cfg: ArchConfig, i: int) -> BlockSpec:
    kind = cfg.layer_kind(i)
    is_moe = cfg.is_moe and i >= cfg.first_dense_layers
    has_mlp = kind != "ssm" and (cfg.d_ff > 0 or is_moe)
    return BlockSpec(kind, is_moe, has_mlp)


def build_segments(cfg: ArchConfig) -> List[SegmentSpec]:
    specs = [_block_spec(cfg, i) for i in range(cfg.n_layers)]
    if cfg.family == "hybrid":
        period = max(cfg.hybrid_attn_period, 1)
    elif cfg.is_moe:
        period = 1
    else:
        period = len(cfg.layer_pattern)
    segments: List[SegmentSpec] = []
    i = 0
    while i < len(specs):
        # longest run of repeated periods starting at i
        pat = tuple(specs[i : i + period])
        if len(pat) < period:
            pat = tuple(specs[i:])
        r = 1
        while specs[i + r * len(pat) : i + (r + 1) * len(pat)] == list(pat):
            r += 1
        segments.append(SegmentSpec(pat, r))
        i += r * len(pat)
    return segments


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def _one_block_params(key, cfg: ArchConfig, spec: BlockSpec, dtype,
                      skip_shared: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), dtype=dtype)}
    if spec.kind == "ssm":
        p["mixer"] = mamba.ssm_params(ks[0], cfg, dtype)
    elif not skip_shared:
        if cfg.mla:
            p["mixer"] = attn.mla_params(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn.gqa_params(ks[0], cfg, dtype)
    if spec.mlp:
        p["ln2"] = jnp.zeros((d,), dtype=dtype)
        if not skip_shared or spec.kind == "ssm":
            if spec.moe:
                p["mlp"] = moe_mod.moe_params(ks[1], cfg, dtype)
            else:
                ff = cfg.d_ff
                if cfg.is_moe:  # dense layers of a MoE arch match active width
                    ff = cfg.d_ff * max(cfg.top_k + cfg.n_shared_experts, 1)
                p["mlp"] = mlp_params(ks[1], d, ff, cfg.activation, dtype)
    return p


def _shares_weights(cfg: ArchConfig, spec: BlockSpec) -> bool:
    return cfg.shared_attn and spec.kind != "ssm"


def segment_params(key, cfg: ArchConfig, seg: SegmentSpec, dtype) -> Params:
    """Stacked params: each period-position's block params get a leading
    ``repeats`` dimension (scanned).  Weight-shared blocks (zamba2's shared
    attention) keep their mixer/MLP once, under ``shared``."""
    keys = jax.random.split(key, seg.repeats * len(seg.pattern)).reshape(
        seg.repeats, len(seg.pattern), 2
    )
    blocks, shared = [], {}
    for j, spec in enumerate(seg.pattern):
        skip = _shares_weights(cfg, spec)
        stacked = jax.vmap(
            lambda k, spec=spec, skip=skip: _one_block_params(
                k, cfg, spec, dtype, skip_shared=skip
            )
        )(keys[:, j])
        blocks.append(stacked)
        if skip:
            one = _one_block_params(keys[0, j], cfg, spec, dtype)
            shared[str(j)] = {
                k: v for k, v in one.items() if k in ("mixer", "mlp")
            }
    out: Params = {"blocks": blocks}
    if shared:
        out["shared"] = shared
    return out


# --------------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------------- #
def _apply_block(p: Params, cfg: ArchConfig, spec: BlockSpec, x, positions,
                 causal: bool):
    from repro.sharding.act import constrain

    x = constrain(x, "btd")
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "ssm":
        mixed = mamba.ssm_apply(p["mixer"], cfg, h)
    elif cfg.mla:
        mixed = attn.mla_apply(p["mixer"], cfg, h, positions,
                               local=spec.kind == "local", causal=causal)
    else:
        mixed = attn.gqa_apply(p["mixer"], cfg, h, positions,
                               local=spec.kind == "local", causal=causal)
    x = x + mixed
    if spec.mlp:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe:
            x = x + moe_mod.moe_apply(p["mlp"], cfg, h2)
        else:
            x = x + mlp_apply(p["mlp"], h2, cfg.activation)
    return x


def forward_segments(params_segs, cfg: ArchConfig, segs: List[SegmentSpec],
                     x, positions, causal: bool = True,
                     remat: str = "full", unroll: bool = False) -> jnp.ndarray:
    for seg, seg_params in zip(segs, params_segs):
        shared = seg_params.get("shared", {})

        def period_body(carry, layer_params, seg=seg, shared=shared):
            y = carry
            for j, spec in enumerate(seg.pattern):
                p = layer_params["blocks"][j]
                if str(j) in shared:
                    p = {**p, **shared[str(j)]}
                y = _apply_block(p, cfg, spec, y, positions, causal)
            return y, None

        if remat == "full":
            period_body = jax.checkpoint(
                period_body, prevent_cse=False
            )
        elif remat == "dots":
            period_body = jax.checkpoint(
                period_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                prevent_cse=False,
            )
        # unroll=True: exact per-layer costs in cost_analysis() (a scanned
        # while-body is otherwise counted once, not ×trips) — used by the
        # dry-run; the trainer keeps the compact scan.
        x, _ = jax.lax.scan(
            lambda c, lp: period_body(c, lp), x,
            {"blocks": seg_params["blocks"]},
            unroll=seg.repeats if unroll else 1,
        )
    return x


# --------------------------------------------------------------------------- #
# decode (single token, cached)
# --------------------------------------------------------------------------- #
def init_segment_caches(cfg: ArchConfig, segs, batch: int, max_len: int,
                        dtype) -> List[Params]:
    caches = []
    for seg in segs:
        c = {"blocks": []}
        for spec in seg.pattern:
            if spec.kind == "ssm":
                one = mamba.init_ssm_cache(cfg, batch, dtype, seg.repeats)
            else:
                one = attn.init_kv_cache(cfg, batch, max_len, dtype, seg.repeats)
                # drop the layer axis added by init_kv_cache helper signature
            c["blocks"].append(one)
        caches.append(c)
    return caches


def decode_segments(params_segs, caches, cfg: ArchConfig, segs, x, pos,
                    unroll: bool = False) -> Tuple[jnp.ndarray, List]:
    """x: (B,1,d); pos: (B,) current length.  Returns (x, new_caches)."""
    new_caches = []
    for seg, seg_params, seg_cache in zip(segs, params_segs, caches):
        shared = seg_params.get("shared", {})

        def body(carry, xs, seg=seg, shared=shared):
            y = carry
            layer_params, layer_cache = xs
            new_lc = []
            for j, spec in enumerate(seg.pattern):
                p = layer_params["blocks"][j]
                if str(j) in shared:
                    p = {**p, **shared[str(j)]}
                c = layer_cache["blocks"][j]
                h = rms_norm(y, p["ln1"], cfg.norm_eps)
                if spec.kind == "ssm":
                    mixed, c2 = mamba.ssm_decode(p["mixer"], cfg, h, c)
                elif cfg.mla:
                    mixed, c2 = attn.mla_decode(p["mixer"], cfg, h, c, pos,
                                                local=spec.kind == "local")
                else:
                    mixed, c2 = attn.gqa_decode(p["mixer"], cfg, h, c, pos,
                                                local=spec.kind == "local")
                y = y + mixed
                if spec.mlp:
                    h2 = rms_norm(y, p["ln2"], cfg.norm_eps)
                    if spec.moe:
                        y = y + moe_mod.moe_apply(p["mlp"], cfg, h2)
                    else:
                        y = y + mlp_apply(p["mlp"], h2, cfg.activation)
                new_lc.append(c2)
            return y, {"blocks": new_lc}

        x, updated = jax.lax.scan(
            body, x, ({"blocks": seg_params["blocks"]}, seg_cache),
            unroll=seg.repeats if unroll else 1,
        )
        new_caches.append(updated)
    return x, new_caches
