"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD form: within a chunk the output is an attention-like quadratic
term (MXU-friendly); across chunks a small recurrent state (H, P, N) is
carried with ``jax.lax.scan``.  Decode is the O(1) recurrent step.

Simplifications vs. the reference CUDA kernel (recorded in DESIGN.md):
single B/C group shared across heads (n_groups=1), short conv applied to x
only, no bias terms.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, dense_init, rms_norm

__all__ = ["ssm_params", "ssm_apply", "ssm_decode", "init_ssm_cache"]

CONV_W = 4


def ssm_params(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * p_dim
    ks = jax.random.split(key, 8)
    return {
        "wx": dense_init(ks[0], (d, d_in), dtype=dtype),
        "wz": dense_init(ks[1], (d, d_in), dtype=dtype),
        "wB": dense_init(ks[2], (d, n), dtype=dtype),
        "wC": dense_init(ks[3], (d, n), dtype=dtype),
        "wdt": dense_init(ks[4], (d, h), dtype=dtype),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "A_log": jnp.zeros((h,), dtype=jnp.float32),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "conv": dense_init(ks[5], (CONV_W, d_in), scale=0.5, dtype=dtype),
        "norm": jnp.zeros((d_in,), dtype=dtype),
        "wo": dense_init(ks[6], (d_in, d), dtype=dtype),
    }


def _conv1d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv; x: (B,S,D), w: (W,D)."""
    pads = [(0, 0), (CONV_W - 1, 0), (0, 0)]
    xp = jnp.pad(x, pads)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out)


def _ssd_chunk_scan(x, dt, A, B, C, chunk: int):
    """SSD chunked algorithm.

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B,C: (b, s, n).
    Returns y: (b, s, h, p), final_state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    da = dtc * A[None, None, None, :]  # (b,nc,l,h) log-decay increments
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative
    total = cum[:, :, -1, :]  # (b,nc,h)

    # intra-chunk (quadratic, attention-like): y_t += C_t·Σ_{u<=t} exp(cum_t−cum_u)·dt_u·B_u·x_u
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,t,u,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bctn,bcun->bctu", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    att = cb[:, :, :, :, None] * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", att, xc.astype(jnp.float32))

    # chunk-boundary states: S_c = Σ_u exp(total−cum_u)·dt_u·B_u⊗x_u
    decay_out = jnp.exp(total[:, :, None, :] - cum)  # (b,nc,l,h)
    dBx = jnp.einsum(
        "bclh,bcln,bclhp->bchpn",
        (dtc * decay_out).astype(jnp.float32),
        Bc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    # inter-chunk recurrence over nc chunks
    def step(state, inp):
        dbx, tot = inp  # (b,h,p,n), (b,h)
        new = state * jnp.exp(tot)[:, :, None, None] + dbx
        return new, state  # emit state *entering* the chunk

    init = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    final, entering = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)  # (b,nc,h,p,n)

    # inter-chunk contribution: y_t += C_t · exp(cum_t) · S_entering
    y_inter = jnp.einsum(
        "bctn,bchpn,bcth->bcthp",
        Cc.astype(jnp.float32),
        entering,
        jnp.exp(cum),
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def ssm_apply(p: Params, cfg: ArchConfig, u: jnp.ndarray
              ) -> jnp.ndarray:
    """u: (B, S, d) → (B, S, d)."""
    b, s, d = u.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    chunk = min(cfg.ssm_chunk, s)
    assert s % chunk == 0, (s, chunk)
    x = _conv1d(u @ p["wx"], p["conv"]).reshape(b, s, h, pd)
    z = u @ p["wz"]
    B = u @ p["wB"]
    C = u @ p["wC"]
    dt = jax.nn.softplus(
        (u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    y, _ = _ssd_chunk_scan(x, dt, A, B, C, chunk)
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, h * pd).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"]


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype, n_ssm_layers: int):
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * pd
    return {
        "state": jnp.zeros((n_ssm_layers, batch, h, pd, n), dtype=jnp.float32),
        "conv": jnp.zeros((n_ssm_layers, batch, CONV_W - 1, d_in), dtype=dtype),
    }


def ssm_decode(p: Params, cfg: ArchConfig, u: jnp.ndarray, cache: Dict
               ) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step.  u: (B,1,d); cache: {state, conv} for this
    layer — state (B,h,p,n), conv (B,W-1,d_in)."""
    b = u.shape[0]
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = (u @ p["wx"])[:, 0]  # (B, d_in)
    hist = jnp.concatenate([cache["conv"], xin[:, None, :]], axis=1)
    x = jax.nn.silu(
        sum(hist[:, i, :] * p["conv"][i] for i in range(CONV_W))
    ).reshape(b, h, pd)
    new_conv = hist[:, 1:, :]
    z = (u @ p["wz"])[:, 0]
    B = (u @ p["wB"])[:, 0].astype(jnp.float32)
    C = (u @ p["wC"])[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(
        (u @ p["wdt"])[:, 0].astype(jnp.float32) + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B,h)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, B, x.astype(jnp.float32))
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C, state)
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, h * pd).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (y @ p["wo"])[:, None, :], {"state": state, "conv": new_conv}
