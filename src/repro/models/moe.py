"""Top-k MoE with capacity-factor group dispatch (GShard/Switch-style).

Tokens are split into groups (aligned with the data-parallel sharding); the
dispatch/combine tensors are one-hots of shape (G, S_g, E, C) with
C = S_g·k·cf / E, so the per-device footprint stays bounded and XLA SPMD
lowers the expert einsums into the expected all-to-all pattern when experts
are sharded over the model axis (EP).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Params, dense_init, mlp_apply, mlp_params

__all__ = ["moe_params", "moe_apply", "GROUP_SIZE"]

GROUP_SIZE = 1024  # tokens per dispatch group


def moe_params(key, cfg: ArchConfig, dtype) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "wi": dense_init(ks[1], (e, d, ff), dtype=dtype),
        "wg": dense_init(ks[2], (e, d, ff), dtype=dtype),
        "wo": dense_init(ks[3], (e, ff, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(
            ks[4], d, ff * cfg.n_shared_experts, cfg.activation, dtype
        )
    return p


def moe_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, d) → (B, S, d).  Auxiliary-loss-free top-k routing with
    per-group capacity (dropped tokens fall back to the shared expert /
    residual, as in capacity-factor implementations)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    g_sz = min(GROUP_SIZE, n)
    n_groups = max(n // g_sz, 1)
    tokens = tokens.reshape(n_groups, g_sz, d)

    logits = (tokens.astype(jnp.float32) @ p["router"])  # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    cap = max(int(g_sz * k * cfg.capacity_factor / e), 1)
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (G,S,k,E)
    flat = onehot.reshape(n_groups, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, S*k, E)
    pos = jnp.einsum("gte,gte->gt", pos, flat).reshape(n_groups, g_sz, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    if cfg.moe_impl == "scatter":
        out = _scatter_moe(p, cfg, tokens, gate_idx, gate_vals, pos, keep,
                           cap)
        if cfg.n_shared_experts:
            out = out + mlp_apply(p["shared"], tokens, cfg.activation)
        return out.reshape(b, s, d)

    # dispatch: (G, S, E, C) one-hot.  bf16 one-hots are exact (0/1) and
    # halve the dominant dispatch/combine byte traffic (§Perf).
    ddt = jnp.bfloat16 if cfg.moe_bf16_dispatch else jnp.float32
    pos_oh = jax.nn.one_hot(pos, cap, dtype=ddt)  # (G,S,k,C)
    dispatch = jnp.einsum(
        "gske,gskc->gsec", onehot.astype(ddt),
        pos_oh * keep[..., None].astype(ddt)
    )
    combine = jnp.einsum(
        "gsec,gsk,gske->gsec", dispatch, gate_vals.astype(ddt),
        onehot.astype(ddt)
    )

    from repro.sharding.act import constrain

    xin = constrain(
        jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), tokens), "ged"
    )
    h = constrain(jnp.einsum("gecd,edf->gecf", xin, p["wi"]), "ged")
    if cfg.activation in ("silu", "geglu"):
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(h) * constrain(
            jnp.einsum("gecd,edf->gecf", xin, p["wg"]), "ged"
        )
    else:
        h = jax.nn.gelu(h)
    expert_out = constrain(
        jnp.einsum("gecf,efd->gecd", h, p["wo"]), "ged"
    )
    out = constrain(
        jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), expert_out),
        "gsd",
    )

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], tokens, cfg.activation)
    return out.reshape(b, s, d)


def _expert_ffn(p: Params, cfg: ArchConfig, xin: jnp.ndarray) -> jnp.ndarray:
    """xin: (G, E, C, d) → (G, E, C, d) via per-expert gated FFN."""
    from repro.sharding.act import constrain

    h = constrain(jnp.einsum("gecd,edf->gecf", xin, p["wi"]), "ged")
    if cfg.activation in ("silu", "geglu"):
        act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
        h = act(h) * constrain(
            jnp.einsum("gecd,edf->gecf", xin, p["wg"]), "ged"
        )
    else:
        h = jax.nn.gelu(h)
    return constrain(jnp.einsum("gecf,efd->gecd", h, p["wo"]), "ged")


def _scatter_moe(p: Params, cfg: ArchConfig, tokens, gate_idx, gate_vals,
                 pos, keep, cap: int) -> jnp.ndarray:
    """Index-based dispatch (§Perf optimization): scatter token ids into
    (E, C) expert slots and gather — O(tokens·d) bytes instead of the
    (G, S, E, C) one-hot einsums, and no dispatch-matmul FLOPs."""
    from repro.sharding.act import constrain

    g, s_g, d = tokens.shape
    e = cfg.n_experts

    slot = jnp.where(keep, pos, cap)  # dropped tokens land in slot `cap`
    flat_tok = jnp.broadcast_to(
        jnp.arange(s_g, dtype=jnp.int32)[None, :, None], gate_idx.shape
    ).reshape(g, -1)
    flat_e = gate_idx.reshape(g, -1)
    flat_slot = slot.reshape(g, -1).astype(jnp.int32)

    def scatter_one(eidx, sidx, tok):
        buf = jnp.full((e, cap + 1), s_g, dtype=jnp.int32)  # s_g = padding
        return buf.at[eidx, sidx].set(tok, mode="drop")

    idx = jax.vmap(scatter_one)(flat_e, flat_slot, flat_tok)  # (G,E,C+1)
    idx = idx[:, :, :cap]
    pad = jnp.zeros((g, 1, d), dtype=tokens.dtype)
    tok_pad = jnp.concatenate([tokens, pad], axis=1)  # (G, S+1, d)
    xin = constrain(
        jax.vmap(lambda t, i: t[i])(tok_pad, idx),  # (G, E, C, d)
        "ged",
    )
    expert_out = _expert_ffn(p, cfg, xin)

    # combine: gather each (token, slot)'s output and weight by the gate
    def gather_one(out_e, eidx, sidx):
        return out_e[eidx, sidx]  # (S*k, d)

    flat_out = jax.vmap(gather_one)(
        expert_out, flat_e, jnp.minimum(flat_slot, cap - 1)
    )  # (G, S*k, d)
    w = (gate_vals * keep).reshape(g, -1, 1).astype(tokens.dtype)
    contrib = (flat_out * w).reshape(g, s_g, cfg.top_k, d)
    return contrib.sum(axis=2)
