"""Model-level API: params init, loss, train / prefill / decode steps.

Input conventions per family (DESIGN.md §Arch-applicability):

* LM families (dense/moe/ssm/hybrid): ``tokens``/``labels`` (B, S) int32.
* ``vlm`` / ``audio``: the modality frontend is a STUB — train/prefill take
  precomputed patch/frame ``embeds`` (B, S, d_model) plus (B, S) labels.
* encoder-only (hubert): bidirectional attention, no decode path.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.layers import Params, dense_init, rms_norm, softcap
from repro.models.transformer import (
    build_segments,
    decode_segments,
    forward_segments,
    init_segment_caches,
    segment_params,
)

__all__ = [
    "init_params", "abstract_params", "loss_fn", "prefill", "decode_step",
    "init_caches", "batch_spec", "uses_embeds",
]


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def uses_embeds(cfg: ArchConfig) -> bool:
    return cfg.family in ("vlm", "audio")


# --------------------------------------------------------------------------- #
# params
# --------------------------------------------------------------------------- #
def init_params(cfg: ArchConfig, key) -> Params:
    dt = _dtype(cfg)
    segs = build_segments(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: Params = {
        "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), scale=1.0,
                            dtype=dt),
        "final_norm": jnp.zeros((cfg.d_model,), dtype=dt),
        "segments": [
            segment_params(keys[2 + i], cfg, seg, dt)
            for i, seg in enumerate(segs)
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab), dtype=dt
        )
    return params


def abstract_params(cfg: ArchConfig) -> Params:
    """ShapeDtypeStruct tree (no allocation) — dry-run input."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0))
    )


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def _backbone(params: Params, cfg: ArchConfig, x, positions, causal, remat,
              scan_unroll: bool = False):
    segs = build_segments(cfg)
    x = forward_segments(params["segments"], cfg, segs, x, positions,
                         causal=causal, remat=remat, unroll=scan_unroll)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _logits(params: Params, cfg: ArchConfig, x) -> jnp.ndarray:
    from repro.sharding.act import constrain

    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = constrain(x @ head, "logits")
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def _embed_inputs(params: Params, cfg: ArchConfig, batch: Dict[str, Any]):
    from repro.sharding.act import constrain

    if uses_embeds(cfg):
        return constrain(batch["embeds"].astype(_dtype(cfg)), "btd")
    return constrain(
        jnp.take(params["embed"], batch["tokens"], axis=0), "btd"
    )


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, Any],
            remat: str = "full", scan_unroll: bool = False) -> jnp.ndarray:
    x = _embed_inputs(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    causal = not cfg.encoder_only
    x = _backbone(params, cfg, x, positions, causal, remat, scan_unroll)
    logits = _logits(params, cfg, x)
    labels = batch["labels"]
    # Sharding-friendly CE: take_along_axis over a vocab-sharded logits
    # tensor forces XLA to all-gather the whole (B,S,V) f32 array; the
    # iota==label masked reduction keeps the vocab axis sharded (the only
    # cross-shard traffic is the (B,S) partial sums).
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, Any],
            remat: str = "none", scan_unroll: bool = False) -> jnp.ndarray:
    """Full-sequence forward returning last-position logits (B, vocab)."""
    x = _embed_inputs(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x = _backbone(params, cfg, x, positions, not cfg.encoder_only, remat,
                  scan_unroll)
    return _logits(params, cfg, x[:, -1:, :])[:, 0]


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    segs = build_segments(cfg)
    return init_segment_caches(cfg, segs, batch, max_len, _dtype(cfg))


def decode_step(params: Params, caches, cfg: ArchConfig,
                tokens: jnp.ndarray, pos: jnp.ndarray,
                scan_unroll: bool = False) -> Tuple[jnp.ndarray, Any]:
    """tokens: (B, 1) int32; pos: (B,) current lengths → (logits, caches)."""
    segs = build_segments(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x, new_caches = decode_segments(params["segments"], caches, cfg, segs,
                                    x, pos, unroll=scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], new_caches


# --------------------------------------------------------------------------- #
# input specs
# --------------------------------------------------------------------------- #
def batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(_dtype(cfg))
    i32 = jnp.int32
    if shape.kind == "decode":
        spec: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
        return spec
    if uses_embeds(cfg):
        spec = {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    else:
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    return spec
