"""Attention blocks: GQA/MQA (qk-norm, qkv-bias, sliding window, softcap) and
MLA (DeepSeek latent compression), with prefill and single-token decode paths.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    Params,
    apply_rope,
    dense_init,
    rms_norm,
    rope,
    softcap,
)

__all__ = [
    "gqa_params", "gqa_apply", "gqa_decode",
    "mla_params", "mla_apply", "mla_decode",
    "init_kv_cache",
]


# --------------------------------------------------------------------------- #
# grouped-query attention
# --------------------------------------------------------------------------- #
def gqa_params(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype=dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype=dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype=dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype=dtype)
    return p


def _project_qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    from repro.sharding.act import constrain

    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = constrain(x @ p["wq"], "btf")
    k = constrain(x @ p["wk"], "btf")
    v = constrain(x @ p["wv"], "btf")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, h, hd), "bshd")
    k = constrain(k.reshape(b, s, kv, hd), "bshd")
    v = constrain(v.reshape(b, s, kv, hd), "bshd")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ArchConfig, q, k, v, mask) -> jnp.ndarray:
    """q: (B,S,H,D); k/v: (B,T,KV,D); mask: (B,1,S,T) or (S,T) additive."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // max(kv, 1)
    qg = q.reshape(b, s, kv, rep, hd)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    logits = softcap(logits, cfg.attn_softcap)
    logits = logits + mask
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", w, v)
    return out.reshape(b, s, h * hd)


def _causal_mask(s: int, t: int, window: Optional[int]) -> jnp.ndarray:
    """(1, 1, s, t) additive mask; t >= s, queries at positions t-s..t-1."""
    qpos = jnp.arange(s)[:, None] + (t - s)
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, -1e30)[None, None].astype(jnp.float32)


def gqa_apply(p: Params, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray, local: bool,
              causal: bool = True) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    cos, sin = rope(positions, cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    window = cfg.local_window if local else None
    if cfg.attn_impl == "pallas" and cfg.attn_softcap is None:
        # fused VMEM-resident kernel (TPU target; interpret-mode on CPU)
        from repro.kernels.flash_attention import flash_attention_pallas

        out = flash_attention_pallas(
            q, k, v, causal=causal, window=window,
            interpret=jax.default_backend() != "tpu",
        ).reshape(b, s, -1)
    elif cfg.attn_impl in ("chunked", "pallas"):
        from repro.models.flash import flash_attention

        out = flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
            pv_bf16=cfg.attn_pv_bf16,
        ).reshape(b, s, -1)
    else:
        if causal:
            mask = _causal_mask(s, s, window)
        else:
            mask = jnp.zeros((1, 1, s, s), dtype=jnp.float32)
        out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"]


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                  n_attn_layers: int):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.mla:
        # MLA caches the compressed latent + decoupled rope key
        width = cfg.kv_lora_rank + cfg.rope_head_dim
        return jnp.zeros((n_attn_layers, batch, max_len, width), dtype=dtype)
    return jnp.zeros((n_attn_layers, 2, batch, max_len, kv, hd), dtype=dtype)


def gqa_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
               cache: jnp.ndarray, pos: jnp.ndarray, local: bool
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,1,d); cache: (2,B,T,KV,D) with valid prefix [0,pos)."""
    b = x.shape[0]
    t = cache.shape[2]
    q, k, v = _project_qkv(p, cfg, x)
    cos, sin = rope(pos[:, None], cfg.resolved_head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # append at position pos (static cache length; dry-run uses full window)
    cache_k = jax.vmap(
        lambda c, kk, pp: jax.lax.dynamic_update_slice(c, kk, (pp, 0, 0))
    )(cache[0], k, jnp.minimum(pos, t - 1))
    cache_v = jax.vmap(
        lambda c, vv, pp: jax.lax.dynamic_update_slice(c, vv, (pp, 0, 0))
    )(cache[1], v, jnp.minimum(pos, t - 1))
    kpos = jnp.arange(t)[None, :]
    ok = kpos <= pos[:, None]
    if local and cfg.local_window is not None:
        ok &= kpos > (pos[:, None] - cfg.local_window)
    # (B, kv, rep, s=1, T) broadcast layout
    mask = jnp.where(ok, 0.0, -1e30)[:, None, None, None, :].astype(jnp.float32)
    out = _sdpa(cfg, q, cache_k, cache_v, mask)
    out = out @ p["wo"]
    return out, jnp.stack([cache_k, cache_v])


# --------------------------------------------------------------------------- #
# MLA (DeepSeek-V3 multi-head latent attention)
# --------------------------------------------------------------------------- #
def mla_params(key, cfg: ArchConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (d, qr), dtype=dtype),
        "q_a_norm": jnp.zeros((qr,), dtype=dtype),
        "wq_b": dense_init(ks[1], (qr, h * (dn + dr)), dtype=dtype),
        "wkv_a": dense_init(ks[2], (d, kvr + dr), dtype=dtype),
        "kv_a_norm": jnp.zeros((kvr,), dtype=dtype),
        "wkv_b": dense_init(ks[3], (kvr, h * (dn + dv)), dtype=dtype),
        "wo": dense_init(ks[4], (h * dv, d), dtype=dtype),
    }


def _mla_qkv(p: Params, cfg: ArchConfig, x, positions):
    from repro.sharding.act import constrain

    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps) @ p["wq_b"]
    q = constrain(q.reshape(b, s, h, dn + dr), "bshd")
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = constrain(x @ p["wkv_a"], "btd")
    latent, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    latent = rms_norm(latent, p["kv_a_norm"], cfg.norm_eps)
    cos, sin = rope(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # single shared head
    return q_nope, q_rope, latent, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask):
    from repro.sharding.act import constrain

    b, s, h, dn = q_nope.shape
    t = latent.shape[1]
    dv = cfg.v_head_dim
    wkv = p["wkv_b"].reshape(cfg.kv_lora_rank, h, dn + dv)
    k_nope_w, v_w = wkv[..., :dn], wkv[..., dn:]
    # absorb k projection into the query (latent stays compressed — the MLA
    # trick): q_eff (b,s,h,kvr) = q_nope · k_nope_wᵀ
    q_eff = constrain(
        jnp.einsum("bshd,rhd->bshr", q_nope, k_nope_w), "bshr"
    )
    logits = constrain(
        jnp.einsum("bshr,btr->bhst", q_eff, latent), "bhst"
    ).astype(jnp.float32)
    logits += constrain(
        jnp.einsum("bshd,btd->bhst", q_rope, k_rope[:, :, 0, :]), "bhst"
    ).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(dn + cfg.rope_head_dim))
    logits = logits + mask
    w = constrain(
        jax.nn.softmax(logits, axis=-1), "bhst"
    ).astype(latent.dtype)
    ctx = constrain(jnp.einsum("bhst,btr->bshr", w, latent), "bshr")
    out = jnp.einsum("bshr,rhd->bshd", ctx, v_w)
    return constrain(out.reshape(b, s, h * dv), "btf") @ p["wo"]


def mla_apply(p: Params, cfg: ArchConfig, x, positions, local: bool,
              causal: bool = True) -> jnp.ndarray:
    del local
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, positions)
    if cfg.attn_impl == "chunked":
        # Absorbed MLA *is* MQA: one shared (kv_lora+rope_dim)-wide key
        # (latent ⊕ rope-key) and values = latent — reuse flash attention
        # with the MLA scale, then project ctx through W_kv_b's value half.
        from repro.models.flash import flash_attention
        from repro.sharding.act import constrain

        wkv = p["wkv_b"].reshape(
            cfg.kv_lora_rank, h, cfg.nope_head_dim + cfg.v_head_dim
        )
        k_nope_w = wkv[..., : cfg.nope_head_dim]
        v_w = wkv[..., cfg.nope_head_dim:]
        q_eff = constrain(
            jnp.einsum("bshd,rhd->bshr", q_nope, k_nope_w), "bshr"
        )
        q_cat = jnp.concatenate([q_eff, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [latent, k_rope[:, :, 0, :]], axis=-1
        )[:, :, None, :]
        ctx = flash_attention(
            q_cat, k_cat, latent[:, :, None, :], causal=causal,
            scale=1.0 / float(
                (cfg.nope_head_dim + cfg.rope_head_dim) ** 0.5
            ),
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
            pv_bf16=cfg.attn_pv_bf16,
        )  # (b, s, h, kv_lora)
        ctx = constrain(ctx, "bshr")
        out = jnp.einsum("bshr,rhd->bshd", ctx, v_w)
        return constrain(
            out.reshape(b, s, h * cfg.v_head_dim), "btf"
        ) @ p["wo"]
    mask = _causal_mask(s, s, None) if causal else 0.0
    return _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask)


def mla_decode(p: Params, cfg: ArchConfig, x, cache, pos, local: bool
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cache: (B, T, kv_lora + rope_hd) compressed latent+rope-key cache."""
    del local
    b = x.shape[0]
    t = cache.shape[1]
    q_nope, q_rope, latent, k_rope = _mla_qkv(
        p, cfg, x, pos[:, None]
    )
    new_entry = jnp.concatenate([latent, k_rope[:, :, 0, :]], axis=-1)
    cache = jax.vmap(
        lambda c, e, pp: jax.lax.dynamic_update_slice(c, e, (pp, 0))
    )(cache, new_entry, jnp.minimum(pos, t - 1))
    lat_t = cache[..., : cfg.kv_lora_rank]
    kr_t = cache[..., cfg.kv_lora_rank:][:, :, None, :]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.where(kpos <= pos[:, None], 0.0, -1e30)[
        :, None, None, :
    ].astype(jnp.float32)
    out = _mla_attend(p, cfg, q_nope, q_rope, lat_t, kr_t, mask)
    return out, cache
