"""Flash-style attention in pure JAX: online-softmax over key blocks with a
query-block scan and causal block skipping.

Memory: O(S·block) instead of O(S²) — this is what makes the 32k prefill
cells fit HBM and is the first §Perf hillclimb change (the naive path stays
available as the measured baseline, cfg.attn_impl="naive").

Block skipping: for causal masks, key blocks strictly above the query
block's diagonal are skipped with ``lax.cond`` (halves attention FLOPs); for
sliding windows, blocks left of the window are skipped the same way.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention"]

NEG = -1e30


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    v: jnp.ndarray,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,  # absolute position of q[0] (= Sk - Sq when cached)
    softcap: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
    pv_bf16: bool = False,  # §Perf: bf16 P·V matmul (f32 accumulator)
    scale: Optional[float] = None,  # default 1/sqrt(head_dim)
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[3]  # may differ from d (MLA: values are the latent)
    rep = h // max(kv, 1)
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))

    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    qp = _pad_to(q, qc, 1)
    kp = _pad_to(k, kc, 1)
    vp = _pad_to(v, kc, 1)
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc

    qg = qp.reshape(b, nq, qc, kv, rep, d)
    kg = kp.reshape(b, nk, kc, kv, d)
    vg = vp.reshape(b, nk, kc, kv, dv)

    kpos_base = jnp.arange(kc)
    qpos_base = jnp.arange(qc)

    def q_block(_, qi):
        qb = qg[:, qi]  # (b, qc, kv, rep, d)
        qpos = q_offset + qi * qc + qpos_base  # absolute

        def k_block(carry, kj):
            m, l, acc = carry

            def compute(args):
                m, l, acc = args
                kb = kg[:, kj]  # (b, kc, kv, d)
                vb = vg[:, kj]
                kpos = kj * kc + kpos_base
                logits = jnp.einsum(
                    "bqkrd,bckd->bkrqc", qb, kb
                ).astype(jnp.float32) * scale
                if softcap is not None:
                    logits = softcap * jnp.tanh(logits / softcap)
                ok = jnp.ones((qc, kc), dtype=bool)
                if causal:
                    ok &= kpos[None, :] <= qpos[:, None]
                if window is not None:
                    ok &= kpos[None, :] > qpos[:, None] - window
                ok &= (kpos[None, :] < sk)  # key padding
                logits = jnp.where(ok[None, None, None], logits, NEG)
                m2 = jnp.maximum(m, logits.max(-1))
                p = jnp.exp(logits - m2[..., None])
                alpha = jnp.exp(m - m2)
                l2 = alpha * l + p.sum(-1)
                if pv_bf16:
                    pv = jax.lax.dot_general(
                        p.astype(jnp.bfloat16), vb.astype(jnp.bfloat16),
                        (((4,), (1,)), ((0, 1), (0, 2))),
                        preferred_element_type=jnp.float32,
                    )  # (b, kv, rep, qc, d)
                else:
                    pv = jnp.einsum(
                        "bkrqc,bckd->bkrqd", p, vb.astype(jnp.float32)
                    )
                acc2 = alpha[..., None] * acc + pv
                return m2, l2, acc2

            if causal or window is not None:
                lo = qpos[0]
                hi = qpos[-1]
                skip = jnp.zeros((), dtype=bool)
                if causal:
                    skip |= kj * kc > hi  # block entirely above diagonal
                if window is not None:
                    skip |= (kj + 1) * kc - 1 <= lo - window
                m, l, acc = jax.lax.cond(
                    skip, lambda args: args, compute, (m, l, acc)
                )
            else:
                m, l, acc = compute((m, l, acc))
            return (m, l, acc), None

        init = (
            jnp.full((b, kv, rep, qc), NEG, dtype=jnp.float32),
            jnp.zeros((b, kv, rep, qc), dtype=jnp.float32),
            jnp.zeros((b, kv, rep, qc, dv), dtype=jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(k_block, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)  # (b, kv, rep, qc, d)

    _, blocks = jax.lax.scan(q_block, None, jnp.arange(nq))
    # blocks: (nq, b, kv, rep, qc, dv) → (b, sq, h, dv)
    out = jnp.moveaxis(blocks, 0, 3)  # (b, kv, rep, nq, qc, dv)
    out = out.reshape(b, kv, rep, nq * qc, dv)[:, :, :, :sq, :]
    out = jnp.moveaxis(out.reshape(b, h, sq, dv), 1, 2)
    return out
