from repro.models.model import (
    abstract_params,
    batch_spec,
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill,
    uses_embeds,
)

__all__ = [
    "abstract_params",
    "batch_spec",
    "decode_step",
    "init_caches",
    "init_params",
    "loss_fn",
    "prefill",
    "uses_embeds",
]
