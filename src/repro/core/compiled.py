"""Compiled tensor plans: lower a cached plan to one vectorized program.

The morsel interpreter (``core/executor.py``) pays a Python round-trip per
(morsel × operator): generator stepping, per-morsel decision groups, and one
impute flush per (morsel, attr).  For *hot* query signatures the serving
layer re-runs the same plan shape over and over, so this module lowers the
rewritten SPJ(+aggregate) tree once into a :class:`CompiledPlan` — a
straight-line whole-relation program over the dense column/mask arrays of
``MaskedRelation``:

* selections   → one vectorized mask op per σ̂ (``(present & passes) | absent``);
* the join spine → ``triggers.multi_match`` over int64 key arrays, which
  routes through ``kernels.ops.hash_join_match`` under ``ref``/``pallas``
  join impls (bit-identical to the numpy oracle);
* aggregates   → reductions; grouped COUNT/SUM/AVG/MIN/MAX lower to
  ``kernels.ops.segment_reduce`` over ``np.unique`` group ids.

QUIP's impute-decision points become a staged *pre-pass*: at each decision
point the exact needed-cell set is just the missing rows that survived the
upstream mask ops, so one batched ``ImputationService.request`` per
(table, attr) flushes before the vectorized op that consumes the values.
``impute_batches`` drops from O(morsels × attrs) to O(operators) while
``imputations`` (deduplicated cells) stays bit-identical.

Exactness contract — compilation is only attempted when whole-relation
execution provably requests the *same cell set* as morsel streaming:

* strategy ``eager`` (or ``imputedb``, its alias): the decision function
  imputes every missing row at every operator, so the needed set at each
  decision point is morsel-size-independent.  ``lazy``/``adaptive`` may
  defer per (morsel × pattern) group → :class:`CompileFallback`.
* ``use_vf=False``: VF filter sets / bloom cascades prune as a function of
  *when* blooms complete mid-stream → fallback when active.
* no active MIN/MAX pushdown: its bound tightens morsel-by-morsel →
  fallback when ``minmax_opt`` would install one.

Under those conditions eager never pads outer rows (every key is imputed,
verify failures drop), so ρ reduces to sequential per-attribute imputation
over the surviving rows plus ``full_verify`` — no fixpoint, no BF_Join.
``execute_quip`` catches :class:`CompileFallback`, bumps
``counters.compile_fallbacks``, and runs the interpreter, so answers stay
bit-identical in every configuration.

Dispatch mirrors the kernel layer: ``QUIP_EXEC_IMPL=interp|compiled`` (see
``resolve_exec_impl``); the serving stack promotes hot signatures on the
Kth plan-cache hit (``QuipService(compile_after_hits=K)``) and keys cached
artifacts by table epochs (docs/compiled.md).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.env import env_choice
from repro.core.operators import full_verify, op_kind, verify_values
from repro.obs.trace import NULL_SPAN, NULL_TRACER
from repro.core.plan import (
    AggregateNode,
    JoinNode,
    PlanNode,
    ProjectNode,
    Query,
    RhoNode,
    ScanNode,
    SelectNode,
    base_tables,
    clone_plan,
    walk,
)
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema, table_of
from repro.core.stats import ExecutionCounters, RuntimeStats
from repro.core.triggers import multi_match, resolve_join_impl
from repro.core.vflist import rewrite_for_quip
from repro.kernels import ops as kops

__all__ = [
    "CompileFallback",
    "CompiledPlan",
    "compile_plan",
    "resolve_exec_impl",
]

_EXEC_IMPLS = ("interp", "compiled")


def resolve_exec_impl(impl: Optional[str] = None) -> str:
    """Executor dispatch: explicit ``impl`` > ``QUIP_EXEC_IMPL`` env >
    ``"interp"`` (the morsel interpreter).  ``"compiled"`` lowers eligible
    plans via :func:`compile_plan` and falls back per query otherwise."""
    if impl is not None:
        if impl not in _EXEC_IMPLS:
            raise ValueError(f"unknown exec impl {impl!r}")
        return impl
    return env_choice("QUIP_EXEC_IMPL", _EXEC_IMPLS, "interp")


class CompileFallback(Exception):
    """This (plan, strategy, knobs) combination must run on the interpreter
    to keep answers bit-identical; ``reason`` says which condition failed."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


def compile_plan(
    query: Query,
    plan: PlanNode,
    tables: Dict[str, MaskedRelation],
    strategy: str,
    *,
    use_vf: bool = True,
    minmax_opt: bool = True,
    join_impl: Optional[str] = None,
    segment_impl: Optional[str] = None,
) -> "CompiledPlan":
    """Lower ``plan`` for ``query`` to a :class:`CompiledPlan`, or raise
    :class:`CompileFallback` when the configuration needs the interpreter.

    ``tables`` supplies schemas only (column names for the ρ rewrite and
    join normalization) — the artifact is stateless and reusable across
    sessions; per-run data arrives via :meth:`CompiledPlan.run`.
    """
    if strategy == "imputedb":  # same alias remap as QuipExecutor
        strategy, use_vf, minmax_opt = "eager", False, False
    if strategy != "eager":
        raise CompileFallback(
            f"strategy {strategy!r}: decision function may defer imputations"
            " (or has no plan to lower)"
        )
    if use_vf:
        raise CompileFallback(
            "VF-list / bloom-cascade path required (pruning depends on"
            " mid-stream bloom completion)"
        )
    agg = query.aggregate
    if (
        minmax_opt
        and agg is not None
        and agg.op in ("max", "min")
        and agg.attr is not None
        and agg.group_by is None
    ):
        raise CompileFallback(
            "MIN/MAX pushdown bound is maintained morsel-by-morsel"
        )
    ta = {t: tables[t].column_names() for t in query.tables}
    root = rewrite_for_quip(clone_plan(plan), query, ta)
    return CompiledPlan(
        query,
        root,
        table_cols=ta,
        join_impl=resolve_join_impl(join_impl),
        segment_impl=kops.resolve_segment_impl(segment_impl),
    )


class CompiledPlan:
    """One lowered plan: the rewritten tree plus the static structure the
    straight-line program needs (top aggregate/projection, join orientation,
    base-table column order).  Holds no per-run state — :meth:`run` threads
    tables and engine through a private :class:`_CompiledRun`, so one
    artifact serves any number of sessions."""

    def __init__(
        self,
        query: Query,
        root: PlanNode,
        *,
        table_cols: Dict[str, List[str]],
        join_impl: str,
        segment_impl: str,
    ):
        self.query = query
        self.root = root
        self.table_cols = table_cols
        self.join_impl = join_impl
        self.segment_impl = segment_impl

        self.agg = None
        self.proj: Optional[Tuple[str, ...]] = None
        body = root
        if isinstance(root, AggregateNode):
            self.agg = root.agg
            body = root.children[0]
        elif isinstance(root, ProjectNode):
            self.proj = root.attrs
            body = root.children[0]
        self.body = body

        # join orientation, keyed by node_id (mirrors QuipExecutor.__init__)
        self.join_attrs: Dict[int, Tuple[str, str]] = {}
        self.join_side_tables: Dict[
            int, Tuple[Tuple[str, ...], Tuple[str, ...]]
        ] = {}
        for n in walk(root):
            if not isinstance(n, JoinNode):
                continue
            l_tabs = base_tables(n.children[0])
            r_tabs = base_tables(n.children[1])
            if table_of(n.pred.left_attr) in l_tabs:
                l_attr, r_attr = n.pred.left_attr, n.pred.right_attr
            else:
                l_attr, r_attr = n.pred.right_attr, n.pred.left_attr
            self.join_attrs[n.node_id] = (l_attr, r_attr)
            self.join_side_tables[n.node_id] = (l_tabs, r_tabs)

    def run(self, tables: Dict[str, MaskedRelation], engine) -> "ExecutionResult":
        """Execute over ``tables`` (the session's private copies), requesting
        imputations through ``engine``.  Returns the same
        :class:`ExecutionResult` shape as ``QuipExecutor.run``."""
        return _CompiledRun(self, tables, engine).execute()


class _CompiledRun:
    """Per-execution state of one :class:`CompiledPlan` run: whole-relation
    recursion over the tree, one batched impute request per decision point,
    interpreter-identical masks, counters, and aggregate semantics."""

    def __init__(self, cp: CompiledPlan, tables: Dict[str, MaskedRelation],
                 engine):
        self.cp = cp
        self.query = cp.query
        self.tables = tables
        self.engine = engine
        self.stats: RuntimeStats = engine.stats
        self.counters: ExecutionCounters = engine.counters
        # observability rides on the engine, same as the interpreter
        self.tracer = getattr(engine, "tracer", NULL_TRACER)
        self.provenance = getattr(engine, "provenance", None)

    # full_verify() notifies drops for bloom-liveness bookkeeping; the
    # compiled path has no VF machinery, so drops need no side effects
    def on_rows_dropped(self, dropped: MaskedRelation,
                        node: Optional[PlanNode] = None) -> None:
        return None

    def execute(self) -> "ExecutionResult":
        from repro.core.executor import ExecutionResult

        t0 = time.perf_counter()
        self.counters.join_impl = self.cp.join_impl
        self.counters.exec_impl = "compiled"
        self.counters.compiled_hits += 1
        tr = self.tracer
        with (tr.span("compiled_exec", join_impl=self.cp.join_impl)
              if tr.enabled else NULL_SPAN) as sp:
            rel = self._node(self.cp.body)
            if self.cp.agg is not None:
                rel = self._aggregate(rel, self.cp.agg)
            elif self.cp.proj is not None:
                rel = rel.project(list(self.cp.proj))
            sp.set(rows=rel.num_rows)
        self.counters.wall_seconds = (
            time.perf_counter() - t0
        ) + self.engine.simulated_seconds
        return ExecutionResult(rel, self.counters, self.stats, self.cp.root)

    # ------------------------------------------------------------------ #
    # whole-relation operator program
    # ------------------------------------------------------------------ #
    def _node(self, node: PlanNode) -> MaskedRelation:
        if isinstance(node, ScanNode):
            rel = self.tables[node.table]
            return rel.take(np.arange(rel.num_rows))
        if isinstance(node, SelectNode):
            return self._select(node, self._node(node.children[0]))
        if isinstance(node, JoinNode):
            return self._join(node)
        if isinstance(node, RhoNode):
            return self._rho(node, self._node(node.children[0]))
        raise TypeError(type(node))  # pragma: no cover - Π/γ handled on top

    # -- σ̂: mask op + one batched impute at the decision point ----------- #
    def _select(self, node: SelectNode, rel: MaskedRelation) -> MaskedRelation:
        if rel.num_rows == 0:
            return rel
        pred = node.pred
        attr = pred.attr
        present = rel.is_present(attr)
        missing = rel.is_missing(attr)
        absent = rel.is_absent(attr)
        passes = pred.evaluate_values(rel.values(attr))
        keep = (present & passes) | absent
        self.stats.record_selectivity(
            node.node_id, int((present & passes).sum()), int(present.sum())
        )
        rows = np.nonzero(missing)[0]
        if len(rows):
            # eager pre-pass: the needed-cell set here is exactly the rows
            # still missing after upstream ops — flush them as one batch
            ok_rows, _bad = self._impute(node, rel, attr, rows,
                                         extra_check=pred)
            keep[ok_rows] = True
        out = rel.filter(keep)
        self.counters.temp_tuples += out.num_rows
        return out

    # -- ⋈̂: kernel join spine over dense int64 key arrays ---------------- #
    def _join(self, node: JoinNode) -> MaskedRelation:
        l_attr, r_attr = self.cp.join_attrs[node.node_id]
        build = self._prepare_side(node, r_attr, self._node(node.children[1]))
        b_present = build.is_present(r_attr)
        b_keys = np.where(
            b_present, build.values(r_attr), np.int64(-(2 ** 62))
        ).astype(np.int64)
        probe = self._prepare_side(node, l_attr, self._node(node.children[0]))
        if probe.num_rows == 0:
            out = self._normalize(node, probe.hstack(build.take(
                np.zeros(0, dtype=np.int64))))
            return out
        p_present = probe.is_present(l_attr)
        t0 = time.perf_counter()
        probe_keys = np.where(
            p_present, probe.values(l_attr), np.int64(-(2 ** 61))
        ).astype(np.int64)
        tr = self.tracer
        with (tr.span("kernel:multi_match", cat="kernel", node=node.node_id,
                      impl=self.cp.join_impl, build=len(b_keys),
                      probe=len(probe_keys))
              if tr.enabled else NULL_SPAN):
            p_idx, b_idx = multi_match(
                b_keys, probe_keys, impl=self.cp.join_impl
            )
        dt = time.perf_counter() - t0
        n_present = int(p_present.sum())
        self.counters.join_tests += n_present
        self.stats.record_join(
            node.node_id, tests=max(n_present, 1), tuples=max(n_present, 1),
            seconds=dt,
        )
        denom = max(n_present * max(len(b_keys), 1), 1)
        self.stats.record_selectivity(node.node_id, len(p_idx), denom)
        joined = probe.take(p_idx).hstack(build.take(b_idx))
        out = self._normalize(node, joined)
        self.counters.temp_tuples += out.num_rows
        return out

    def _prepare_side(self, node: JoinNode, attr: str,
                      rel: MaskedRelation) -> MaskedRelation:
        """Eager ⋈̂ operand prep: one batched impute of the side's missing
        keys, verify-failed rows dropped (no deferral, no outer padding)."""
        if rel.num_rows == 0:
            return rel
        rows = np.nonzero(rel.is_missing(attr))[0]
        if len(rows) == 0:
            return rel
        _ok, bad = self._impute(node, rel, attr, rows)
        if len(bad):
            keep = np.ones(rel.num_rows, dtype=bool)
            keep[bad] = False
            rel = rel.filter(keep)
        return rel

    # -- ρ: sequential per-attribute imputation + full verify ------------- #
    def _rho(self, node: RhoNode, rel: MaskedRelation) -> MaskedRelation:
        if rel.num_rows == 0:
            return rel
        sel_attrs = [p.attr for p in self.query.selections]
        join_attrs = [a for j in self.query.joins for a in j.attrs]
        other = [a for a in node.attrs if a not in sel_attrs + join_attrs]
        for attr in sel_attrs + join_attrs + other:
            if not rel.has_column(attr):
                continue
            rows = np.nonzero(rel.is_missing(attr))[0]
            if len(rows) == 0:
                continue
            _ok, bad = self._impute(node, rel, attr, rows)
            if len(bad):
                keep = np.ones(rel.num_rows, dtype=bool)
                keep[bad] = False
                rel = rel.filter(keep)
            if rel.num_rows == 0:
                return rel
        rel = full_verify(self, rel)
        self.counters.temp_tuples += rel.num_rows
        return rel

    # -- shared impute + verify (decision-point flush) -------------------- #
    def _impute(
        self,
        node: PlanNode,
        rel: MaskedRelation,
        attr: str,
        rows: np.ndarray,
        extra_check=None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """``impute_rows`` minus the VF machinery (no bloom inserts, no join
        snapshot writeback — neither exists on the compiled path); returns
        (passed_rows, failed_rows)."""
        if len(rows) == 0:
            return rows, rows
        t = table_of(attr)
        tids = rel.tids[t][rows]
        ok_tid = tids >= 0
        rows, tids = rows[ok_tid], tids[ok_tid]
        if len(rows) == 0:
            return rows, rows
        prov = self.provenance
        if prov is not None:
            # explain parity with the interpreter: the compiled path only
            # exists for eager, where every decision is "impute now"
            prov.record_decision(
                op_kind(node), node.node_id, attr, (), len(rows), True, {},
                "strategy:eager")
            with prov.at(op_kind(node), node.node_id):
                values = self._request_values(t, attr, tids)
        else:
            values = self._request_values(t, attr, tids)
        passed = verify_values(node, attr, values)
        if extra_check is not None:
            passed &= extra_check.evaluate_values(values)
        rel.set_values(attr, rows, values)
        return rows[passed], rows[~passed]

    def _request_values(self, table: str, attr: str,
                        tids: np.ndarray) -> np.ndarray:
        request = getattr(self.engine, "request", None)
        if request is not None:
            return request(table, attr, tids)
        self.engine.enqueue(table, attr, tids)
        self.engine.flush()
        return self.engine.lookup(table, attr, tids)

    def _normalize(self, node: JoinNode, rel: MaskedRelation) -> MaskedRelation:
        l_tabs, r_tabs = self.cp.join_side_tables[node.node_id]
        cols = []
        for t in l_tabs + r_tabs:
            cols.extend(self.cp.table_cols[t])
        return rel.project(cols)

    # -- γ: grouped aggregates as segment reductions ---------------------- #
    def _aggregate(self, rel: MaskedRelation, agg) -> MaskedRelation:
        from repro.core.executor import _aggregate as interp_aggregate

        if agg.group_by is None:
            # scalar reduction — nothing to segment; share the interpreter's
            # exact path (incl. the NULL-over-zero-inputs absent bit)
            return interp_aggregate(rel, agg)
        op, attr, gb = agg.op, agg.attr, agg.group_by
        out_name = f"{op}({attr or '*'})"
        kind = "int" if op == "count" else (
            "float" if op in ("avg", "sum") else
            ("float" if attr and rel.schema.column(attr).kind == "float"
             else "int")
        )
        keys = rel.values(gb)
        uniq, inv = np.unique(keys, return_inverse=True)
        num_groups = len(uniq)
        if attr:
            pres = rel.is_present(attr)
            seg = inv[pres]
            vals = rel.values(attr)[pres]
        else:
            seg = inv
            vals = None
        impl = self.cp.segment_impl
        tr = self.tracer
        with (tr.span("kernel:segment_reduce", cat="kernel", op=op,
                      impl=impl, groups=num_groups)
              if tr.enabled else NULL_SPAN):
            return self._aggregate_grouped(
                rel, op, attr, gb, out_name, kind, uniq, seg, vals,
                num_groups, impl)

    def _aggregate_grouped(self, rel, op, attr, gb, out_name, kind, uniq,
                           seg, vals, num_groups, impl):
        counts = kops.segment_reduce(None, seg, num_groups, "count", impl=impl)
        if op == "count":
            out_vals = counts
            null_rows = np.zeros(num_groups, dtype=bool)
        else:
            null_rows = counts == 0
            if op == "sum":
                red = kops.segment_reduce(vals, seg, num_groups, "sum",
                                          impl=impl)
            elif op == "avg":
                # np.mean accumulates integer inputs in float64; matching
                # cast-then-sum keeps the division bit-identical
                red = kops.segment_reduce(
                    vals.astype(np.float64), seg, num_groups, "sum", impl=impl
                )
                red = red / np.maximum(counts, 1)
            else:
                red = kops.segment_reduce(vals, seg, num_groups, op, impl=impl)
            # zero non-NULL inputs in a group → NULL: clean 0 payload under
            # the absent bit (replaces the reduction identity fill)
            out_vals = np.where(null_rows, 0, red)
        schema = Schema(
            "agg",
            [ColumnSpec(gb, rel.schema.column(gb).kind),
             ColumnSpec(out_name, kind)],
        )
        out = MaskedRelation.from_columns(
            schema, {gb: uniq, out_name: out_vals}
        )
        if null_rows.any():
            out.absent[out_name][null_rows] = True
        return out
