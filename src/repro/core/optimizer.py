"""Plan generators.

QUIP is an *executor*: it takes a plan from an external optimizer (paper §3).
We provide the two externals used in the paper's experiments (Fig. 13):

* :func:`naive_plan` — PostgreSQL-style: push every selection to its scan,
  greedy left-deep join order by estimated output cardinality.  Ignores
  imputation cost.
* :func:`imputedb_plan` — ImputeDB-style [Cambronero et al., VLDB'17]: joint
  cost model (query processing + eager imputation cost), searching left-deep
  join orders × selection push/pull placements.

Both return an SPJ tree (no ρ/Π — the QUIP rewriter adds those).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import (
    JoinNode,
    PlanNode,
    Query,
    ScanNode,
    SelectNode,
    base_tables,
)
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import table_of

__all__ = ["TableStats", "collect_stats", "naive_plan", "imputedb_plan"]


@dataclasses.dataclass
class TableStats:
    cardinality: Dict[str, int]
    missing_rate: Dict[str, float]  # per qualified attr
    distinct: Dict[str, int]  # per qualified attr (over present values)
    selectivity: Dict[str, float]  # per str(selection predicate)


def collect_stats(
    tables: Dict[str, MaskedRelation], query: Query
) -> TableStats:
    card = {t: r.num_rows for t, r in tables.items()}
    mrate, dist, sel = {}, {}, {}
    for t, rel in tables.items():
        for name in rel.column_names():
            m = rel.is_missing(name)
            mrate[name] = float(m.mean()) if len(m) else 0.0
            present = rel.values(name)[rel.is_present(name)]
            dist[name] = max(1, len(np.unique(present)))
    for p in query.selections:
        rel = tables[p.table]
        sel[str(p)] = p.selectivity_estimate(rel)
    return TableStats(card, mrate, dist, sel)


# --------------------------------------------------------------------------- #
# cost simulation shared by both planners
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _SimState:
    card: float  # estimated rows at this point
    per_table: Dict[str, float]  # estimated surviving base rows per table
    imputed: set  # attrs already (eagerly) imputed
    qp_cost: float = 0.0
    imp_cost: float = 0.0


def _impute_touch(
    st: _SimState, attr: str, stats: TableStats, impute_cost: Dict[str, float]
) -> None:
    """Eager imputation: first operator touching attr imputes its remaining
    missing values (ImputeDB placement-of-impute-operator behaviour)."""
    if attr in st.imputed:
        return
    st.imputed.add(attr)
    t = table_of(attr)
    rows = st.per_table.get(t, stats.cardinality.get(t, 1))
    st.imp_cost += rows * stats.missing_rate.get(attr, 0.0) * impute_cost.get(attr, 1.0)


def _apply_selection(st: _SimState, p: SelectionPredicate, stats: TableStats,
                     impute_cost: Dict[str, float]) -> None:
    _impute_touch(st, p.attr, stats, impute_cost)
    s = stats.selectivity.get(str(p), 0.5)
    st.qp_cost += st.card
    st.card *= s
    t = p.table
    st.per_table[t] = st.per_table.get(t, stats.cardinality[t]) * s


def _apply_join(st: _SimState, right_card: float, p: JoinPredicate,
                stats: TableStats, impute_cost: Dict[str, float],
                right_table: str) -> None:
    for a in p.attrs:
        _impute_touch(st, a, stats, impute_cost)
    d = max(stats.distinct.get(p.left_attr, 1), stats.distinct.get(p.right_attr, 1))
    st.qp_cost += st.card + right_card  # hash build + probe
    st.card = st.card * right_card / max(d, 1)
    st.per_table.setdefault(right_table, right_card)


# --------------------------------------------------------------------------- #
# plan construction helpers
# --------------------------------------------------------------------------- #
def _leaf(table: str, pushed: Sequence[SelectionPredicate]) -> PlanNode:
    node: PlanNode = ScanNode(table)
    for p in pushed:
        node = SelectNode(p, node)
    return node


def _order_joins(order: Sequence[str], joins: Sequence[JoinPredicate]
                 ) -> Optional[List[Tuple[JoinPredicate, str]]]:
    """Left-deep: returns [(pred, right_table)] or None if order needs a
    cross product (we reject those orders)."""
    joined = {order[0]}
    remaining = list(joins)
    out = []
    for t in order[1:]:
        hit = None
        for j in remaining:
            lt, rt = j.left_table, j.right_table
            if (lt in joined and rt == t) or (rt in joined and lt == t):
                hit = j
                break
        if hit is None:
            return None
        remaining.remove(hit)
        joined.add(t)
        out.append((hit, t))
    # attach residual join predicates (cycles) as additional joins on the top
    for j in remaining:
        out.append((j, j.right_table))
    return out


def _build(order: Sequence[str], join_seq, pushed: Dict[str, List[SelectionPredicate]],
           pulled: Sequence[SelectionPredicate]) -> PlanNode:
    node = _leaf(order[0], pushed.get(order[0], []))
    for pred, rt in join_seq:
        node = JoinNode(pred, node, _leaf(rt, pushed.get(rt, [])))
    for p in pulled:
        node = SelectNode(p, node)
    return node


def _simulate(order, join_seq, pushed, pulled, stats, impute_cost, lam) -> float:
    st = _SimState(
        card=float(stats.cardinality[order[0]]),
        per_table={order[0]: float(stats.cardinality[order[0]])},
        imputed=set(),
    )
    for p in pushed.get(order[0], []):
        _apply_selection(st, p, stats, impute_cost)
    for pred, rt in join_seq:
        rc = float(stats.cardinality[rt])
        for p in pushed.get(rt, []):
            rc *= stats.selectivity.get(str(p), 0.5)
            _impute_touch(st, p.attr, stats, impute_cost)
        _apply_join(st, rc, pred, stats, impute_cost, rt)
    for p in pulled:
        _apply_selection(st, p, stats, impute_cost)
    return st.qp_cost + lam * st.imp_cost


# --------------------------------------------------------------------------- #
# public planners
# --------------------------------------------------------------------------- #
def naive_plan(query: Query, stats: TableStats) -> PlanNode:
    """PostgreSQL-ish: selections pushed to scans; greedy join order."""
    pushed: Dict[str, List[SelectionPredicate]] = {}
    for p in query.selections:
        pushed.setdefault(p.table, []).append(p)

    # greedy smallest-effective-cardinality first
    eff = {}
    for t in query.tables:
        c = float(stats.cardinality[t])
        for p in pushed.get(t, []):
            c *= stats.selectivity.get(str(p), 0.5)
        eff[t] = c
    best_order, best_seq, best_cost = None, None, float("inf")
    for order in itertools.permutations(query.tables):
        seq = _order_joins(order, query.joins)
        if seq is None:
            continue
        cost = _simulate(order, seq, pushed, [], stats, {}, 0.0) + eff[order[0]]
        if cost < best_cost:
            best_order, best_seq, best_cost = order, seq, cost
    assert best_order is not None, "query graph is disconnected"
    return _build(best_order, best_seq, pushed, [])


def imputedb_plan(
    query: Query,
    stats: TableStats,
    impute_cost: Optional[Dict[str, float]] = None,
    lam: float = 1.0,
) -> PlanNode:
    """ImputeDB-style joint optimization: search join orders × selection
    placements under qp_cost + lam * imputation_cost (eager imputation)."""
    impute_cost = impute_cost or {}
    sels = list(query.selections)
    best, best_cost = None, float("inf")
    for order in itertools.permutations(query.tables):
        seq = _order_joins(order, query.joins)
        if seq is None:
            continue
        for mask in range(1 << len(sels)):
            pushed: Dict[str, List[SelectionPredicate]] = {}
            pulled: List[SelectionPredicate] = []
            for i, p in enumerate(sels):
                if mask >> i & 1:
                    pushed.setdefault(p.table, []).append(p)
                else:
                    pulled.append(p)
            cost = _simulate(order, seq, pushed, pulled, stats, impute_cost, lam)
            if cost < best_cost:
                best, best_cost = (order, seq, pushed, pulled), cost
    assert best is not None, "query graph is disconnected"
    order, seq, pushed, pulled = best
    return _build(order, seq, pushed, pulled)
