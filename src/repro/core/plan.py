"""Logical query plans.

A :class:`Query` is a declarative SPJ(+aggregate) description; planners in
``repro.core.optimizer`` turn it into an operator tree of :class:`PlanNode`.
QUIP's rewriter (paper §3, Fig. 3) does not change the tree structure — it
replaces each node with its modified counterpart and inserts the imputation
operator ρ above the topmost selection/join (paper §5, Fig. 6-b).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.core.predicates import JoinPredicate, Predicate, SelectionPredicate

__all__ = [
    "Query",
    "PlanNode",
    "ScanNode",
    "SelectNode",
    "JoinNode",
    "RhoNode",
    "ProjectNode",
    "AggregateNode",
    "walk",
    "downstream_chain",
    "clone_plan",
]

_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class Aggregate:
    op: str  # "max" | "min" | "count" | "sum" | "avg"
    attr: Optional[str]  # None for count(*)
    group_by: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class Query:
    tables: Tuple[str, ...]
    selections: Tuple[SelectionPredicate, ...]
    joins: Tuple[JoinPredicate, ...]
    projection: Tuple[str, ...]
    aggregate: Optional[Aggregate] = None

    @property
    def predicates(self) -> Tuple[Predicate, ...]:
        return tuple(self.selections) + tuple(self.joins)

    def predicate_attrs(self) -> Tuple[str, ...]:
        out: List[str] = []
        for p in self.predicates:
            out.extend(p.attrs)
        return tuple(dict.fromkeys(out))


class PlanNode:
    """Base plan node. ``children`` ordered; ``attrs`` = operator attributes A_o."""

    def __init__(self, children: Sequence["PlanNode"]):
        self.node_id = next(_ids)
        self.children: List[PlanNode] = list(children)
        self.parent: Optional[PlanNode] = None
        for c in self.children:
            c.parent = self
        # Populated by the VF-list builder (repro.core.vflist).
        self.verify_set: List[Predicate] = []
        self.filter_set: List = []  # List[FilterEntry]

    @property
    def attrs(self) -> Tuple[str, ...]:
        return ()

    def label(self) -> str:
        return type(self).__name__

    def __repr__(self):
        return f"{self.label()}#{self.node_id}"


class ScanNode(PlanNode):
    def __init__(self, table: str):
        super().__init__([])
        self.table = table

    def label(self):
        return f"Scan({self.table})"


class SelectNode(PlanNode):
    def __init__(self, pred: SelectionPredicate, child: PlanNode):
        super().__init__([child])
        self.pred = pred

    @property
    def attrs(self):
        return pred_attrs(self.pred)

    def label(self):
        return f"σ̂[{self.pred}]"


class JoinNode(PlanNode):
    def __init__(self, pred: JoinPredicate, left: PlanNode, right: PlanNode):
        super().__init__([left, right])
        self.pred = pred

    @property
    def attrs(self):
        return pred_attrs(self.pred)

    def label(self):
        return f"⋈̂[{self.pred}]"


class RhoNode(PlanNode):
    """Imputation operator ρ: imputes every remaining missing predicate /
    projection attribute and re-verifies deferred predicates (paper §5)."""

    def __init__(self, child: PlanNode, attrs_to_impute: Sequence[str]):
        super().__init__([child])
        self._attrs = tuple(attrs_to_impute)

    @property
    def attrs(self):
        return self._attrs

    def label(self):
        return "ρ"


class ProjectNode(PlanNode):
    def __init__(self, attrs: Sequence[str], child: PlanNode):
        super().__init__([child])
        self._attrs = tuple(attrs)

    @property
    def attrs(self):
        return self._attrs

    def label(self):
        return f"Π{list(self._attrs)}"


class AggregateNode(PlanNode):
    def __init__(self, agg: Aggregate, child: PlanNode):
        super().__init__([child])
        self.agg = agg

    @property
    def attrs(self):
        return (self.agg.attr,) if self.agg.attr else ()

    def label(self):
        g = f" group by {self.agg.group_by}" if self.agg.group_by else ""
        return f"γ[{self.agg.op}({self.agg.attr}){g}]"


def pred_attrs(pred: Predicate) -> Tuple[str, ...]:
    return tuple(pred.attrs)


def walk(node: PlanNode):
    """Post-order traversal (children before parents — execution order)."""
    for c in node.children:
        yield from walk(c)
    yield node


def downstream_chain(node: PlanNode) -> List[PlanNode]:
    """Operators strictly above ``node`` up to (excluding) ρ/Π/γ — the
    decision-tree operators of the decision function (paper §6.2/Fig. 8)."""
    out = []
    cur = node.parent
    while cur is not None and not isinstance(cur, (RhoNode, ProjectNode, AggregateNode)):
        out.append(cur)
        cur = cur.parent
    return out


def clone_plan(node: PlanNode) -> PlanNode:
    """Structural copy of a plan tree with fresh nodes (and node ids).

    Executors mutate plan nodes — the QUIP rewriter re-wraps the root in ρ
    (reassigning parent pointers) and rebuilds verify/filter sets — so a plan
    held in a cache must hand each execution its own tree.  Predicates are
    immutable (frozen dataclasses) and are shared, not copied.
    """
    children = [clone_plan(c) for c in node.children]
    if isinstance(node, ScanNode):
        return ScanNode(node.table)
    if isinstance(node, SelectNode):
        return SelectNode(node.pred, children[0])
    if isinstance(node, JoinNode):
        return JoinNode(node.pred, children[0], children[1])
    if isinstance(node, RhoNode):
        return RhoNode(children[0], node.attrs)
    if isinstance(node, ProjectNode):
        return ProjectNode(node.attrs, children[0])
    if isinstance(node, AggregateNode):
        return AggregateNode(node.agg, children[0])
    raise TypeError(f"clone_plan: unknown node {type(node)!r}")


def base_tables(node: PlanNode) -> Tuple[str, ...]:
    return tuple(
        dict.fromkeys(n.table for n in walk(node) if isinstance(n, ScanNode))
    )


def plan_string(root: PlanNode, indent: int = 0) -> str:
    pad = "  " * indent
    s = f"{pad}{root.label()}\n"
    for c in root.children:
        s += plan_string(c, indent + 1)
    return s
