"""Weighted row deltas (DBSP Z-sets) for incremental cache maintenance.

A mutation commit on the epoch-versioned ``TableRegistry`` is represented
as a :class:`TableDelta` — two small canonical relations holding the rows
leaving and entering the table — plus the equivalent :class:`ZSet` view
(row → integer weight, -1 for a removal, +1 for an insertion; an
``update_rows`` is the sum of both, exactly the DBSP encoding from the
gnitz spec referenced in SNIPPETS.md §1).

The serving layer's IVM maintainer (``repro.service.ivm``) consumes these
to *patch* cached answers instead of evicting them: because QUIP answers
are strategy-independent multisets, ``Q(T + ΔT) = Q(T) + Q(ΔT)`` holds for
the linear fragment (select/project over a join spine with the other build
sides frozen), and the answer patch itself is plain Z-set addition over
answer tuples.

``ZSet`` is deliberately tiny and algebraic — ``add``/``negate``/
``consolidate`` obey the abelian-group laws the unit tests pin down — so
the same structure serves both the registry deltas (keyed by
``(tid, row values)``) and answer multisets (keyed by answer tuples).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, Iterable, Mapping, Optional, Tuple

import numpy as np

from repro.core.relation import MaskedRelation

__all__ = [
    "ZSet",
    "TableDelta",
    "slice_rows",
    "delta_for_update",
    "delta_for_delete",
    "delta_for_insert",
]


class ZSet:
    """A weighted multiset: mapping from hashable rows to integer weights.

    Positive weights are (multi-)set membership, negative weights are
    retractions.  ``add`` merges weights (keeping explicit zeros so the
    group laws are observable), ``consolidate`` drops zero-weight entries,
    ``negate`` flips signs.  ``(a.add(a.negate())).consolidate()`` is the
    empty Z-set for every ``a`` — the inverse law the unit tests assert.
    """

    __slots__ = ("_weights",)

    def __init__(self, weights: Optional[Mapping[Hashable, int]] = None):
        self._weights: Dict[Hashable, int] = dict(weights or {})

    @staticmethod
    def from_rows(rows: Iterable[Hashable], weight: int = 1) -> "ZSet":
        w: Dict[Hashable, int] = {}
        for r in rows:
            w[r] = w.get(r, 0) + weight
        return ZSet(w)

    def add(self, other: "ZSet") -> "ZSet":
        out = dict(self._weights)
        for row, w in other._weights.items():
            out[row] = out.get(row, 0) + w
        return ZSet(out)

    def negate(self) -> "ZSet":
        return ZSet({row: -w for row, w in self._weights.items()})

    def consolidate(self) -> "ZSet":
        return ZSet({row: w for row, w in self._weights.items() if w != 0})

    def weight(self, row: Hashable) -> int:
        return self._weights.get(row, 0)

    def items(self) -> Tuple[Tuple[Hashable, int], ...]:
        return tuple(self._weights.items())

    def is_positive(self) -> bool:
        """True iff every consolidated weight is >= 0 (a real multiset)."""
        return all(w >= 0 for w in self._weights.values())

    def __len__(self) -> int:  # number of non-zero entries
        return sum(1 for w in self._weights.values() if w != 0)

    def __eq__(self, other) -> bool:
        if not isinstance(other, ZSet):
            return NotImplemented
        return (self.consolidate()._weights ==
                other.consolidate()._weights)

    def __hash__(self):  # pragma: no cover - Z-sets are not dict keys
        raise TypeError("ZSet is unhashable")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"ZSet({self.consolidate()._weights!r})"


@dataclasses.dataclass(frozen=True)
class TableDelta:
    """One registry commit as a pair of canonical row slices.

    ``removed``/``added`` are small :class:`MaskedRelation` instances with
    the mutated table's schema (tids re-canonicalized to ``arange`` so they
    are valid standalone tables for sub-execution); ``None`` means that
    side is empty.  A commit the registry cannot express as a delta
    (``replace_table``, duplicate row ids in one ``update_rows`` call)
    yields no ``TableDelta`` at all — subscribers receive ``delta=None``
    and must fall back to full invalidation.
    """

    table: str
    removed: Optional[MaskedRelation]
    added: Optional[MaskedRelation]

    @property
    def removed_rows(self) -> int:
        return 0 if self.removed is None else self.removed.num_rows

    @property
    def added_rows(self) -> int:
        return 0 if self.added is None else self.added.num_rows

    def to_zset(self) -> ZSet:
        """Z-set view keyed by ``(tid, row values)`` — the DBSP encoding.

        ``update_rows`` surfaces as ``(tid, old) → -1`` plus
        ``(tid, new) → +1``; a no-op update (new value == old) cancels to
        weight 0 under ``consolidate``.
        """
        z = ZSet()
        if self.removed is not None:
            rows = _keyed_rows(self.removed)
            z = z.add(ZSet.from_rows(rows, weight=-1))
        if self.added is not None:
            rows = _keyed_rows(self.added)
            z = z.add(ZSet.from_rows(rows, weight=+1))
        return z


def _keyed_rows(rel: MaskedRelation) -> Tuple[Tuple, ...]:
    names = rel.column_names()
    cols = [rel.values(n) for n in names]
    missing = [rel.missing[n] for n in names]
    # a canonical base-table slice carries exactly one tids entry
    tids = next(iter(rel.tids.values()))
    out = []
    for i in range(rel.num_rows):
        vals = tuple(
            None if missing[j][i] else _scalar(cols[j][i])
            for j in range(len(names))
        )
        out.append((int(tids[i]), vals))
    return tuple(out)


def _scalar(v):
    if isinstance(v, (np.floating, float)):
        return float(v)
    return int(v)


def slice_rows(rel: MaskedRelation, table: str,
               rows: np.ndarray) -> MaskedRelation:
    """A canonical standalone relation holding ``rel``'s rows at ``rows``.

    Built through ``from_columns`` so tids are ``arange`` — the
    imputation service keeps dense per-(table, attr) arrays indexed by
    tid, so a delta slice must look like a fresh small table, not carry
    the parent's row ids.
    """
    idx = np.asarray(rows, dtype=np.int64)
    cols = {a: rel.values(a)[idx].copy() for a in rel.column_names()}
    miss = {a: rel.missing[a][idx].copy() for a in rel.column_names()}
    return MaskedRelation.from_columns(
        rel.schema, cols, missing=miss, base_table=table
    )


def delta_for_update(table: str, old: MaskedRelation, new: MaskedRelation,
                     rows: np.ndarray) -> Optional[TableDelta]:
    idx = np.asarray(rows, dtype=np.int64)
    if len(np.unique(idx)) != len(idx):
        # duplicate row ids make the old-row slice ambiguous (later writes
        # win in set_values); not expressible as a single Z-set delta
        return None
    return TableDelta(
        table,
        removed=slice_rows(old, table, idx),
        added=slice_rows(new, table, idx),
    )


def delta_for_delete(table: str, old: MaskedRelation,
                     rows: np.ndarray) -> TableDelta:
    idx = np.unique(np.asarray(rows, dtype=np.int64))
    return TableDelta(table, removed=slice_rows(old, table, idx), added=None)


def delta_for_insert(table: str, new: MaskedRelation,
                     old_rows: int) -> TableDelta:
    idx = np.arange(old_rows, new.num_rows, dtype=np.int64)
    return TableDelta(table, removed=None, added=slice_rows(new, table, idx))
