"""Modified-operator building blocks (paper §5, Fig. 4).

Every modified operator routes a morsel through the same four stages:

    filter  →  decision function  →  verify  →  operation′

* ``apply_filter_set``   — VF filter-set test (selection entries always
  active; join entries activate once the partner attribute's bloom filter is
  complete — paper §5.3 "VF list update").
* ``decide_groups``      — vectorized decision function: rows are grouped by
  their missing-attribute pattern; each group gets one impute/delay decision
  (identical cost inputs ⇒ identical per-tuple decision in the paper).
* ``impute_and_verify``  — imputes a group's values, charges `impute(a)`,
  checks the operator's verify set, writes back into join snapshots and bloom
  filters, and maintains missing refcounts.

The operators themselves (σ̂ / ⋈̂ / ρ / Π̂ / γ) live in ``repro.core.executor``
as morsel streams; this module is the shared per-morsel machinery.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.plan import PlanNode
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import table_of

__all__ = [
    "apply_filter_set",
    "decide_groups",
    "full_verify",
    "group_rows_by_missing_pattern",
    "op_kind",
]


def op_kind(node: PlanNode) -> str:
    """Short operator label for spans / provenance ("select", "join", …)."""
    name = type(node).__name__
    return name[:-4].lower() if name.endswith("Node") else name.lower()


# --------------------------------------------------------------------------- #
# filter stage
# --------------------------------------------------------------------------- #
def apply_filter_set(ex, node: PlanNode, rel: MaskedRelation) -> MaskedRelation:
    """Drop rows that some downstream predicate (VF filter set) already
    rejects.  Rows whose check attribute is missing/absent are kept (they are
    routed to the decision function / preserved, paper Fig. 4)."""
    if rel.num_rows == 0 or not node.filter_set or not ex.use_vf:
        return rel
    keep = np.ones(rel.num_rows, dtype=bool)
    for entry in node.filter_set:
        if not rel.has_column(entry.check_attr):
            continue
        present = rel.is_present(entry.check_attr)
        if entry.kind == "sel":
            passes, _known = entry.pred.evaluate(rel)
            drop = present & ~passes
        else:  # join entry: one-sided bloom semi-join, only once BFC(partner)
            bloom = ex.blooms.get(entry.bloom_attr)
            if bloom is None or not bloom.complete:
                continue
            vals = rel.values(entry.check_attr)
            hit = np.zeros(rel.num_rows, dtype=bool)
            if present.any():
                hit_p = bloom.might_contain(vals[present], impl=ex.bloom_impl)
                hit[present] = hit_p
            drop = present & ~hit
            ex.counters.filtered_by_bloom += int(drop.sum())
        ex.counters.filtered_by_vf += int(drop.sum())
        keep &= ~drop
        if not keep.any():
            break
    if keep.all():
        return rel
    dropped = rel.filter(~keep)
    ex.on_rows_dropped(dropped)
    return rel.filter(keep)


def apply_dynamic_preds(ex, node: PlanNode, rel: MaskedRelation) -> MaskedRelation:
    """MIN/MAX pushdown (paper §9.3): dynamically maintained σ̂_{a>t} / σ̂_{a<t}
    attached to this node.  Missing/absent rows pass through."""
    preds = ex.dynamic_preds.get(node.node_id, [])
    if rel.num_rows == 0 or not preds:
        return rel
    keep = np.ones(rel.num_rows, dtype=bool)
    for dyn in preds:
        if dyn.value is None or not rel.has_column(dyn.attr):
            continue
        pred = SelectionPredicate(dyn.attr, dyn.op, dyn.value)
        passes, known = pred.evaluate(rel)
        drop = known & ~passes
        ex.counters.minmax_removed += int(drop.sum())
        keep &= ~drop
    if keep.all():
        return rel
    dropped = rel.filter(~keep)
    ex.on_rows_dropped(dropped)
    return rel.filter(keep)


# --------------------------------------------------------------------------- #
# decision stage
# --------------------------------------------------------------------------- #
def group_rows_by_missing_pattern(
    rel: MaskedRelation, rows: np.ndarray, pattern_attrs: Sequence[str]
) -> List[Tuple[frozenset, np.ndarray]]:
    """Group row indices by which predicate attributes are missing — the
    vectorized analogue of per-tuple decisions (same cost inputs ⇒ same
    decision)."""
    if len(rows) == 0:
        return []
    attrs = [a for a in pattern_attrs if rel.has_column(a)]
    if not attrs:
        return [(frozenset(), rows)]
    bits = np.zeros(len(rows), dtype=np.int64)
    for i, a in enumerate(attrs):
        bits |= rel.is_missing(a)[rows].astype(np.int64) << i
    out = []
    for code in np.unique(bits):
        mask = bits == code
        missing = frozenset(attrs[i] for i in range(len(attrs)) if code >> i & 1)
        out.append((missing, rows[mask]))
    return out


def decide_groups(
    ex,
    node: PlanNode,
    rel: MaskedRelation,
    attr: str,
    rows: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Split ``rows`` (attr missing) into (impute_rows, delay_rows) using the
    decision function per missing-pattern group."""
    from repro.core.decision import decide_impute_explain

    if len(rows) == 0:
        return rows, rows
    prov = getattr(ex, "provenance", None)
    imp, dly = [], []
    for missing_attrs, grp in group_rows_by_missing_pattern(
        rel, rows, ex.query.predicate_attrs()
    ):
        decision, costs, reason = decide_impute_explain(
            node, attr, set(missing_attrs), ex.stats, ex.strategy,
            ex.obligated)
        if prov is not None:
            prov.record_decision(
                op_kind(node), node.node_id, attr,
                tuple(sorted(missing_attrs)), len(grp), decision, costs,
                reason)
        if decision:
            imp.append(grp)
        else:
            dly.append(grp)
    cat = lambda xs: np.concatenate(xs) if xs else np.zeros(0, dtype=np.int64)
    return cat(imp), cat(dly)


# --------------------------------------------------------------------------- #
# verify stage
# --------------------------------------------------------------------------- #
def verify_values(
    node: PlanNode, attr: str, values: np.ndarray
) -> np.ndarray:
    """Imputed values must retroactively satisfy the operator's verify set
    (predicates below, applicable to the attribute — paper §4)."""
    ok = np.ones(len(values), dtype=bool)
    for p in node.verify_set:
        if isinstance(p, SelectionPredicate) and p.attr == attr:
            ok &= p.evaluate_values(values)
    return ok


def full_verify(ex, rel: MaskedRelation) -> MaskedRelation:
    """ρ-level verification: every *present* value must satisfy every
    applicable query predicate (selections + both-sides-present joins).
    Safe because answer tuples satisfy all predicates (paper §4 ρ row)."""
    if rel.num_rows == 0:
        return rel
    keep = np.ones(rel.num_rows, dtype=bool)
    for p in ex.query.selections:
        if not rel.has_column(p.attr):
            continue
        passes, known = p.evaluate(rel)
        keep &= passes | ~known
    for j in ex.query.joins:
        if not (rel.has_column(j.left_attr) and rel.has_column(j.right_attr)):
            continue
        both = rel.is_present(j.left_attr) & rel.is_present(j.right_attr)
        eq = rel.values(j.left_attr) == rel.values(j.right_attr)
        keep &= eq | ~both
    if keep.all():
        return rel
    dropped = rel.filter(~keep)
    ex.on_rows_dropped(dropped)
    return rel.filter(keep)
