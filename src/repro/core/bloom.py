"""Bloom filters with completeness tracking (paper §4).

One :class:`BloomFilter` per equi-join attribute.  Values are inserted as
tuples rise to the join operator; imputed values are inserted after passing
verification.  ``BFC(a)`` (completeness w.r.t. the query) is tracked by the
executor: the filter is *complete* once (i) the operand side has been fully
consumed (hash table built / relation scanned) AND (ii) the attribute's
missing counter is zero (paper §4, last paragraph).
"""

from __future__ import annotations

import numpy as np

from repro.analysis.lockcheck import make_lock
from repro.kernels import ops as kops
from repro.kernels.hashing import fold64, hash_positions_np

__all__ = ["BloomFilter"]


class BloomFilter:
    def __init__(self, attr: str, log2m: int = 20, num_hashes: int = 4):
        self.attr = attr
        self.log2m = int(log2m)
        self.num_hashes = int(num_hashes)
        self.bits = np.zeros((1 << self.log2m) // 32, dtype=np.uint32)  # guarded-by: _lock
        self.n_inserted = 0  # guarded-by: _lock
        self.complete = False  # BFC(attr)  # guarded-by: _lock
        # ``np.bitwise_or.at`` is a read-modify-write over shared words;
        # concurrent inserts from sibling parallel morsels would lose bits
        # (→ false negatives → wrong pruning), so inserts serialize
        self._lock = make_lock("BloomFilter._lock")

    # ------------------------------------------------------------------ #
    def insert(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys)
        if keys.size == 0:
            return
        pos = hash_positions_np(keys, self.num_hashes, self.log2m).ravel()
        word = (pos >> np.uint32(5)).astype(np.int64)
        bit = (np.uint32(1) << (pos & np.uint32(31))).astype(np.uint32)
        with self._lock:
            np.bitwise_or.at(self.bits, word, bit)
            self.n_inserted += len(keys)

    def might_contain(self, keys: np.ndarray, impl=None) -> np.ndarray:
        keys = np.asarray(keys)
        if keys.size == 0:
            return np.zeros(0, dtype=bool)
        out = kops.bloom_probe(
            self.bits,
            fold64(keys),
            num_hashes=self.num_hashes,
            log2m=self.log2m,
            impl=impl,
        )
        return np.asarray(out)

    def mark_complete(self) -> None:
        # monotonic bool flip by the owning executor thread; readers
        # tolerate a stale False (one extra probe), never a wrong True
        self.complete = True  # unguarded: monotonic flip, single writer

    def __repr__(self):
        return (
            f"BloomFilter({self.attr}, m=2^{self.log2m}, k={self.num_hashes}, "
            f"n={self.n_inserted}, complete={self.complete})"
        )
