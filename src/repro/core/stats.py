"""Adaptive runtime statistics (paper §6.2).

Tracks, per attribute / operator:

* ``impute(a)``      — running average imputation cost per value of ``a``;
* ``S_o``            — operator selectivity (selection: |pass|/|seen|; join:
                       |out| / (|L|·|R|), missing-value rows excluded);
* ``T_o``            — average evaluation (join) tests per tuple;
* ``TTJoin_o``       — average time per join test (0 for selections);
* missing counters   — remaining missing values per attribute (drives BFC).

Bootstrap: QUIP initially delays all imputations (paper §6.2); the first
morsel's imputations at ρ seed ``impute(a)`` and the operator counters seed
selectivities, after which decisions adapt online.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import defaultdict
from typing import Dict, List, Optional

__all__ = [
    "RuntimeStats",
    "ExecutionCounters",
    "QueryRecord",
    "ServingStats",
    "nearest_rank_quantile",
]


def nearest_rank_quantile(values: List[float], q: float) -> float:
    """Nearest-rank quantile over ``values`` (0.0 if empty) — the single
    definition used by both serving telemetry and the benchmarks.

    True nearest-rank: the ``ceil(q·n)``-th order statistic (1-indexed).
    The previous banker's-rounded ``round(q·(n-1))`` was *not* nearest
    rank — p50 of 4 values returned the 3rd order statistic instead of
    the 2nd."""
    if not values:
        return 0.0
    ordered = sorted(values)
    idx = min(len(ordered) - 1, max(0, math.ceil(q * len(ordered)) - 1))
    return ordered[idx]


@dataclasses.dataclass
class _Avg:
    total: float = 0.0
    count: int = 0

    def add(self, value: float, n: int = 1):
        self.total += value
        self.count += n

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None


class RuntimeStats:
    def __init__(self, default_impute_cost: float = 1e-4):
        self.impute_cost: Dict[str, _Avg] = defaultdict(_Avg)
        self.sel_pass: Dict[int, _Avg] = defaultdict(_Avg)  # node_id -> selectivity obs
        self.join_tests: Dict[int, _Avg] = defaultdict(_Avg)  # node_id -> T_o obs
        self.join_test_time: Dict[int, _Avg] = defaultdict(_Avg)  # node_id -> TTJoin
        self.missing_counter: Dict[str, int] = {}
        self.flush_batch: Dict[str, _Avg] = defaultdict(_Avg)  # attr -> dedup batch size per flush
        self.flush_requested: Dict[str, _Avg] = defaultdict(_Avg)  # attr -> queued tids per flush
        self.default_impute_cost = default_impute_cost

    # -- impute(a) ------------------------------------------------------- #
    def record_imputation(self, attr: str, n: int, seconds: float) -> None:
        if n > 0:
            self.impute_cost[attr].add(seconds, n)

    def impute(self, attr: str) -> float:
        m = self.impute_cost[attr].mean
        return m if m is not None else self.default_impute_cost

    # -- flush telemetry (batched imputation service) ---------------------#
    def record_flush(self, attr: str, requested: int, computed: int) -> None:
        """One flushed batch of ``attr``: ``requested`` queued tids coalesced
        into ``computed`` deduplicated model evaluations."""
        if computed > 0:
            self.flush_batch[attr].add(computed, 1)
        if requested > 0:
            self.flush_requested[attr].add(requested, 1)

    def mean_flush_size(self, attr: str) -> Optional[float]:
        """Average deduplicated batch size per flush of ``attr``."""
        return self.flush_batch[attr].mean

    # -- selectivities ----------------------------------------------------#
    def record_selectivity(self, node_id: int, passed: int, seen: int) -> None:
        if seen > 0:
            self.sel_pass[node_id].add(passed, seen)

    def selectivity(self, node_id: int, default: float = 0.5) -> float:
        m = self.sel_pass[node_id].mean
        return m if m is not None else default

    # -- join cost --------------------------------------------------------#
    def record_join(self, node_id: int, tests: int, tuples: int, seconds: float) -> None:
        if tuples > 0:
            self.join_tests[node_id].add(tests, tuples)
        if tests > 0:
            self.join_test_time[node_id].add(seconds, tests)

    def tests_per_tuple(self, node_id: int, default: float = 1.0) -> float:
        m = self.join_tests[node_id].mean
        return m if m is not None else default

    def ttjoin(self, node_id: int, default: float = 1e-7) -> float:
        m = self.join_test_time[node_id].mean
        return m if m is not None else default

    # -- missing counters (paper §4) ---------------------------------------#
    def init_missing_counter(self, attr: str, n: int) -> None:
        self.missing_counter[attr] = int(n)

    def dec_missing(self, attr: str, n: int) -> None:
        if attr in self.missing_counter:
            self.missing_counter[attr] = max(0, self.missing_counter[attr] - int(n))

    def no_missing_left(self, attr: str) -> bool:
        return self.missing_counter.get(attr, 0) == 0


@dataclasses.dataclass
class ExecutionCounters:
    """Benchmark-facing counters (paper Experiments 1–5)."""

    imputations: int = 0
    impute_batches: int = 0  # imputer invocations (deduplicated batches)
    impute_flushes: int = 0  # service flush() calls that had queued work
    impute_cross_hits: int = 0  # values served from cells another query filled
    imputation_seconds: float = 0.0
    temp_tuples: int = 0
    join_tests: int = 0
    filtered_by_vf: int = 0
    filtered_by_bloom: int = 0
    minmax_removed: int = 0  # |RT| in Table 7
    trigger_joins: int = 0
    wall_seconds: float = 0.0
    join_impl: str = "numpy"  # resolved join-core dispatch (see triggers)
    exec_impl: str = "interp"  # which executor answered (see core/compiled.py)
    compiled_hits: int = 0  # executions served by a compiled plan
    compile_fallbacks: int = 0  # compiled requested but interpreter ran

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    def merged(self, other: "ExecutionCounters") -> "ExecutionCounters":
        """Element-wise sum of all numeric counters (compound queries and
        serving aggregation); ``join_impl``/``exec_impl`` are kept when both
        branches agree and reported as ``"mixed"`` otherwise."""
        out = ExecutionCounters()
        for f in dataclasses.fields(self):
            if f.name in ("join_impl", "exec_impl"):
                continue
            setattr(out, f.name, getattr(self, f.name) + getattr(other, f.name))
        out.join_impl = (
            self.join_impl if self.join_impl == other.join_impl else "mixed"
        )
        out.exec_impl = (
            self.exec_impl if self.exec_impl == other.exec_impl else "mixed"
        )
        return out


# --------------------------------------------------------------------------- #
# serving telemetry (QuipService — see repro.service and docs/serving.md)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class QueryRecord:
    """One served query: scheduling timeline + its execution counters.

    Failed queries land here too (``failed=True``, counters as far as the
    session got) — a query that never produced an answer still consumed
    admission and scheduling resources, and dropping it silently made the
    telemetry under-report failures.  Result-cache hits record with
    ``result_cache_hit=True`` and empty counters (no relational work ran)."""

    ticket: int
    tenant: Optional[int]
    strategy: str
    queue_wait_s: float  # submit → admission
    latency_s: float  # submit → result available
    plan_cache_hit: bool
    counters: ExecutionCounters
    result_cache_hit: bool = False
    failed: bool = False
    # -- QoS scheduling accounting (see service/scheduler.py) ----------- #
    steps: int = 0  # morsel steps the scheduler granted
    sched_cost: float = 0.0  # cost charged under the scheduler's model
    # None = never admitted (cancelled in the queue / failed at setup) —
    # distinct from "admitted at clock 0.0"
    admit_clock: Optional[float] = None  # scheduler clock at admission
    finish_clock: Optional[float] = None  # scheduler clock at completion
    deadline_met: Optional[bool] = None  # None: no deadline class

    @property
    def turnaround_cost(self) -> Optional[float]:
        """Admission → completion on the scheduler's cost clock (steps
        under the ``unit`` model — wall-clock-free p95s).  ``None`` for
        work that was never admitted: a cancelled queued session has no
        turnaround, and reporting 0.0 would drag quantiles toward zero."""
        if self.admit_clock is None or self.finish_clock is None:
            return None
        return max(0.0, self.finish_clock - self.admit_clock)

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["counters"] = self.counters.as_dict()
        return d


class ServingStats:
    """Aggregate telemetry of a QuipService instance.

    Collects one :class:`QueryRecord` per finished query plus service-level
    gauges (observed concurrency, admission queueing).  Plan-cache hit/miss
    counts live on the cache itself; ``summary`` merges both views into the
    flat ``serving_*`` metric dict the benchmarks record."""

    def __init__(self):
        self.records: List[QueryRecord] = []
        self.max_concurrent = 0
        self.admission_queued = 0  # submissions that had to wait
        # registry-mutation invalidation telemetry (see service/registry.py)
        self.invalidation_events = 0  # mutations observed by the service
        self.plans_invalidated = 0  # PlanCache entries evicted by mutations
        self.results_invalidated = 0  # ResultCache entries purged
        self.store_cells_invalidated = 0  # shared-store cells dropped
        # delta-driven maintenance (QUIP_IVM, service/ivm.py): per dependent
        # cached answer, exactly one of these two advances per mutation
        self.results_patched = 0  # answers patched in place of eviction
        self.ivm_fallbacks = 0  # answers that had to fall back to eviction

    def observe_concurrency(self, running: int) -> None:
        self.max_concurrent = max(self.max_concurrent, int(running))

    def record_query(self, record: QueryRecord) -> None:
        self.records.append(record)

    def record_invalidation(self, plans: int, results: int,
                            store_cells: int) -> None:
        """One registry mutation as seen by a subscribed service: how many
        plan-cache entries, cached answers, and shared-store cells it cost."""
        self.invalidation_events += 1
        self.plans_invalidated += int(plans)
        self.results_invalidated += int(results)
        self.store_cells_invalidated += int(store_cells)

    # -- aggregates -------------------------------------------------------#
    def latency_quantile(self, q: float) -> float:
        """Latency quantile in seconds over finished queries (0 if none)."""
        return nearest_rank_quantile([r.latency_s for r in self.records], q)

    def tenant_summary(self) -> Dict[Optional[int], Dict[str, float]]:
        """Per-tenant QoS view over the finished-query records.

        ``cost_share`` is the tenant's fraction of all scheduler-charged
        morsel cost (deterministic step shares under the ``unit`` cost
        model); ``p95_turnaround_cost`` is admission → completion on the
        same clock; ``deadline_hit_rate`` aggregates only queries that
        carried a deadline class (None when no query of the tenant did)."""
        by_tenant: Dict[Optional[int], List[QueryRecord]] = defaultdict(list)
        for r in self.records:
            by_tenant[r.tenant].append(r)
        total_cost = sum(r.sched_cost for r in self.records)
        out: Dict[Optional[int], Dict[str, float]] = {}
        for tenant, recs in by_tenant.items():
            latencies = [r.latency_s for r in recs]
            # unadmitted records (turnaround None) carry no turnaround —
            # including them as 0.0 would reward cancelling queued work
            turnarounds = [
                r.turnaround_cost for r in recs
                if r.steps and r.turnaround_cost is not None
            ]
            deadlined = [r for r in recs if r.deadline_met is not None]
            cost = sum(r.sched_cost for r in recs)
            out[tenant] = {
                "queries": len(recs),
                "failed": sum(1 for r in recs if r.failed),
                "p50_latency_s": nearest_rank_quantile(latencies, 0.50),
                "p95_latency_s": nearest_rank_quantile(latencies, 0.95),
                "queue_wait_s": sum(r.queue_wait_s for r in recs),
                "steps": sum(r.steps for r in recs),
                "sched_cost": cost,
                "cost_share": cost / total_cost if total_cost > 0 else 0.0,
                "p95_turnaround_cost": nearest_rank_quantile(
                    turnarounds, 0.95
                ),
                "deadline_hit_rate": (
                    sum(1 for r in deadlined if r.deadline_met)
                    / len(deadlined)
                    if deadlined else None
                ),
            }
        return out

    def total_counters(self) -> ExecutionCounters:
        if not self.records:
            return ExecutionCounters()
        # fold from the first record so agreeing join_impl labels survive
        # (a zero seed would taint the label to "mixed")
        total = dataclasses.replace(self.records[0].counters)
        for r in self.records[1:]:
            total = total.merged(r.counters)
        return total

    def summary(self) -> Dict[str, float]:
        total = self.total_counters()
        return {
            "queries": len(self.records),
            "failed": sum(1 for r in self.records if r.failed),
            "tenants": len({r.tenant for r in self.records}),
            "morsel_steps": sum(r.steps for r in self.records),
            "sched_cost": round(
                sum(r.sched_cost for r in self.records), 6
            ),
            "p50_latency_s": round(self.latency_quantile(0.50), 6),
            "p95_latency_s": round(self.latency_quantile(0.95), 6),
            "queue_wait_s": round(sum(r.queue_wait_s for r in self.records), 6),
            "max_concurrent": self.max_concurrent,
            "admission_queued": self.admission_queued,
            # per-record view; the cache's own hit/miss counters (which also
            # see unfinished queries) are merged in as plan_cache_* keys
            "queries_plan_cache_hit": sum(
                1 for r in self.records if r.plan_cache_hit
            ),
            "queries_result_cache_hit": sum(
                1 for r in self.records if r.result_cache_hit
            ),
            "invalidation_events": self.invalidation_events,
            "plans_invalidated": self.plans_invalidated,
            "results_invalidated": self.results_invalidated,
            "store_cells_invalidated": self.store_cells_invalidated,
            "results_patched": self.results_patched,
            "ivm_fallbacks": self.ivm_fallbacks,
            "imputations": total.imputations,
            "impute_batches": total.impute_batches,
            "impute_cross_hits": total.impute_cross_hits,
            "compiled_hits": total.compiled_hits,
            "compile_fallbacks": total.compile_fallbacks,
        }
