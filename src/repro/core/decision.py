"""Decision function df(a, o) — the cost-based impute/delay choice (paper §6, §9.2).

For a (morsel-group of) tuple(s) with attribute ``a`` missing at operator
``o``, we enumerate the decision-tree chain ``[o] + downstream(o) (+ ρ)`` and
compute the expected imputation cost and expected query-processing (join-test)
cost of the two decisions:

* E[IMP(impute)]  = impute(a) + Σ_{o_i downstream, a_i missing} impute(a_i)·Π S
* E[IMP(delay)]   = Σ_{o_i downstream, a_i missing} impute(a_i)·Π' S
                    + impute(a)·Π_{downstream} S      (imputed at ρ)
* E[QP(·)]        = Σ_i (Π_{c ≤ i} T_c)·TTJoin_i·P(reach o_i)

where on the delay branch the deciding operator neither filters (its S does
not apply) nor evaluates (its T is 1 — footnote 11).  Decision: impute iff
ΔIMP + ΔQP < 0 (paper §9.2 "Decision Making").

Per-tuple decisions are grouped by the tuple's *missing-attribute pattern*
within the morsel (same cost inputs ⇒ same decision), which vectorizes the
paper's per-tuple semantics.

Obligated attributes (Def. 6.1) are always imputed immediately.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

from repro.core.plan import (
    JoinNode,
    PlanNode,
    Query,
    SelectNode,
    downstream_chain,
)
from repro.core.schema import table_of
from repro.core.stats import RuntimeStats

__all__ = [
    "obligated_attributes",
    "expected_costs",
    "decide_impute",
    "decide_impute_explain",
]


def obligated_attributes(query: Query, table_attrs: Dict[str, List[str]]) -> Set[str]:
    """Def. 6.1: a is obligated iff a ∈ A_Q ∪ projection and no *other*
    attribute of a's table appears in any predicate of Q."""
    a_q = set()
    for p in query.predicates:
        a_q.update(p.attrs)
    candidates = a_q | set(query.projection)
    if query.aggregate:
        for a in (query.aggregate.attr, query.aggregate.group_by):
            if a:
                candidates.add(a)
    out = set()
    for a in candidates:
        t = table_of(a)
        others = [x for x in table_attrs.get(t, []) if x != a]
        if not any(x in a_q for x in others):
            out.add(a)
    return out


def _op_params(op: PlanNode, stats: RuntimeStats) -> Tuple[float, float, float]:
    """(S_o, T_o, TTJoin_o) with paper defaults."""
    s = stats.selectivity(op.node_id)
    if isinstance(op, JoinNode):
        t = stats.tests_per_tuple(op.node_id)
        tt = stats.ttjoin(op.node_id)
    else:
        t, tt = 1.0, 0.0
    return s, t, tt


def expected_costs(
    node: PlanNode,
    attr: str,
    missing_attrs: Set[str],
    stats: RuntimeStats,
) -> Tuple[float, float, float, float]:
    """Returns (E_imp_impute, E_imp_delay, E_qp_impute, E_qp_delay).

    ``missing_attrs`` — the other attributes of this tuple(-group) that are
    missing (QUIP assumes downstream operators will impute them on arrival —
    paper §6.2, no recursive search).
    """
    chain: List[PlanNode] = [node] + downstream_chain(node)

    def branch(impute_now: bool) -> Tuple[float, float]:
        e_imp = stats.impute(attr) if impute_now else 0.0
        e_qp = 0.0
        reach = 1.0  # P(tuple reaches the current operator)
        t_prod = 1.0  # cumulative fan-out (join tests per original tuple)
        for i, op in enumerate(chain):
            s, t, tt = _op_params(op, stats)
            deciding = i == 0
            if deciding and not impute_now:
                # delayed: preserved without evaluation (T=1) and no filtering
                t_here, s_here = 1.0, 1.0
            else:
                t_here, s_here = t, s
            if not deciding:
                # downstream imputations of the tuple's other missing attrs
                for a_i in op.attrs:
                    if a_i in missing_attrs and a_i != attr:
                        e_imp += stats.impute(a_i) * reach
            t_prod *= t_here
            e_qp += t_prod * tt * reach
            reach *= s_here
        if not impute_now:
            # ρ imputes (and re-verifies) the delayed value at the top
            e_imp += stats.impute(attr) * reach
        return e_imp, e_qp

    ei_i, eq_i = branch(True)
    ei_d, eq_d = branch(False)
    return ei_i, ei_d, eq_i, eq_d


def decide_impute(
    node: PlanNode,
    attr: str,
    missing_attrs: Set[str],
    stats: RuntimeStats,
    strategy: str,
    obligated: Set[str],
) -> bool:
    """True → impute now; False → delay (preserve)."""
    return decide_impute_explain(
        node, attr, missing_attrs, stats, strategy, obligated
    )[0]


def decide_impute_explain(
    node: PlanNode,
    attr: str,
    missing_attrs: Set[str],
    stats: RuntimeStats,
    strategy: str,
    obligated: Set[str],
) -> Tuple[bool, Dict[str, float], str]:
    """The decision *with its evidence*: ``(impute, costs, reason)``.

    ``costs`` holds the §9.2 expected-cost terms when the adaptive branch
    computed them (empty for the constant strategies / obligated
    short-circuit — nothing was estimated, and the provenance layer must
    not pretend otherwise).  ``reason`` is one of ``strategy:eager``,
    ``strategy:lazy``, ``obligated``, ``cost:impute``, ``cost:delay``."""
    if strategy == "eager":
        return True, {}, "strategy:eager"
    if strategy == "lazy":
        return False, {}, "strategy:lazy"
    assert strategy == "adaptive", strategy
    if attr in obligated:
        return True, {}, "obligated"  # §6.1: no benefit in delaying
    ei_i, ei_d, eq_i, eq_d = expected_costs(node, attr, missing_attrs, stats)
    impute = (ei_i - ei_d) + (eq_i - eq_d) < 0.0
    costs = {
        "est_imp_impute": ei_i,
        "est_imp_delay": ei_d,
        "est_qp_impute": eq_i,
        "est_qp_delay": eq_d,
    }
    return impute, costs, ("cost:impute" if impute else "cost:delay")
