"""§9.3 extensions: union, set minus, nested (IN-subquery) queries.

* ``execute_union``  — L ∪ R: each branch runs through QUIP normally
  (filter → DF → verify per branch); missing values may stay delayed inside
  the branches (they are resolved by each branch's ρ).
* ``execute_minus``  — L − R: a *blocking* operator for QUIP (paper §9.3):
  all missing values in both branches are imputed before evaluation to
  avoid cascade invalidation; implemented by running both branches and
  multiset-subtracting the answer tuples.
* ``execute_nested`` — outer query with ``attr IN (subquery)``: QUIP runs
  the subquery first (its ρ guarantees no missing values in its output),
  then the outer query with the result as an ``in``-set predicate.  An
  empty subquery result becomes an empty ``in``-set — a proper always-false
  predicate (no sentinel values).

Each extension reports the *full* merged :class:`ExecutionCounters` of its
branches (imputations, impute_batches, impute_flushes, join_impl, ...), not
just an imputation count.  The combination helpers (``union_answers``,
``minus_answers``, ``nested_outer_query``, ``merge_stats``) are shared with
the serving layer, which routes the same compound queries through
QuipService sessions (``repro.service.server``).
"""

from __future__ import annotations

from collections import Counter
from typing import Dict, List, Tuple

from repro.core.executor import ExecutionResult, execute_quip
from repro.core.plan import Query
from repro.core.predicates import SelectionPredicate
from repro.core.stats import ExecutionCounters

__all__ = [
    "execute_union",
    "execute_minus",
    "execute_nested",
    "union_answers",
    "minus_answers",
    "nested_outer_query",
    "merge_stats",
]


def _run(q: Query, tables, engine, strategy: str) -> ExecutionResult:
    return execute_quip(q, tables, engine, strategy=strategy)


# --------------------------------------------------------------------------- #
# combination helpers (shared by the direct entry points and QuipService)
# --------------------------------------------------------------------------- #
def merge_stats(*counters: ExecutionCounters) -> Dict:
    """Merged branch counters as the extensions' stats dict: every
    :class:`ExecutionCounters` field, element-wise summed."""
    total = counters[0]
    for c in counters[1:]:
        total = total.merged(c)
    return total.as_dict()


def union_answers(left: List[tuple], right: List[tuple]) -> List[tuple]:
    return left + right


def minus_answers(left: List[tuple], right: List[tuple]) -> List[tuple]:
    return sorted((Counter(left) - Counter(right)).elements())


def nested_outer_query(outer: Query, in_attr: str,
                       sub_result: ExecutionResult) -> Query:
    """Rewrite ``outer`` with the materialized subquery ``in``-set.  The
    subquery's ρ guarantees no missing values survive in its output; an
    empty result yields an empty ``in``-set (always-false predicate)."""
    assert len(sub_result.relation.column_names()) >= 1, "subquery needs a column"
    col = sub_result.relation.column_names()[0]
    rel = sub_result.relation
    values = frozenset(
        int(v) for v in rel.values(col)[rel.is_present(col)]
    )
    pred = SelectionPredicate(in_attr, "in", values)
    return Query(
        tables=outer.tables,
        selections=tuple(outer.selections) + (pred,),
        joins=outer.joins,
        projection=outer.projection,
        aggregate=outer.aggregate,
    )


# --------------------------------------------------------------------------- #
# direct (cold-engine) entry points
# --------------------------------------------------------------------------- #
def execute_union(left: Query, right: Query, tables, engine_factory,
                  strategy: str = "adaptive") -> Tuple[List[tuple], Dict]:
    el, er = engine_factory(), engine_factory()
    rl = _run(left, tables, el, strategy)
    rr = _run(right, tables, er, strategy)
    answers = union_answers(rl.answer_tuples(), rr.answer_tuples())
    return answers, merge_stats(rl.counters, rr.counters)


def execute_minus(left: Query, right: Query, tables, engine_factory,
                  strategy: str = "adaptive") -> Tuple[List[tuple], Dict]:
    """L − R (multiset semantics over projected tuples).  Set minus blocks:
    both branches run with an *eager-at-ρ* guarantee (every branch answer is
    fully imputed by construction of ρ), so the subtraction is exact."""
    el, er = engine_factory(), engine_factory()
    rl = _run(left, tables, el, strategy)
    rr = _run(right, tables, er, strategy)
    answers = minus_answers(rl.answer_tuples(), rr.answer_tuples())
    return answers, merge_stats(rl.counters, rr.counters)


def execute_nested(outer: Query, in_attr: str, sub: Query, tables,
                   engine_factory, strategy: str = "adaptive"
                   ) -> Tuple[List[tuple], Dict]:
    """``outer WHERE in_attr IN (SELECT ... sub)`` — the paper's Fig. 18/19.
    The subquery subtree is blocking: QUIP executes it first (no missing
    values survive its ρ), then the outer query runs with the materialized
    ``in``-set."""
    es = engine_factory()
    rs = _run(sub, tables, es, strategy)
    outer2 = nested_outer_query(outer, in_attr, rs)
    eo = engine_factory()
    ro = _run(outer2, tables, eo, strategy)
    return ro.answer_tuples(), merge_stats(rs.counters, ro.counters)
