"""§9.3 extensions: union, set minus, nested (IN-subquery) queries.

* ``execute_union``  — L ∪ R: each branch runs through QUIP normally
  (filter → DF → verify per branch); missing values may stay delayed inside
  the branches (they are resolved by each branch's ρ).
* ``execute_minus``  — L − R: a *blocking* operator for QUIP (paper §9.3):
  all missing values in both branches are imputed before evaluation to
  avoid cascade invalidation; implemented by running both branches and
  multiset-subtracting the answer tuples.
* ``execute_nested`` — outer query with ``attr IN (subquery)``: QUIP runs
  the subquery first (its ρ guarantees no missing values in its output),
  then the outer query with the result as an ``in``-set predicate.
"""

from __future__ import annotations

from collections import Counter
from typing import Dict, List, Tuple

import numpy as np

from repro.core.executor import ExecutionResult, execute_quip
from repro.core.plan import Query
from repro.core.predicates import SelectionPredicate
from repro.core.relation import MaskedRelation

__all__ = ["execute_union", "execute_minus", "execute_nested"]


def _run(q: Query, tables, engine, strategy: str) -> ExecutionResult:
    return execute_quip(q, tables, engine, strategy=strategy)


def execute_union(left: Query, right: Query, tables, engine_factory,
                  strategy: str = "adaptive") -> Tuple[List[tuple], Dict]:
    el, er = engine_factory(), engine_factory()
    rl = _run(left, tables, el, strategy)
    rr = _run(right, tables, er, strategy)
    answers = rl.answer_tuples() + rr.answer_tuples()
    stats = {
        "imputations": rl.counters.imputations + rr.counters.imputations
    }
    return answers, stats


def execute_minus(left: Query, right: Query, tables, engine_factory,
                  strategy: str = "adaptive") -> Tuple[List[tuple], Dict]:
    """L − R (multiset semantics over projected tuples).  Set minus blocks:
    both branches run with an *eager-at-ρ* guarantee (every branch answer is
    fully imputed by construction of ρ), so the subtraction is exact."""
    el, er = engine_factory(), engine_factory()
    rl = _run(left, tables, el, strategy)
    rr = _run(right, tables, er, strategy)
    remaining = Counter(rl.answer_tuples()) - Counter(rr.answer_tuples())
    answers = sorted(remaining.elements())
    stats = {
        "imputations": rl.counters.imputations + rr.counters.imputations
    }
    return answers, stats


def execute_nested(outer: Query, in_attr: str, sub: Query, tables,
                   engine_factory, strategy: str = "adaptive"
                   ) -> Tuple[List[tuple], Dict]:
    """``outer WHERE in_attr IN (SELECT ... sub)`` — the paper's Fig. 18/19.
    The subquery subtree is blocking: QUIP executes it first (no missing
    values survive its ρ), then the outer query runs with the materialized
    ``in``-set."""
    es = engine_factory()
    rs = _run(sub, tables, es, strategy)
    assert len(rs.relation.column_names()) >= 1, "subquery needs a column"
    col = rs.relation.column_names()[0]
    values = frozenset(
        int(v) for v in rs.relation.values(col)[rs.relation.is_present(col)]
    )
    pred = SelectionPredicate(in_attr, "in", values or frozenset({-(2**60)}))
    outer2 = Query(
        tables=outer.tables,
        selections=tuple(outer.selections) + (pred,),
        joins=outer.joins,
        projection=outer.projection,
        aggregate=outer.aggregate,
    )
    eo = engine_factory()
    ro = _run(outer2, tables, eo, strategy)
    stats = {
        "imputations": rs.counters.imputations + ro.counters.imputations
    }
    return ro.answer_tuples(), stats
