"""Predicate algebra for QUIP plans.

Two predicate kinds (paper §4): selection predicates ``attr op value`` (with
``in``-set support) and equi-join predicates ``L.a = R.b``.  Evaluation is
fully vectorized over a relation; rows whose operand is missing/absent
evaluate to "unknown" and are reported separately so the modified operators
can route them through the decision function instead of dropping them.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.relation import MaskedRelation
from repro.core.schema import table_of

__all__ = ["SelectionPredicate", "JoinPredicate", "Predicate"]

_OPS = ("==", "!=", "<", "<=", ">", ">=", "in")


@dataclasses.dataclass(frozen=True)
class SelectionPredicate:
    attr: str  # qualified, e.g. "S.building"
    op: str
    value: Union[float, int, FrozenSet]

    def __post_init__(self):
        assert self.op in _OPS, self.op
        if self.op == "in" and not isinstance(self.value, frozenset):
            object.__setattr__(self, "value", frozenset(self.value))

    @property
    def table(self) -> str:
        return table_of(self.attr)

    @property
    def attrs(self) -> Tuple[str, ...]:
        return (self.attr,)

    def evaluate(self, rel: MaskedRelation) -> Tuple[np.ndarray, np.ndarray]:
        """Returns ``(passes, known)`` boolean arrays.

        ``known[i]`` is False where the operand is missing or absent — for
        those rows ``passes`` is meaningless and the caller must route the
        row through the decision function (missing) or preserve it (absent:
        an outer-join padded row never fails a predicate on the padded side;
        it is judged when/if its join partner is recovered).
        """
        v = rel.values(self.attr)
        known = rel.is_present(self.attr)
        passes = self.evaluate_values(v)
        return passes & known, known

    def evaluate_values(self, v: np.ndarray) -> np.ndarray:
        if self.op == "in":
            if not self.value:
                # empty IN-set (e.g. an empty subquery result): a proper
                # always-false predicate — no row can match
                return np.zeros(np.shape(v), dtype=bool)
            table = np.asarray(sorted(self.value))
            idx = np.searchsorted(table, v)
            idx = np.clip(idx, 0, len(table) - 1)
            return table[idx] == v
        rhs = self.value
        if self.op == "==":
            return v == rhs
        if self.op == "!=":
            return v != rhs
        if self.op == "<":
            return v < rhs
        if self.op == "<=":
            return v <= rhs
        if self.op == ">":
            return v > rhs
        return v >= rhs

    def selectivity_estimate(self, rel: MaskedRelation) -> float:
        passes, known = self.evaluate(rel)
        k = known.sum()
        return float(passes.sum()) / float(k) if k else 1.0

    def __str__(self):
        val = set(self.value) if isinstance(self.value, frozenset) else self.value
        return f"{self.attr} {self.op} {val}"


@dataclasses.dataclass(frozen=True)
class JoinPredicate:
    left_attr: str  # qualified
    right_attr: str  # qualified

    @property
    def left_table(self) -> str:
        return table_of(self.left_attr)

    @property
    def right_table(self) -> str:
        return table_of(self.right_attr)

    @property
    def attrs(self) -> Tuple[str, ...]:
        return (self.left_attr, self.right_attr)

    def other(self, attr: str) -> str:
        return self.right_attr if attr == self.left_attr else self.left_attr

    def __str__(self):
        return f"{self.left_attr} = {self.right_attr}"


Predicate = Union[SelectionPredicate, JoinPredicate]


def predicate_applicable(pred: Predicate, attrs: Sequence[str]) -> bool:
    """A predicate is applicable to an attribute set if one of its attributes
    is in the set (paper §4, VF-list construction)."""
    return any(a in attrs for a in pred.attrs)
