"""Schemas for QUIP relations.

Values are stored dictionary-encoded: categorical/string attributes are dense
``int64`` codes assigned at load time, numeric attributes are ``float32``.
This is the columnar, TPU-friendly analogue of SimpleDB's tuple schema.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = ["ColumnSpec", "Schema"]


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    name: str  # fully qualified, e.g. "T.room_location"
    kind: str = "int"  # "int" (codes/keys/timestamps) | "float" (numeric)

    @property
    def np_dtype(self):
        return np.float64 if self.kind == "float" else np.int64


@dataclasses.dataclass(frozen=True)
class Schema:
    name: str
    columns: Sequence[ColumnSpec]

    def column(self, name: str) -> ColumnSpec:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"no column {name!r} in {self.name} ({[c.name for c in self.columns]})")

    def column_names(self) -> List[str]:
        return [c.name for c in self.columns]

    def has(self, name: str) -> bool:
        return any(c.name == name for c in self.columns)


def qualify(table: str, attr: str) -> str:
    return attr if "." in attr else f"{table}.{attr}"


def table_of(qualified: str) -> str:
    return qualified.split(".", 1)[0]
