"""VF lists and the QUIP query rewriter (paper §3–§4, Fig. 5).

The rewriter keeps the external optimizer's tree structure, inserts the
imputation operator ρ above the topmost selection/join, adds Π/γ on top, and
attaches to every operator:

* **verify set** — predicates below the operator applicable to its attributes
  A_o (an imputed value must retroactively satisfy them);
* **filter set** — predicates from downstream operators applicable to the
  tuple's other attributes, extended by the transitive closure over join
  equivalences; join-predicate entries carry a status bit that activates only
  once the partner attribute's bloom filter is complete (BFC), after which
  they act as one-sided semi-join filters (paper §5.3 "VF list update").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.plan import (
    AggregateNode,
    JoinNode,
    PlanNode,
    ProjectNode,
    Query,
    RhoNode,
    ScanNode,
    SelectNode,
    walk,
)
from repro.core.predicates import JoinPredicate, SelectionPredicate

__all__ = ["FilterEntry", "rewrite_for_quip", "build_vf_lists", "attr_equivalences"]


@dataclasses.dataclass
class FilterEntry:
    kind: str  # "sel" | "join"
    check_attr: str  # attribute of the incoming tuple to test
    pred: Optional[SelectionPredicate] = None  # for kind == "sel"
    bloom_attr: Optional[str] = None  # for kind == "join": partner attr

    def __str__(self):
        if self.kind == "sel":
            return f"{self.check_attr}: {self.pred}"
        return f"{self.check_attr} ∈ BF({self.bloom_attr})"


# --------------------------------------------------------------------------- #
# attribute equivalence classes (transitive closure over join predicates)
# --------------------------------------------------------------------------- #
def attr_equivalences(query: Query) -> Dict[str, Set[str]]:
    parent: Dict[str, str] = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    for j in query.joins:
        union(j.left_attr, j.right_attr)
    classes: Dict[str, Set[str]] = {}
    for a in list(parent):
        classes.setdefault(find(a), set()).add(a)
    return {a: classes[find(a)] for a in list(parent)}


# --------------------------------------------------------------------------- #
# input attributes of a node = all base-table attributes below it
# --------------------------------------------------------------------------- #
def _input_attrs(node: PlanNode, table_attrs: Dict[str, List[str]]) -> Set[str]:
    out: Set[str] = set()
    for n in walk(node):
        if isinstance(n, ScanNode):
            out.update(table_attrs[n.table])
    return out


def _subtree_predicates(node: PlanNode) -> List:
    preds = []
    for n in walk(node):
        if isinstance(n, (SelectNode, JoinNode)) and n is not node:
            preds.append(n.pred)
    return preds


def _downstream_predicates(node: PlanNode) -> List:
    preds = []
    cur = node.parent
    while cur is not None:
        if isinstance(cur, (SelectNode, JoinNode)):
            preds.append(cur.pred)
        cur = cur.parent
    return preds


# --------------------------------------------------------------------------- #
# rewriter
# --------------------------------------------------------------------------- #
def rewrite_for_quip(spj_root: PlanNode, query: Query,
                     table_attrs: Dict[str, List[str]]) -> PlanNode:
    """Insert ρ above the topmost selection/join, then Π/γ; build VF lists."""
    impute_attrs = list(query.predicate_attrs())
    for a in query.projection:
        if a not in impute_attrs:
            impute_attrs.append(a)
    if query.aggregate:
        for a in (query.aggregate.attr, query.aggregate.group_by):
            if a and a not in impute_attrs:
                impute_attrs.append(a)

    root: PlanNode = RhoNode(spj_root, impute_attrs)
    if query.aggregate is not None:
        root = AggregateNode(query.aggregate, root)
    elif query.projection:
        root = ProjectNode(query.projection, root)
    build_vf_lists(root, query, table_attrs)
    return root


def build_vf_lists(root: PlanNode, query: Query,
                   table_attrs: Dict[str, List[str]]) -> None:
    equiv = attr_equivalences(query)

    for node in walk(root):
        node.verify_set = []
        node.filter_set = []
        if isinstance(node, ScanNode):
            continue
        a_o = set(node.attrs)

        # ---- verify set: predicates below, applicable to A_o ------------- #
        below = _subtree_predicates(node)
        if isinstance(node, RhoNode):
            # ρ imputes everything: carries all upstream (executed-below)
            # predicates (paper §4).
            node.verify_set = list(below)
        else:
            node.verify_set = [
                p for p in below if any(a in a_o for a in p.attrs)
            ]

        # ---- filter set --------------------------------------------------#
        inp = _input_attrs(node, table_attrs) if node.children else set()
        testable = inp - a_o
        entries: List[FilterEntry] = []
        seen: Set[Tuple] = set()

        def _add(e: FilterEntry):
            key = (e.kind, e.check_attr, str(e.pred), e.bloom_attr)
            if key not in seen:
                seen.add(key)
                entries.append(e)

        downstream = _downstream_predicates(node)
        for p in downstream:
            if isinstance(p, SelectionPredicate) and p.attr in testable:
                _add(FilterEntry("sel", p.attr, pred=p))
            elif isinstance(p, JoinPredicate):
                in_t = [a for a in p.attrs if a in testable]
                out_t = [a for a in p.attrs if a not in inp]
                if len(in_t) == 1 and len(out_t) == 1:
                    _add(FilterEntry("join", in_t[0], bloom_attr=out_t[0]))

        # transitive closure: any query selection predicate mapped onto an
        # equivalent attribute available in this operator's input.  Globally
        # safe: every answer tuple satisfies all predicates, and equivalence
        # means equal values in the answer.
        for p in query.selections:
            for eq_attr in equiv.get(p.attr, {p.attr}):
                if eq_attr != p.attr and eq_attr in testable:
                    _add(
                        FilterEntry(
                            "sel",
                            eq_attr,
                            pred=SelectionPredicate(eq_attr, p.op, p.value),
                        )
                    )
        node.filter_set = entries
