"""QUIP execution engine (paper §5–§6).

Morsel-pipelined execution of a rewritten plan: the probe spine of a
left-deep plan streams morsels through σ̂ / ⋈̂ / ρ, build sides are
materialized (classic pipelined hash-join execution).  Modified operators
preserve tuples with missing values (outer-join padding), the decision
function chooses impute/delay per (morsel × missing-pattern) group, and the
ρ fixpoint resolves deferred join parts (L1⋈R2, L2⋈R1, L2⋈R2) via
``JoinState.bf_join`` with Algorithm-2 dedup.

Strategies (paper §6/§9.1):

* ``offline``  — impute every missing value first, then evaluate (baseline).
* ``eager``    — DF always imputes: ImputeDB behaviour on the same plan.
* ``lazy``     — DF always delays: all imputations happen at ρ.
* ``adaptive`` — cost-based DF (paper §9.2).

Correctness invariant (tested property): for any query/data/strategy the
answer multiset equals the offline answer.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.decision import obligated_attributes
from repro.core.operators import (
    apply_dynamic_preds,
    apply_filter_set,
    decide_groups,
    full_verify,
    op_kind,
    verify_values,
)
from repro.obs.trace import NULL_SPAN, NULL_TRACER
from repro.core.optimizer import collect_stats, imputedb_plan, naive_plan
from repro.core.plan import (
    AggregateNode,
    JoinNode,
    PlanNode,
    ProjectNode,
    Query,
    RhoNode,
    ScanNode,
    SelectNode,
    base_tables,
    walk,
)
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation, concat_relations
from repro.core.schema import ColumnSpec, Schema, table_of
from repro.core.stats import ExecutionCounters, RuntimeStats
from repro.core.triggers import JoinState, multi_match, resolve_join_impl
from repro.core.vflist import rewrite_for_quip

__all__ = [
    "ExecutionResult",
    "AggAux",
    "GroupStat",
    "agg_aux_of",
    "relation_from_agg_aux",
    "execute_quip",
    "execute_offline",
    "evaluate_clean",
    "evaluate_clean_body",
    "make_plan",
]


@dataclasses.dataclass
class DynPred:
    """MIN/MAX pushdown predicate with a mutable bound (paper §9.3)."""

    attr: str
    op: str  # ">" for max, "<" for min
    value: Optional[float] = None


@dataclasses.dataclass
class ExecutionResult:
    relation: MaskedRelation
    counters: ExecutionCounters
    stats: RuntimeStats
    plan: Optional[PlanNode]
    # per-group auxiliary aggregate state (counts + exact totals) recorded
    # alongside aggregate answers; the serving layer's IVM maintainer needs
    # it to patch COUNT/SUM/AVG answers under table deltas.  None for
    # non-aggregate answers and paths that don't record it (compiled plans).
    agg_aux: Optional["AggAux"] = None

    def answer_tuples(self) -> List[tuple]:
        return self.relation.to_sorted_tuples()


# --------------------------------------------------------------------------- #
# plan construction convenience
# --------------------------------------------------------------------------- #
def make_plan(query: Query, tables: Dict[str, MaskedRelation],
              planner: str = "imputedb",
              impute_cost: Optional[Dict[str, float]] = None) -> PlanNode:
    stats = collect_stats(tables, query)
    if planner == "naive":
        return naive_plan(query, stats)
    return imputedb_plan(query, stats, impute_cost=impute_cost)


def _table_attrs(tables: Dict[str, MaskedRelation]) -> Dict[str, List[str]]:
    return {t: rel.column_names() for t, rel in tables.items()}


# --------------------------------------------------------------------------- #
# the executor
# --------------------------------------------------------------------------- #
class QuipExecutor:
    def __init__(
        self,
        query: Query,
        tables: Dict[str, MaskedRelation],
        plan: PlanNode,
        engine,
        strategy: str = "adaptive",
        morsel_rows: int = 8192,
        bloom_impl: Optional[str] = None,
        join_impl: Optional[str] = None,
        minmax_opt: bool = True,
        use_vf: bool = True,
    ):
        self.query = query
        self.tables = tables
        # "imputedb" = the baseline the paper compares against: eager
        # imputation at each operator with none of QUIP's VF-list / bloom /
        # MIN-MAX machinery (the plan itself may still be ImputeDB's).
        if strategy == "imputedb":
            strategy, use_vf, minmax_opt = "eager", False, False
        self.strategy = strategy
        self.use_vf = use_vf
        self.morsel_rows = int(morsel_rows)
        self.bloom_impl = bloom_impl
        self.join_impl = resolve_join_impl(join_impl)
        self.minmax_opt = minmax_opt

        self.engine = engine
        self.stats: RuntimeStats = engine.stats
        self.counters: ExecutionCounters = engine.counters
        self.counters.join_impl = self.join_impl
        # observability rides on the engine (the serving layer injects it
        # there); bare engines get the shared no-op tracer / no provenance
        self.tracer = getattr(engine, "tracer", NULL_TRACER)
        self.provenance = getattr(engine, "provenance", None)
        # batched imputation service: coalesce impute requests where the
        # morsel pipeline is provably order-insensitive (see _join / _rho)
        self.batching = bool(getattr(engine, "batching", False))
        self._scan_whole = False  # build-side materialization flag
        # intra-query morsel parallelism: the serving layer's worker pool
        # injects a runner ``(fn, items) -> [fn(x) for x in items]`` that
        # fans sibling morsels of join-free Scan/Select subtrees across
        # worker threads (order-preserving).  None = serial (seed path).
        self.task_runner = None

        ta = _table_attrs(tables)
        self.root = rewrite_for_quip(plan, query, ta)
        self.obligated = obligated_attributes(query, ta)

        # bloom filters per join attribute
        self.blooms: Dict[str, BloomFilter] = {}
        for j in query.joins:
            for a in j.attrs:
                self.blooms.setdefault(a, BloomFilter(a))

        # join runtime state, bottom-up execution order
        self.join_nodes: List[JoinNode] = [
            n for n in walk(self.root) if isinstance(n, JoinNode)
        ]
        self.join_states: Dict[int, JoinState] = {}
        self.join_side_tables: Dict[int, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {}
        self.join_attrs: Dict[int, Tuple[str, str]] = {}
        for n in self.join_nodes:
            l_tabs = base_tables(n.children[0])
            r_tabs = base_tables(n.children[1])
            # orient the predicate by which subtree holds each attribute
            if table_of(n.pred.left_attr) in l_tabs:
                l_attr, r_attr = n.pred.left_attr, n.pred.right_attr
            else:
                l_attr, r_attr = n.pred.right_attr, n.pred.left_attr
            self.join_attrs[n.node_id] = (l_attr, r_attr)
            self.join_states[n.node_id] = JoinState(
                n.node_id, l_attr, r_attr,
                self.blooms[l_attr], self.blooms[r_attr],
                join_impl=self.join_impl,
            )
            self.join_side_tables[n.node_id] = (l_tabs, r_tabs)

        # missing-value liveness per predicate/projection attribute:
        # tid-sets, shrunk on imputation and on provably-single-copy drops
        self.outstanding: Dict[str, Set[int]] = {}
        self.consumed: Dict[str, bool] = {}
        tracked = set(query.predicate_attrs()) | set(query.projection)
        if query.aggregate and query.aggregate.attr:
            tracked.add(query.aggregate.attr)
        for a in tracked:
            t = table_of(a)
            if t in tables and tables[t].has_column(a):
                mis = tables[t].is_missing(a)
                self.outstanding[a] = set(np.nonzero(mis)[0].tolist())
            self.consumed[a] = False
        for a in self.blooms:
            self.consumed.setdefault(a, False)

        # flag nodes below any join (drops there are single-copy)
        self._below_join: Set[int] = set()
        for n in self.join_nodes:
            for c in n.children:
                for sub in walk(c):
                    if not isinstance(sub, JoinNode):
                        self._below_join.add(sub.node_id)

        # MIN/MAX dynamic predicates
        self.dynamic_preds: Dict[int, List[DynPred]] = {}
        self._minmax: Optional[DynPred] = None
        agg = query.aggregate
        if (
            minmax_opt
            and agg is not None
            and agg.op in ("max", "min")
            and agg.attr is not None
            and agg.group_by is None
        ):
            self._install_minmax(agg)

        # set when steps() is exhausted (run() drives it to completion)
        self.result: Optional[ExecutionResult] = None

        # ρ bookkeeping
        self._rho_pool: List[MaskedRelation] = []
        self._emitted: List[MaskedRelation] = []
        self._closed_attrs: Set[str] = set()
        # ρ deferral: park arriving morsels and impute them in one fixpoint
        # pass (one flush per attribute).  Only exact when ρ's mid-stream
        # imputations cannot feed back into upstream pruning: with VF lists
        # active, imputing a join key at ρ can complete its bloom filter and
        # prune later probe morsels (the paper's BFC cascade), and MIN/MAX
        # pushdown needs ρ's verified output to tighten its bound — in both
        # cases deferral would change which values get imputed, so ρ stays
        # morsel-streamed there.
        self._defer_rho = (
            self.batching and not self.use_vf and self._minmax is None
        )

    # ------------------------------------------------------------------ #
    # MIN/MAX pushdown placement (paper §9.3)
    # ------------------------------------------------------------------ #
    def _install_minmax(self, agg) -> None:
        dyn = DynPred(agg.attr, ">" if agg.op == "max" else "<")
        self._minmax = dyn
        t = table_of(agg.attr)
        # probe spine = leftmost leaf chain; a spine table streams so the
        # dynamic predicate helps at its scan.  Build tables are blocked →
        # attach above the join where the table enters the spine.
        target: Optional[PlanNode] = None
        for n in walk(self.root):
            if isinstance(n, ScanNode) and n.table == t:
                target = n
                break
        if target is None:
            return
        cur, spine = target, False
        while cur.parent is not None:
            par = cur.parent
            if isinstance(par, JoinNode) and par.children[1] is cur:
                # build side → blocked; place above this join
                target = par
                spine = False
                break
            spine = True
            cur = par
        self.dynamic_preds.setdefault(target.node_id, []).append(dyn)

    # ------------------------------------------------------------------ #
    # liveness + drop notification
    # ------------------------------------------------------------------ #
    def on_rows_dropped(self, dropped: MaskedRelation, node: Optional[PlanNode] = None
                        ) -> None:
        """Eliminated rows: below the first join every row is single-copy, so
        its missing values are truly eliminated (drives mid-stream BFC)."""
        if dropped.num_rows == 0:
            return
        if node is not None and node.node_id in self._below_join:
            for a, live in self.outstanding.items():
                if not live or not dropped.has_column(a):
                    continue
                t = table_of(a)
                tids = dropped.tids.get(t)
                if tids is None:
                    continue
                mis = dropped.is_missing(a)
                for tid in tids[mis & (tids >= 0)].tolist():
                    live.discard(tid)

    def record_imputed(self, attr: str, tids: np.ndarray) -> None:
        live = self.outstanding.get(attr)
        if live:
            for tid in np.asarray(tids).tolist():
                live.discard(tid)

    def maybe_complete_bloom(self, attr: str) -> None:
        b = self.blooms.get(attr)
        if b is None or b.complete or not self.use_vf:
            return
        if self.consumed.get(attr, False) and not self.outstanding.get(attr):
            b.mark_complete()

    # ------------------------------------------------------------------ #
    # imputation with verify + writeback (shared by σ̂ / ⋈̂ / ρ)
    # ------------------------------------------------------------------ #
    def impute_rows(
        self,
        node: PlanNode,
        rel: MaskedRelation,
        attr: str,
        rows: np.ndarray,
        extra_check: Optional[SelectionPredicate] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Impute ``rel[rows].attr``; returns (passed_rows, failed_rows).

        Writes imputed values into ``rel`` for passing rows, pushes them to
        join snapshots (with verify-failure kills), inserts verified values
        of join attributes into their bloom filter, and updates liveness.
        """
        if len(rows) == 0:
            return rows, rows
        t = table_of(attr)
        tids = rel.tids[t][rows]
        ok_tid = tids >= 0
        rows, tids = rows[ok_tid], tids[ok_tid]
        if len(rows) == 0:
            return rows, rows
        # operator boundary = decision point: impute this group's tids now
        # (the operator needs the values to verify).  Cross-morsel
        # coalescing happens upstream — whole-relation build sides and ρ
        # deferral hand larger groups to this call — while the columnar
        # cache dedups repeated requests across pipeline copies.
        prov = self.provenance
        if prov is not None:
            with prov.at(op_kind(node), node.node_id):
                values = self._request_values(t, attr, tids)
        else:
            values = self._request_values(t, attr, tids)
        passed = verify_values(node, attr, values)
        if extra_check is not None:
            passed &= extra_check.evaluate_values(values)
        # writeback into every join snapshot holding this attribute
        for js in self.join_states.values():
            js.writeback(attr, tids, values, passed)
        if attr in self.blooms:
            self.blooms[attr].insert(values[passed])
        rel.set_values(attr, rows, values)
        # verify-failed rows will be dropped by the caller; mark absent rows
        self.record_imputed(attr, tids)
        self.maybe_complete_bloom(attr)
        return rows[passed], rows[~passed]

    def _request_values(self, table: str, attr: str,
                        tids: np.ndarray) -> np.ndarray:
        """One imputed batch at an operator boundary.

        Routes through :meth:`ImputationService.request` — atomic dedup +
        compute + gather under the store's per-key lock, so concurrent
        sibling morsels (and concurrent sessions over a shared store)
        cannot interleave each other's enqueue→flush→lookup triples.
        Counter semantics match the serial triple exactly; a bare engine
        without ``request`` falls back to it."""
        request = getattr(self.engine, "request", None)
        if request is not None:
            return request(table, attr, tids)
        self.engine.enqueue(table, attr, tids)
        self.engine.flush()
        return self.engine.lookup(table, attr, tids)

    # ------------------------------------------------------------------ #
    # operator streams
    # ------------------------------------------------------------------ #
    def _stream(self, node: PlanNode) -> Iterator[MaskedRelation]:
        if isinstance(node, ScanNode):
            yield from self._scan(node)
        elif isinstance(node, SelectNode):
            for m in self._stream(node.children[0]):
                out = self._select(node, m)
                if out.num_rows:
                    self.counters.temp_tuples += out.num_rows
                    yield out
        elif isinstance(node, JoinNode):
            yield from self._join(node)
        elif isinstance(node, RhoNode):
            yield from self._rho(node)
        else:  # pragma: no cover - Π/γ handled at top level
            raise TypeError(type(node))

    def _parallel_chain(
        self, node: PlanNode
    ) -> Optional[Tuple[List[SelectNode], ScanNode]]:
        """``(selects top-down, scan)`` when ``node`` is a join-free
        Select*(Scan) chain — the shape whose sibling morsels are
        independent and safe to fan out — else None."""
        sels: List[SelectNode] = []
        cur = node
        while isinstance(cur, SelectNode):
            sels.append(cur)
            cur = cur.children[0]
        if isinstance(cur, ScanNode) and sels:
            return sels, cur
        return None

    def _select_chain(self, sels: List[SelectNode],
                      morsel: MaskedRelation) -> Tuple[MaskedRelation, int]:
        """Run one morsel through a Select chain (bottom-up); returns the
        surviving morsel and the temp-tuple count the serial stream would
        have charged (added by the owner thread, not here — counters are
        not fan-out-safe)."""
        temp = 0
        for s in reversed(sels):
            morsel = self._select(s, morsel)
            if morsel.num_rows == 0:
                return morsel, temp
            temp += morsel.num_rows
        return morsel, temp

    def _stream_subtree(self, node: PlanNode) -> Iterator[MaskedRelation]:
        """Morsel stream of an operand subtree, fanning sibling morsels
        across the worker pool when a task runner is attached.

        Only join-free Scan/Select chains parallelize: their morsels are
        mutually independent (σ̂ imputes through the engine's atomic
        ``request``, bloom inserts are locked, liveness updates are
        per-tid discards), and output order is preserved so the stream is
        a permutation-free drop-in for ``_stream``.  Everything else —
        join spines, ρ — keeps the serial generator path, which is what
        makes answers thread-count-independent (see docs/serving.md
        "Worker pool & thread safety")."""
        runner = self.task_runner
        chain = (
            self._parallel_chain(node)
            if runner is not None and not self._scan_whole else None
        )
        if chain is None:
            yield from self._stream(node)
            return
        sels, scan = chain
        chunks = list(self._scan(scan))
        if len(chunks) <= 1:
            results = [self._select_chain(sels, m) for m in chunks]
        else:
            results = runner(
                lambda m: self._select_chain(sels, m), chunks
            )
        for out, temp in results:
            self.counters.temp_tuples += temp
            if out.num_rows:
                yield out

    # -- scan ------------------------------------------------------------- #
    def _scan(self, node: ScanNode) -> Iterator[MaskedRelation]:
        rel = self.tables[node.table]
        n = rel.num_rows
        # under build-side batching, materialized operands scan as a single
        # morsel so σ̂ below runs once and its impute requests flush as one
        # deduplicated batch instead of one per morsel
        step = max(n, 1) if self._scan_whole else self.morsel_rows
        for lo in range(0, max(n, 1), step):
            chunk = rel.take(np.arange(lo, min(lo + step, n)))
            if chunk.num_rows:
                yield chunk
        for a in list(self.consumed):
            if table_of(a) == node.table:
                pass  # consumption of an attr is decided at its join side

    # -- σ̂ ----------------------------------------------------------------#
    def _select(self, node: SelectNode, rel: MaskedRelation) -> MaskedRelation:
        tr = self.tracer
        with (tr.span("op:select", node=node.node_id, rows=rel.num_rows)
              if tr.enabled else NULL_SPAN) as sp:
            out = self._select_body(node, rel)
            sp.set(kept=out.num_rows)
        return out

    def _select_body(self, node: SelectNode, rel: MaskedRelation) -> MaskedRelation:
        rel = apply_filter_set(self, node, rel)
        rel = apply_dynamic_preds(self, node, rel)
        if rel.num_rows == 0:
            return rel
        pred = node.pred
        attr = pred.attr
        present = rel.is_present(attr)
        missing = rel.is_missing(attr)
        absent = rel.is_absent(attr)

        passes = pred.evaluate_values(rel.values(attr))
        keep = (present & passes) | absent

        self.stats.record_selectivity(
            node.node_id, int((present & passes).sum()), int(present.sum())
        )

        rows = np.nonzero(missing)[0]
        if len(rows):
            imp_rows, delay_rows = decide_groups(self, node, rel, attr, rows)
            if len(imp_rows):
                ok_rows, _bad = self.impute_rows(
                    node, rel, attr, imp_rows, extra_check=pred
                )
                keep[ok_rows] = True
            keep[delay_rows] = True  # preserved with the missing value
        dropped = rel.filter(~keep)
        if dropped.num_rows:
            self.on_rows_dropped(dropped, node)
        return rel.filter(keep)

    # -- ⋈̂ ----------------------------------------------------------------#
    def _join(self, node: JoinNode) -> Iterator[MaskedRelation]:
        js = self.join_states[node.node_id]
        l_attr, r_attr = self.join_attrs[node.node_id]
        l_tabs, r_tabs = self.join_side_tables[node.node_id]

        # ---- build (right) side: materialize ---------------------------- #
        # The build operand is blocked anyway, so with batching on, its
        # Scan/Select chain runs whole-relation-at-a-time: σ̂ decision groups
        # span the full operand and each attribute imputes in one flush.
        # Exact by construction — during build materialization no bloom can
        # complete (completion only fires for the attr being imputed, whose
        # side is unconsumed) and no dynamic bound can move (ρ has not
        # emitted yet), so per-morsel and whole-relation processing request
        # identical imputation sets.  Nested-join build subtrees (bushy
        # plans) keep the seed streaming path.  (adaptive's cost inputs
        # coarsen from morsel to operand granularity; its decisions are
        # wall-clock-dependent either way and answers are invariant.)
        tr = self.tracer
        with (tr.span("op:join_build", node=node.node_id, attr=r_attr)
              if tr.enabled else NULL_SPAN) as bsp:
            prev_whole = self._scan_whole
            if self.batching and not any(
                isinstance(sub, JoinNode) for sub in walk(node.children[1])
            ):
                self._scan_whole = True
            try:
                # build-side subtrees fan out across the worker pool when one
                # is attached (morsel-parallel materialization)
                parts = list(self._stream_subtree(node.children[1]))
            finally:
                self._scan_whole = prev_whole
            build = (
                concat_relations(parts)
                if parts
                else self._empty_of(node.children[1])
            )
            build = self._prepare_join_side(node, js, "R", r_attr, build)
            js.set_snapshot("R", build)
            self.blooms[r_attr].insert(
                build.values(r_attr)[build.is_present(r_attr)]
            )
            self.consumed[r_attr] = True
            js.sides["R"].consumed = True
            self.maybe_complete_bloom(r_attr)
            bsp.set(build_rows=build.num_rows)

        b_present = build.is_present(r_attr)
        b_keys = np.where(
            b_present, build.values(r_attr), np.int64(-(2 ** 62))
        ).astype(np.int64)
        b_missing_rows = np.nonzero(build.is_missing(r_attr))[0]
        if len(b_missing_rows):
            for t in build.tids:
                if t in [table_of(r_attr)]:
                    js.record_deferred("R", build.tids[t][b_missing_rows])

        # deferred / absent build rows rise as outer rows (padded left side)
        outer_rows = np.nonzero(~b_present)[0]
        if len(outer_rows):
            r_side = build.take(outer_rows)
            l_pad = self._pad_for_tables(l_tabs, len(outer_rows))
            padded = l_pad.hstack(r_side)
            padded = apply_dynamic_preds(self, node, padded)
            if padded.num_rows:
                self.counters.temp_tuples += padded.num_rows
                yield self._normalize(node, padded)

        # ---- probe (left) side: stream --------------------------------- #
        first = True
        for morsel in self._stream_subtree(node.children[0]):
            morsel = self._prepare_join_side(node, js, "L", l_attr, morsel)
            js.append_snapshot("L", morsel)
            if morsel.num_rows == 0:
                continue
            p_present = morsel.is_present(l_attr)
            self.blooms[l_attr].insert(morsel.values(l_attr)[p_present])
            p_missing_rows = np.nonzero(morsel.is_missing(l_attr))[0]
            if len(p_missing_rows):
                js.record_deferred(
                    "L", morsel.tids[table_of(l_attr)][p_missing_rows]
                )

            t0 = time.perf_counter()
            probe_keys = np.where(
                p_present, morsel.values(l_attr), np.int64(-(2 ** 61))
            ).astype(np.int64)
            with (tr.span("kernel:multi_match", cat="kernel",
                          node=node.node_id, impl=self.join_impl,
                          build=len(b_keys), probe=len(probe_keys))
                  if tr.enabled else NULL_SPAN):
                p_idx, b_idx = multi_match(
                    b_keys, probe_keys, impl=self.join_impl
                )
            dt = time.perf_counter() - t0
            self.counters.join_tests += int(p_present.sum())
            self.stats.record_join(
                node.node_id,
                tests=max(int(p_present.sum()), 1),
                tuples=max(int(p_present.sum()), 1),
                seconds=dt,
            )
            matched = np.zeros(morsel.num_rows, dtype=bool)
            if len(p_idx):
                matched[p_idx] = True
            # |out| / (|L|·|R|) selectivity over known rows
            denom = max(int(p_present.sum()) * max(len(b_keys), 1), 1)
            self.stats.record_selectivity(node.node_id, len(p_idx), denom)

            pieces = []
            if len(p_idx):
                joined = morsel.take(p_idx).hstack(build.take(b_idx))
                pieces.append(joined)
            # preserved: missing (deferred) or absent key rows → pad right
            keep_outer = ~p_present
            if keep_outer.any():
                l_side = morsel.filter(keep_outer)
                r_pad = self._pad_for_tables(r_tabs, l_side.num_rows)
                pieces.append(l_side.hstack(r_pad))
            # unmatched present-key rows are dropped from the stream (their
            # snapshot copies still serve L1⋈R2 triggers)
            unmatched = morsel.filter(p_present & ~matched)
            if unmatched.num_rows:
                self.on_rows_dropped(unmatched, None)
            if pieces:
                out = concat_relations(
                    [self._normalize(node, p) for p in pieces]
                )
                out = apply_dynamic_preds(self, node, out)
                if out.num_rows:
                    self.counters.temp_tuples += out.num_rows
                    yield out
            first = False

        self.consumed[l_attr] = True
        js.sides["L"].consumed = True
        js.finalize_deferred()
        self.maybe_complete_bloom(l_attr)

    def _prepare_join_side(self, node: JoinNode, js: JoinState, s: str,
                           attr: str, rel: MaskedRelation) -> MaskedRelation:
        """filter → DF → verify for one operand morsel of ⋈̂ (Fig. 4-b)."""
        rel = apply_filter_set(self, node, rel)
        if rel.num_rows == 0:
            return rel
        rows = np.nonzero(rel.is_missing(attr))[0]
        if len(rows) == 0:
            return rel
        imp_rows, _delay = decide_groups(self, node, rel, attr, rows)
        if len(imp_rows) == 0:
            return rel
        ok_rows, bad_rows = self.impute_rows(node, rel, attr, imp_rows)
        if len(bad_rows):
            keep = np.ones(rel.num_rows, dtype=bool)
            keep[bad_rows] = False
            dropped = rel.filter(~keep)
            self.on_rows_dropped(dropped, node)
            rel = rel.filter(keep)
        # verified imputed keys already entered the bloom in impute_rows;
        # the caller inserts the side's present keys after this returns
        return rel

    # -- ρ ------------------------------------------------------------------#
    def _rho(self, node: RhoNode) -> Iterator[MaskedRelation]:
        for morsel in self._stream_subtree(node.children[0]):
            if self._defer_rho:
                # park unprocessed: the fixpoint below imputes the whole
                # pool with one flush per attribute (cross-morsel batching)
                if morsel.num_rows:
                    self._rho_pool.append(morsel)
                continue
            out = self._rho_process(node, morsel, final=False)
            if out is not None and out.num_rows:
                self.counters.temp_tuples += out.num_rows
                yield out
        # finish: fixpoint over the parked pool
        final = self._rho_fixpoint(node)
        if final is not None and final.num_rows:
            self.counters.temp_tuples += final.num_rows
            yield final

    def _rho_process(self, node: RhoNode, rel: MaskedRelation, final: bool
                     ) -> Optional[MaskedRelation]:
        """One ρ pass: impute every missing predicate/projection attribute
        (selection attrs first — paper §5.3 Discussion), full-verify, then
        resolve padded join sides whose partner is complete; park the rest."""
        tr = self.tracer
        with (tr.span("op:rho", node=node.node_id, rows=rel.num_rows,
                      final=final)
              if tr.enabled else NULL_SPAN):
            return self._rho_process_body(node, rel, final)

    def _rho_process_body(self, node: RhoNode, rel: MaskedRelation, final: bool
                          ) -> Optional[MaskedRelation]:
        rel = apply_filter_set(self, node, rel)
        if rel.num_rows == 0:
            return None
        sel_attrs = [p.attr for p in self.query.selections]
        join_attrs = [a for j in self.query.joins for a in j.attrs]
        other = [a for a in node.attrs if a not in sel_attrs + join_attrs]
        for attr in sel_attrs + join_attrs + other:
            if not rel.has_column(attr):
                continue
            rows = np.nonzero(rel.is_missing(attr))[0]
            if len(rows) == 0:
                continue
            _ok, bad = self.impute_rows(node, rel, attr, rows)
            if len(bad):
                keep = np.ones(rel.num_rows, dtype=bool)
                keep[bad] = False
                self.on_rows_dropped(rel.filter(~keep), node)
                rel = rel.filter(keep)
            if rel.num_rows == 0:
                return None
        rel = full_verify(self, rel)
        if rel.num_rows == 0:
            return None

        # split: fully-concrete rows emit; padded rows resolve or park
        unresolved = self._unresolved_join(rel)
        done = unresolved < 0
        emit = [rel.filter(done)] if done.any() else []
        pending = rel.filter(~done)
        if pending.num_rows:
            resolved_now = self._try_resolve(pending, allow_incomplete=final)
            if resolved_now is not None:
                out = self._rho_process(node, resolved_now, final)
                if out is not None and out.num_rows:
                    emit.append(out)
        return concat_relations(emit) if emit else None

    def _side_padded(self, rel: MaskedRelation, tabs: Sequence[str]) -> np.ndarray:
        padded = np.ones(rel.num_rows, dtype=bool)
        for t in tabs:
            tids = rel.tids.get(t)
            padded &= (tids < 0) if tids is not None else True
        return padded

    def _unresolved_join(self, rel: MaskedRelation) -> np.ndarray:
        """Per row: index into self.join_nodes of the lowest join with
        *exactly one* fully-padded side (the resolvable kind), or -1 if the
        row is concrete.  A join with both sides padded resolves implicitly
        when a higher join's expansion attaches one side's snapshot row."""
        out = np.full(rel.num_rows, -1, dtype=np.int64)
        decided = np.zeros(rel.num_rows, dtype=bool)
        for k, n in enumerate(self.join_nodes):  # post-order: bottom-up
            l_tabs, r_tabs = self.join_side_tables[n.node_id]
            l_pad = self._side_padded(rel, l_tabs)
            r_pad = self._side_padded(rel, r_tabs)
            hit = (l_pad ^ r_pad) & ~decided
            out[hit] = k
            decided |= hit
        return out

    def _try_resolve(self, rel: MaskedRelation, allow_incomplete: bool
                     ) -> Optional[MaskedRelation]:
        """Resolve each row's lowest padded join via BF_Join (Alg. 1–2);
        rows whose partner side is not yet complete are parked."""
        unresolved = self._unresolved_join(rel)
        outputs = []
        parked = []
        for k in np.unique(unresolved):
            n = self.join_nodes[int(k)]
            js = self.join_states[n.node_id]
            rows_mask = unresolved == k
            sub = rel.filter(rows_mask)
            l_tabs, r_tabs = self.join_side_tables[n.node_id]
            # which side is padded?
            r_padded = np.ones(sub.num_rows, dtype=bool)
            for t in r_tabs:
                tids = sub.tids.get(t)
                r_padded &= (tids < 0) if tids is not None else True
            for side_padded, s in ((r_padded, "L"), (~r_padded, "R")):
                rows = np.nonzero(side_padded)[0]
                if len(rows) == 0:
                    continue
                me = js.sides[s]
                partner = js.sides[js.other(s)]
                if allow_incomplete and partner.consumed:
                    # finish-time: close the matched side's key first (BFC)
                    self._ensure_closed(partner.attr)
                ready = partner.consumed and (
                    allow_incomplete
                    or (
                        self.blooms[partner.attr].complete
                        and partner.deferred_tids is None
                    )
                )
                own_key_known = sub.is_present(me.attr)[rows]
                rows_ready = rows[own_key_known] if ready else rows[:0]
                rows_park = np.setdiff1d(rows, rows_ready)
                if len(rows_ready):
                    expanded, _resolved = js.bf_join(
                        sub, rows_ready, s, counters=self.counters,
                        bloom_impl=self.bloom_impl,
                    )
                    if expanded is not None and expanded.num_rows:
                        outputs.append(expanded)
                if len(rows_park):
                    parked.append(sub.take(rows_park))
        if parked:
            self._rho_pool.append(concat_relations(parked))
        if outputs:
            return concat_relations(outputs)
        return None

    def _ensure_closed(self, attr: str) -> None:
        """Impute every missing ``attr`` key of alive snapshot rows — the
        executor analogue of the paper's BFC(attr) precondition for BF_Join.

        Deferred rows can be revived by *cascading* expansions (a higher
        join's resolution re-attaches a snapshot row whose lower-join key is
        still missing), so a resolution that matches on ``attr`` must wait
        until every revivable ``attr`` key is written back.  Run lazily (only
        for sides a resolution actually targets) to preserve the paper's
        imputation savings; one pass suffices because snapshots are fixed
        row sets and writeback only fills keys in."""
        if attr in self._closed_attrs:
            return
        self._closed_attrs.add(attr)
        t = table_of(attr)
        tids: Set[int] = set()
        for js in self.join_states.values():
            for side in js.sides.values():
                snap = side.snapshot
                if snap is None or not snap.has_column(attr):
                    continue
                m = np.asarray(snap.is_missing(attr)) & side.alive
                st = snap.tids.get(t)
                if st is None:
                    continue
                tids.update(st[m & (st >= 0)].tolist())
        if tids:
            arr = np.array(sorted(tids), dtype=np.int64)
            prov = self.provenance
            if prov is not None:
                with prov.at("rho_close", -1):
                    values = self._request_values(t, attr, arr)
            else:
                values = self._request_values(t, attr, arr)
            owner = next(
                (n for n in self.join_nodes
                 if attr in self.join_attrs[n.node_id]),
                self.root,
            )
            passed = verify_values(owner, attr, values)
            for js in self.join_states.values():
                js.writeback(attr, arr, values, passed)
            if attr in self.blooms:
                self.blooms[attr].insert(values[passed])
            self.record_imputed(attr, arr)
        if attr in self.blooms and self.consumed.get(attr, False):
            self.blooms[attr].mark_complete()

    def _rho_fixpoint(self, node: RhoNode) -> Optional[MaskedRelation]:
        """End-of-stream: all operands consumed.  Alternate impute sweeps and
        resolution sweeps until the pool drains (cascading triggers)."""
        for a, b in self.blooms.items():
            if self.consumed.get(a, False) and not self.outstanding.get(a):
                b.mark_complete()
        emitted = []
        guard = 0
        while self._rho_pool:
            guard += 1
            assert guard <= 10_000, "ρ fixpoint failed to converge"
            pool = concat_relations(self._rho_pool)
            self._rho_pool = []
            out = self._rho_process(node, pool, final=True)
            if out is not None and out.num_rows:
                emitted.append(out)
            if self._rho_pool and concat_relations(self._rho_pool).num_rows == pool.num_rows:
                # no progress: remaining rows are unresolvable → eliminated
                leftover = concat_relations(self._rho_pool)
                self._rho_pool = []
                self.on_rows_dropped(leftover, node)
                break
        return concat_relations(emitted) if emitted else None

    # ------------------------------------------------------------------ #
    # helpers
    # ------------------------------------------------------------------ #
    def _pad_for_tables(self, tabs: Sequence[str], n: int) -> MaskedRelation:
        rels = [self.tables[t].pad_like(n) for t in tabs]
        out = rels[0]
        for r in rels[1:]:
            out = out.hstack(r)
        return out

    def _empty_of(self, node: PlanNode) -> MaskedRelation:
        return self._pad_for_tables(base_tables(node), 0)

    def _normalize(self, node: JoinNode, rel: MaskedRelation) -> MaskedRelation:
        l_tabs, r_tabs = self.join_side_tables[node.node_id]
        cols = []
        for t in l_tabs + r_tabs:
            cols.extend(self.tables[t].column_names())
        return rel.project(cols)

    # ------------------------------------------------------------------ #
    # top-level run
    # ------------------------------------------------------------------ #
    def steps(self) -> Iterator[None]:
        """Morsel-granular coroutine execution.

        Yields control after every top-level morsel so a scheduler can
        interleave several executors (the QuipService serving layer steps
        many of these round-robin — no threads, plain generator stepping).
        When the generator is exhausted, :attr:`result` holds the
        :class:`ExecutionResult`.  ``counters.wall_seconds`` accumulates only
        this executor's *active* step time (plus its engine's simulated
        seconds), so latencies stay meaningful under interleaving.
        """
        active = 0.0
        top = self.root
        agg = None
        proj = None
        if isinstance(top, AggregateNode):
            agg = top.agg
            body = top.children[0]
        elif isinstance(top, ProjectNode):
            proj = top.attrs
            body = top.children[0]
        else:
            body = top

        chunks: List[MaskedRelation] = []
        stream = self._stream_subtree(body)
        while True:
            t0 = time.perf_counter()
            try:
                morsel = next(stream)
            except StopIteration:
                active += time.perf_counter() - t0
                break
            if morsel.num_rows:
                chunks.append(morsel)
                if self._minmax is not None:
                    self._update_minmax(morsel)
            active += time.perf_counter() - t0
            yield

        t0 = time.perf_counter()
        rel = (
            concat_relations(chunks)
            if chunks
            else self._pad_for_tables(self.query.tables, 0)
        )
        aux = None
        if agg is not None:
            aux = agg_aux_of(rel, agg)
            rel = _aggregate(rel, agg)
        elif proj is not None:
            rel = rel.project(list(proj))
        active += time.perf_counter() - t0
        self.counters.wall_seconds = active + self.engine.simulated_seconds
        self.result = ExecutionResult(rel, self.counters, self.stats,
                                      self.root, agg_aux=aux)

    def run(self) -> ExecutionResult:
        for _ in self.steps():
            pass
        return self.result

    def _update_minmax(self, rel: MaskedRelation) -> None:
        dyn = self._minmax
        if not rel.has_column(dyn.attr):
            return
        present = rel.is_present(dyn.attr)
        if not present.any():
            return
        vals = rel.values(dyn.attr)[present]
        best = vals.max() if dyn.op == ">" else vals.min()
        if dyn.value is None:
            dyn.value = best
        else:
            dyn.value = max(dyn.value, best) if dyn.op == ">" else min(dyn.value, best)


# --------------------------------------------------------------------------- #
# aggregation (over fully-resolved rows)
# --------------------------------------------------------------------------- #
# Totals whose absolute-value bound stays under 2^52 are exactly
# representable in float64 at every pairwise partial sum, so the patched
# (python-int) total cast to float64 is bit-identical to numpy's
# sum()/mean() over the hypothetical re-executed body (2^52, not 2^53,
# leaves margin for the float64 bound estimate itself).
_EXACT_ABS_BOUND = float(2 ** 52)


@dataclasses.dataclass
class GroupStat:
    """Linear per-group state: row/present counts plus (for int attributes
    within the exact-float64 bound) exact totals as python ints.  Adding /
    subtracting two GroupStats is exactly how a COUNT/SUM/AVG answer is
    maintained under a delta."""

    n_rows: int
    n_present: int
    total: int = 0
    abs_total: int = 0
    exact: bool = False  # totals are exact python ints (int attr, in bound)


@dataclasses.dataclass
class AggAux:
    """Aggregate auxiliary state emitted next to an aggregate answer.

    ``groups`` maps group key (python scalar; ``None`` for the scalar,
    non-grouped case) → :class:`GroupStat`.  ``valid`` is False when the
    grouping column had missing/absent/NaN cells — group identity is then
    fill-payload-dependent and the answer is not safely patchable."""

    op: str
    attr: Optional[str]
    group_by: Optional[str]
    attr_kind: Optional[str]
    valid: bool
    groups: Dict[object, GroupStat]


def _group_stat(group: np.ndarray, n_rows: int, is_int_attr: bool,
                has_attr: bool) -> GroupStat:
    if not has_attr:
        return GroupStat(n_rows=n_rows, n_present=n_rows,
                         total=0, abs_total=0, exact=True)
    n_present = len(group)
    if not is_int_attr:
        return GroupStat(n_rows=n_rows, n_present=n_present, exact=False)
    bound = float(np.sum(np.abs(group), dtype=np.float64)) if n_present else 0.0
    if bound >= _EXACT_ABS_BOUND:
        return GroupStat(n_rows=n_rows, n_present=n_present, exact=False)
    total = int(np.sum(group, dtype=np.int64)) if n_present else 0
    abs_total = int(np.sum(np.abs(group), dtype=np.int64)) if n_present else 0
    return GroupStat(n_rows=n_rows, n_present=n_present,
                     total=total, abs_total=abs_total, exact=True)


def _pykey(k) -> object:
    return float(k) if isinstance(k, (np.floating, float)) else int(k)


def agg_aux_of(rel: MaskedRelation, agg) -> AggAux:
    """The :class:`AggAux` for aggregating ``rel`` — computable standalone
    (the IVM maintainer runs it over delta bodies) or alongside
    :func:`_aggregate` (same grouping semantics: raw group-by values,
    present-only attribute values)."""
    op, attr, gb = agg.op, agg.attr, agg.group_by
    attr_kind = rel.schema.column(attr).kind if attr else None
    is_int = attr_kind == "int"
    if attr:
        present = rel.is_present(attr)
        avals = rel.values(attr)
    valid = True
    groups: Dict[object, GroupStat] = {}
    if gb is None:
        if attr:
            group = avals[present]
            groups[None] = _group_stat(group, rel.num_rows, is_int, True)
        else:
            groups[None] = _group_stat(
                np.empty(0), rel.num_rows, False, False
            )
    else:
        keys = rel.values(gb)
        if rel.num_rows and not rel.is_present(gb).all():
            # a missing/absent group-by cell groups under its fill payload —
            # answer-reproducible but not delta-patchable
            valid = False
        elif np.issubdtype(keys.dtype, np.floating) and np.isnan(keys).any():
            valid = False  # NaN != NaN breaks group-key arithmetic
        else:
            for k in np.unique(keys):
                m = keys == k
                n_rows = int(m.sum())
                if attr:
                    group = avals[m & present]
                    groups[_pykey(k)] = _group_stat(group, n_rows, is_int, True)
                else:
                    groups[_pykey(k)] = _group_stat(
                        np.empty(0), n_rows, False, False
                    )
    return AggAux(op, attr, gb, attr_kind, valid, groups)


def _aggregate(rel: MaskedRelation, agg) -> MaskedRelation:
    op, attr, gb = agg.op, agg.attr, agg.group_by
    out_name = f"{op}({attr or '*'})"
    kind = "int" if op == "count" else (
        "float" if op in ("avg", "sum") else
        ("float" if attr and rel.schema.column(attr).kind == "float" else "int")
    )

    def reduce_vals(v: np.ndarray):
        if op == "count":
            return len(v)
        if len(v) == 0:
            return np.nan
        if op == "max":
            return v.max()
        if op == "min":
            return v.min()
        if op == "sum":
            return v.sum()
        return v.mean()  # avg

    if gb is None:
        v = rel.values(attr)[rel.is_present(attr)] if attr else np.zeros(rel.num_rows)
        val = reduce_vals(v if attr else np.zeros(rel.num_rows))
        # SQL semantics: an aggregate over zero non-NULL inputs is NULL —
        # whether the relation is empty or every surviving row has the attr
        # absent (outer-pad rows).  Use a clean 0 payload under the absent
        # bit instead of pushing NaN through the int cast.
        null_out = op != "count" and len(v) == 0
        if null_out:
            val = 0
        schema = Schema("agg", [ColumnSpec(out_name, kind)])
        data = {out_name: np.array([val])}
        out = MaskedRelation.from_columns(schema, data)
        if null_out:
            out.missing[out_name][:] = False
            out.absent[out_name][:] = True
        return out

    keys = rel.values(gb)
    uniq = np.unique(keys)
    vals, null_rows = [], []
    for k in uniq:
        m = keys == k
        if attr:
            sel = m & rel.is_present(attr)
            group = rel.values(attr)[sel]
        else:
            group = np.zeros(int(m.sum()))
        if op != "count" and len(group) == 0:
            # zero non-NULL inputs in this group → NULL (clean 0 payload
            # under the absent bit, not NaN through the int cast)
            vals.append(0)
            null_rows.append(True)
        else:
            vals.append(reduce_vals(group))
            null_rows.append(False)
    schema = Schema(
        "agg",
        [ColumnSpec(gb, rel.schema.column(gb).kind), ColumnSpec(out_name, kind)],
    )
    out = MaskedRelation.from_columns(
        schema, {gb: uniq, out_name: np.asarray(vals)}
    )
    if any(null_rows):
        out.absent[out_name][np.asarray(null_rows, dtype=bool)] = True
    return out


def relation_from_agg_aux(aux: AggAux, schema: Schema
                          ) -> Optional[MaskedRelation]:
    """Rebuild the aggregate answer relation from (patched) auxiliary
    state, reproducing :func:`_aggregate` bit-for-bit — same group order
    (ascending keys, as ``np.unique`` emits), same NULL rule (absent bit +
    0 payload for a non-count aggregate over zero present inputs), same
    dtypes (via the cached answer's ``schema``).  Returns ``None`` when an
    exact rebuild is not provable: invalid grouping state, MIN/MAX, float
    totals, or totals outside the exact-float64 bound."""
    op, attr, gb = aux.op, aux.attr, aux.group_by
    if not aux.valid or op not in ("count", "sum", "avg"):
        return None
    if op != "count" and (attr is None or aux.attr_kind != "int"):
        return None
    out_name = f"{op}({attr or '*'})"

    def value_of(st: GroupStat):
        # mirrors _aggregate: count(attr)=n_present, count(*)=n_rows, the
        # NULL rule applies only to non-count ops, avg is exact-int total
        # over present count (same IEEE division np.mean performs)
        if op == "count":
            return (st.n_present if attr else st.n_rows), False
        if st.n_present == 0:
            return 0, True
        if not st.exact or st.abs_total >= _EXACT_ABS_BOUND:
            return None
        if op == "sum":
            return st.total, False
        return st.total / st.n_present, False

    if gb is None:
        st = aux.groups.get(None)
        if st is None or st.n_rows < 0 or st.n_present < 0:
            return None
        vo = value_of(st)
        if vo is None:
            return None
        val, null_out = vo
        out = MaskedRelation.from_columns(
            schema, {out_name: np.array([val])}
        )
        if null_out:
            out.absent[out_name][:] = True
        return out

    live = {k: st for k, st in aux.groups.items() if st.n_rows != 0}
    if any(st.n_rows < 0 or st.n_present < 0 or st.n_present > st.n_rows
           for st in live.values()):
        return None
    keys = sorted(live)
    vals, nulls = [], []
    for k in keys:
        vo = value_of(live[k])
        if vo is None:
            return None
        v, nl = vo
        vals.append(v)
        nulls.append(nl)
    gb_dtype = schema.column(gb).np_dtype
    out = MaskedRelation.from_columns(schema, {
        gb: np.asarray(keys, dtype=gb_dtype),
        out_name: np.asarray(vals, dtype=schema.column(out_name).np_dtype),
    })
    if any(nulls):
        out.absent[out_name][np.asarray(nulls, dtype=bool)] = True
    return out


# --------------------------------------------------------------------------- #
# public entry points
# --------------------------------------------------------------------------- #
def execute_quip(
    query: Query,
    tables: Dict[str, MaskedRelation],
    engine,
    plan: Optional[PlanNode] = None,
    strategy: str = "adaptive",
    planner: str = "imputedb",
    morsel_rows: int = 8192,
    bloom_impl: Optional[str] = None,
    join_impl: Optional[str] = None,
    minmax_opt: bool = True,
    use_vf: bool = True,
    exec_impl: Optional[str] = None,
) -> ExecutionResult:
    if plan is None:
        plan = make_plan(query, tables, planner=planner)
    # compiled dispatch (QUIP_EXEC_IMPL mirrors QUIP_JOIN_IMPL): lower the
    # plan to a whole-relation tensor program when provably answer-identical,
    # else count the fallback and run the interpreter below
    from repro.core.compiled import (
        CompileFallback,
        compile_plan,
        resolve_exec_impl,
    )

    if resolve_exec_impl(exec_impl) == "compiled":
        try:
            compiled = compile_plan(
                query, plan, tables, strategy,
                use_vf=use_vf, minmax_opt=minmax_opt, join_impl=join_impl,
            )
        except CompileFallback:
            engine.counters.compile_fallbacks += 1
        else:
            return compiled.run(
                {t: tables[t].copy() for t in query.tables}, engine
            )
    ex = QuipExecutor(
        query,
        {t: tables[t].copy() for t in query.tables},
        plan,
        engine,
        strategy=strategy,
        morsel_rows=morsel_rows,
        bloom_impl=bloom_impl,
        join_impl=join_impl,
        minmax_opt=minmax_opt,
        use_vf=use_vf,
    )
    return ex.run()


def execute_offline(
    query: Query, tables: Dict[str, MaskedRelation], engine
) -> ExecutionResult:
    """Offline baseline: impute *every* missing value first, then evaluate.

    All (table, attr) requests queue up front and flush once — the
    cross-operator request queue coalesces them into one deduplicated batch
    per attribute."""
    t0 = time.perf_counter()
    clean: Dict[str, MaskedRelation] = {}
    for t in query.tables:
        rel = tables[t].copy()
        for a in rel.column_names():
            rows = np.nonzero(rel.is_missing(a))[0]
            if len(rows):
                engine.enqueue(t, a, rel.tids[t][rows])
        clean[t] = rel
    prov = getattr(engine, "provenance", None)
    if prov is not None:
        with prov.at("offline", -1):
            engine.flush()
    else:
        engine.flush()
    for t, rel in clean.items():
        for a in rel.column_names():
            rows = np.nonzero(rel.is_missing(a))[0]
            if len(rows):
                rel.set_values(a, rows, engine.lookup(t, a, rel.tids[t][rows]))
    body = evaluate_clean_body(query, clean)
    aux = None
    if query.aggregate is not None:
        aux = agg_aux_of(body, query.aggregate)
        rel = _aggregate(body, query.aggregate)
    elif query.projection:
        rel = body.project(list(query.projection))
    else:
        rel = body
    engine.counters.wall_seconds = (
        time.perf_counter() - t0
    ) + engine.simulated_seconds
    return ExecutionResult(rel, engine.counters, engine.stats, None,
                           agg_aux=aux)


def evaluate_clean(query: Query, tables: Dict[str, MaskedRelation]
                   ) -> MaskedRelation:
    """Independent relational oracle over clean (no-missing) tables: filter,
    join (in a connectivity-preserving order), project/aggregate."""
    body = evaluate_clean_body(query, tables)
    if query.aggregate is not None:
        return _aggregate(body, query.aggregate)
    if query.projection:
        return body.project(list(query.projection))
    return body


def evaluate_clean_body(query: Query, tables: Dict[str, MaskedRelation]
                        ) -> MaskedRelation:
    """The pre-aggregate/projection body of :func:`evaluate_clean`: filter
    each table, join in a connectivity-preserving order, return the full
    joined relation."""
    filtered: Dict[str, MaskedRelation] = {}
    for t in query.tables:
        rel = tables[t]
        keep = np.ones(rel.num_rows, dtype=bool)
        for p in query.selections:
            if p.table == t:
                passes, known = p.evaluate(rel)
                keep &= passes
        filtered[t] = rel.filter(keep)

    done = {query.tables[0]}
    cur = filtered[query.tables[0]]
    remaining = list(query.joins)
    while remaining:
        hit = None
        for j in remaining:
            if (j.left_table in done) != (j.right_table in done):
                hit = j
                break
            if j.left_table in done and j.right_table in done:
                hit = j
                break
        assert hit is not None, "disconnected join graph"
        remaining.remove(hit)
        if hit.left_table in done and hit.right_table in done:
            both = (
                cur.values(hit.left_attr) == cur.values(hit.right_attr)
            )
            cur = cur.filter(both)
            continue
        if hit.left_table in done:
            my_attr, other_attr = hit.left_attr, hit.right_attr
        else:
            my_attr, other_attr = hit.right_attr, hit.left_attr
        other = filtered[table_of(other_attr)]
        p_idx, b_idx = multi_match(
            other.values(other_attr), cur.values(my_attr)
        )
        cur = cur.take(p_idx).hstack(other.take(b_idx))
        done.add(table_of(other_attr))

    return cur
