"""Environment-variable parsing shared by every QUIP_* gate.

The serving and imputation layers are gated by boolean env vars
(``QUIP_SHARED_IMPUTE``, ``QUIP_IMPUTE_BATCH``).  Each used to parse the
raw string ad hoc — ``resolve_shared_impute`` accepted only the literal
``"1"``, so ``QUIP_SHARED_IMPUTE=true`` silently left sharing *off*.
:func:`env_flag` is the one shared parser: the usual truthy/falsy spellings
work, anything else fails loud instead of silently picking a default.

:func:`env_choice` is the enumerated-value twin for the implementation
dispatch vars (``QUIP_JOIN_IMPL``, ``QUIP_KNN_IMPL``, ``QUIP_EXEC_IMPL``,
``QUIP_SEGMENT_IMPL``): each call site used to hand-parse
``impl or os.environ.get(...) or default`` and a typo'd value raised only
*after* silently skipping the env var's precedence rules; now garbage
fails loud with the variable name and the accepted spellings, exactly
like ``env_flag``.

:func:`env_int` is the integer sibling (``QUIP_FUZZ_SEED``): unset means
the default, garbage raises instead of silently falling back.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

__all__ = ["env_flag", "env_choice", "env_int"]

_TRUE = frozenset({"1", "true", "yes", "on"})
_FALSE = frozenset({"0", "false", "no", "off"})


def env_flag(name: str, default: bool) -> bool:
    """Boolean env var ``name``: 1/true/yes/on ↔ 0/false/no/off (any case).

    Unset (or empty) returns ``default``; any other value raises
    ``ValueError`` — a typo'd gate must not silently mean "off".
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return bool(default)
    value = raw.strip().lower()
    if value in _TRUE:
        return True
    if value in _FALSE:
        return False
    raise ValueError(
        f"{name}={raw!r} is not a boolean flag "
        f"(expected one of {sorted(_TRUE)} or {sorted(_FALSE)})"
    )


def env_choice(name: str, choices: Sequence[str], default: str) -> str:
    """Enumerated env var ``name``: one of ``choices`` (any case).

    Unset (or empty) returns ``default``; any other value raises
    ``ValueError`` — a typo'd impl name must not silently pick a default.
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    value = raw.strip().lower()
    if value in choices:
        return value
    raise ValueError(
        f"{name}={raw!r} is not a valid choice (expected one of {sorted(choices)})"
    )


def env_int(name: str, default: Optional[int] = None) -> Optional[int]:
    """Integer env var ``name`` (e.g. ``QUIP_FUZZ_SEED``).

    Unset (or empty) returns ``default``; any non-integer value raises
    ``ValueError`` — a typo'd seed must not silently fall back to the
    default sweep.
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        return int(raw.strip())
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not an integer"
        ) from None
