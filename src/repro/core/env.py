"""Environment-variable parsing shared by every QUIP_* gate.

The serving and imputation layers are gated by boolean env vars
(``QUIP_SHARED_IMPUTE``, ``QUIP_IMPUTE_BATCH``).  Each used to parse the
raw string ad hoc — ``resolve_shared_impute`` accepted only the literal
``"1"``, so ``QUIP_SHARED_IMPUTE=true`` silently left sharing *off*.
:func:`env_flag` is the one shared parser: the usual truthy/falsy spellings
work, anything else fails loud instead of silently picking a default.

:func:`env_choice` is the enumerated-value twin for the implementation
dispatch vars (``QUIP_JOIN_IMPL``, ``QUIP_KNN_IMPL``, ``QUIP_EXEC_IMPL``,
``QUIP_SEGMENT_IMPL``): each call site used to hand-parse
``impl or os.environ.get(...) or default`` and a typo'd value raised only
*after* silently skipping the env var's precedence rules; now garbage
fails loud with the variable name and the accepted spellings, exactly
like ``env_flag``.

:func:`env_int` is the integer sibling (``QUIP_FUZZ_SEED``): unset means
the default, garbage raises instead of silently falling back.

:data:`ENV_REGISTRY` is the one catalog of every ``QUIP_*`` knob the tree
reads — name, kind, default, accepted values, owning module, one-line doc.
The quiplint env-discipline pass (``repro.analysis``) enforces that every
``QUIP_*`` read goes through the parsers above against a registered name,
and that the generated table in ``docs/analysis.md`` matches this registry
exactly; an unregistered knob (or a registered-but-undocumented one) fails
CI.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence, Tuple

__all__ = ["ENV_REGISTRY", "EnvKnob", "env_flag", "env_choice", "env_int"]

_TRUE = frozenset({"1", "true", "yes", "on"})
_FALSE = frozenset({"0", "false", "no", "off"})


def env_flag(name: str, default: bool) -> bool:
    """Boolean env var ``name``: 1/true/yes/on ↔ 0/false/no/off (any case).

    Unset (or empty) returns ``default``; any other value raises
    ``ValueError`` — a typo'd gate must not silently mean "off".
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return bool(default)
    value = raw.strip().lower()
    if value in _TRUE:
        return True
    if value in _FALSE:
        return False
    raise ValueError(
        f"{name}={raw!r} is not a boolean flag "
        f"(expected one of {sorted(_TRUE)} or {sorted(_FALSE)})"
    )


def env_choice(name: str, choices: Sequence[str], default: str) -> str:
    """Enumerated env var ``name``: one of ``choices`` (any case).

    Unset (or empty) returns ``default``; any other value raises
    ``ValueError`` — a typo'd impl name must not silently pick a default.
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    value = raw.strip().lower()
    if value in choices:
        return value
    raise ValueError(
        f"{name}={raw!r} is not a valid choice (expected one of {sorted(choices)})"
    )


def env_int(name: str, default: Optional[int] = None) -> Optional[int]:
    """Integer env var ``name`` (e.g. ``QUIP_FUZZ_SEED``).

    Unset (or empty) returns ``default``; any non-integer value raises
    ``ValueError`` — a typo'd seed must not silently fall back to the
    default sweep.
    """
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        return int(raw.strip())
    except ValueError:
        raise ValueError(
            f"{name}={raw!r} is not an integer"
        ) from None


# --------------------------------------------------------------------------- #
# the QUIP_* knob registry
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class EnvKnob:
    """One registered ``QUIP_*`` environment knob.

    ``kind`` is the parser family (``flag`` | ``choice`` | ``int``);
    ``default`` is the human-readable unset behaviour; ``choices`` lists
    the accepted spellings for ``choice`` knobs; ``owner`` names the module
    whose resolver reads it."""

    name: str
    kind: str
    default: str
    doc: str
    choices: Tuple[str, ...] = ()
    owner: str = ""


def _registry(*knobs: EnvKnob) -> Dict[str, EnvKnob]:
    out: Dict[str, EnvKnob] = {}
    for knob in knobs:
        if knob.name in out:
            raise ValueError(f"duplicate ENV_REGISTRY knob {knob.name}")
        out[knob.name] = knob
    return out


#: Every QUIP_* knob the tree reads.  quiplint's env-discipline pass fails
#: on any env_flag/env_choice/env_int call naming a QUIP_* variable that is
#: not listed here, on any registered knob with no read site, and on any
#: drift between this registry and the table in docs/analysis.md.
ENV_REGISTRY: Dict[str, EnvKnob] = _registry(
    EnvKnob("QUIP_SHARED_IMPUTE", "flag", "off",
            "cross-query imputation sharing (one ImputeStore for all "
            "sessions)", owner="service/impute_store.py"),
    EnvKnob("QUIP_IMPUTE_BATCH", "flag", "on",
            "batched request-queue imputation (off = per-call flushes)",
            owner="imputers/base.py"),
    EnvKnob("QUIP_JOIN_IMPL", "choice", "numpy (engine) / auto (kernel)",
            "join-spine dispatch: numpy sort-join oracle, jnp ref, or the "
            "Pallas open-addressing kernels; unset means numpy in the "
            "engine (core/triggers.py) and the backend default in the "
            "kernel wrapper (kernels/ops.py)",
            choices=("numpy", "ref", "pallas"),
            owner="core/triggers.py, kernels/ops.py"),
    EnvKnob("QUIP_KNN_IMPL", "choice", "numpy",
            "KNN neighbour-aggregation dispatch (mean/mode)",
            choices=("numpy", "ref", "pallas"), owner="kernels/ops.py"),
    EnvKnob("QUIP_SEGMENT_IMPL", "choice", "numpy",
            "grouped-aggregate segment-reduction dispatch",
            choices=("numpy", "ref", "pallas"), owner="kernels/ops.py"),
    EnvKnob("QUIP_BLOOM_IMPL", "choice", "auto (pallas on TPU, ref on CPU)",
            "bloom-probe dispatch for join pruning",
            choices=("numpy", "ref", "pallas"), owner="kernels/ops.py"),
    EnvKnob("QUIP_DIST_IMPL", "choice", "auto (pallas on TPU, ref on CPU)",
            "masked KNN partial-distance dispatch",
            choices=("numpy", "ref", "pallas"), owner="kernels/ops.py"),
    EnvKnob("QUIP_EXEC_IMPL", "choice", "interp",
            "executor dispatch: morsel interpreter or compiled tensor "
            "plans", choices=("interp", "compiled"),
            owner="core/compiled.py"),
    EnvKnob("QUIP_TRACE", "flag", "off",
            "span tracing (Chrome-trace/Perfetto export)",
            owner="obs/trace.py"),
    EnvKnob("QUIP_TRACE_CLOCK", "choice", "wall",
            "span-tracer clock: wall seconds or the deterministic unit "
            "tick", choices=("wall", "unit"), owner="obs/trace.py"),
    EnvKnob("QUIP_EXPLAIN", "flag", "off",
            "per-query impute-provenance recording (explain reports)",
            owner="obs/provenance.py"),
    EnvKnob("QUIP_IVM", "flag", "off",
            "delta-driven result-cache maintenance: patch cached answers "
            "under registry mutations instead of evicting them",
            owner="service/ivm.py"),
    EnvKnob("QUIP_FUZZ_SEED", "int", "unset",
            "extra seed injected into the serving-fuzzer sweeps (CI "
            "repro)", owner="tests/test_serving_fuzz.py"),
    EnvKnob("QUIP_SANITIZE", "choice", "off",
            "runtime sanitizers: 'locks' swaps every lock site for "
            "instrumented wrappers feeding the lock-order graph",
            choices=("off", "locks"), owner="analysis/lockcheck.py"),
)
