"""Join state + trigger machinery (paper §5.2–§5.3, Algorithms 1–2).

Each modified join ⋈̂ keeps *operand snapshots* (the paper's "index" over the
operand relations), deferred-row bookkeeping (L2/R2, L_temp + Flag), and the
two bloom filters.  ``BF_Join`` recovers the join parts that were skipped when
a missing key was preserved (L2⋈R1, L1⋈R2, L2⋈R2), using the bloom filter as
a cheap pre-filter and an L_temp-based dedup of L2⋈R2 exactly as Algorithm 2.

Imputed keys are written back into the snapshots (with an alive-mask cleared
on verify failure) so that late resolutions observe them — this is what makes
``R2 ⋈ L`` "complete" in the paper's footnote 7.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.env import env_choice
from repro.core.relation import MaskedRelation, concat_relations
from repro.core.schema import table_of
from repro.kernels import ops as kops

__all__ = ["JoinState", "multi_match", "resolve_join_impl"]


_JOIN_IMPLS = ("numpy", "ref", "pallas")


def resolve_join_impl(impl: Optional[str] = None) -> str:
    """Join-core dispatch: explicit ``impl`` > ``QUIP_JOIN_IMPL`` env >
    ``"numpy"`` (the sort-join oracle).  ``"ref"`` / ``"pallas"`` route
    through the kernel layer (``kernels.ops.hash_join_match``)."""
    if impl is not None:
        if impl not in _JOIN_IMPLS:
            raise ValueError(f"unknown join impl {impl!r}")
        return impl
    return env_choice("QUIP_JOIN_IMPL", _JOIN_IMPLS, "numpy")


def multi_match(build_keys: np.ndarray, probe_keys: np.ndarray,
                impl: Optional[str] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """All (probe_idx, build_idx) pairs with equal keys — vectorized hash-join
    core (sort + searchsorted + ragged range expansion).

    ``impl`` (or the ``QUIP_JOIN_IMPL`` env var) routes the match through the
    kernel-backed hash join instead; the NumPy path below stays the semantics
    oracle.  Non-integer key dtypes always take the NumPy path (the kernels
    hash folded 64-bit integers).
    """
    impl = resolve_join_impl(impl)
    if (
        impl != "numpy"
        and np.issubdtype(np.asarray(build_keys).dtype, np.integer)
        and np.issubdtype(np.asarray(probe_keys).dtype, np.integer)
    ):
        return kops.hash_join_match(build_keys, probe_keys, impl=impl)
    if len(build_keys) == 0 or len(probe_keys) == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    order = np.argsort(build_keys, kind="stable")
    sk = build_keys[order]
    lo = np.searchsorted(sk, probe_keys, "left")
    hi = np.searchsorted(sk, probe_keys, "right")
    counts = hi - lo
    total = int(counts.sum())
    if total == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    probe_idx = np.repeat(np.arange(len(probe_keys), dtype=np.int64), counts)
    starts = np.repeat(lo, counts)
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    build_idx = order[starts + offs]
    return probe_idx, build_idx


@dataclasses.dataclass
class _Side:
    attr: str  # qualified key attribute of this side
    snapshot: Optional[MaskedRelation] = None
    alive: Optional[np.ndarray] = None  # False => eliminated by verify failure
    deferred_mask: Optional[np.ndarray] = None  # key missing at append time
    deferred_tids: Optional[np.ndarray] = None  # base tids of missing-key rows
    consumed: bool = False  # operand fully seen (hash built / stream ended)

    @property
    def table(self) -> str:
        return table_of(self.attr)


class JoinState:
    """Runtime state of one modified join operator."""

    def __init__(self, node_id: int, left_attr: str, right_attr: str,
                 bloom_left: BloomFilter, bloom_right: BloomFilter,
                 join_impl: Optional[str] = None):
        self.node_id = node_id
        self.join_impl = join_impl  # resolved per call (env may change)
        self.sides: Dict[str, _Side] = {
            "L": _Side(left_attr),
            "R": _Side(right_attr),
        }
        self.blooms: Dict[str, BloomFilter] = {"L": bloom_left, "R": bloom_right}
        # L_temp: base tids of the *smaller* deferred side (paper Case 3)
        self.flag: Optional[str] = None
        self.l_temp: set = set()

    # ------------------------------------------------------------------ #
    def attr_side(self, attr: str) -> Optional[str]:
        for s, side in self.sides.items():
            if side.attr == attr:
                return s
        return None

    def other(self, s: str) -> str:
        return "R" if s == "L" else "L"

    def set_snapshot(self, s: str, rel: MaskedRelation) -> None:
        self.append_snapshot(s, rel)

    def append_snapshot(self, s: str, rel: MaskedRelation) -> None:
        side = self.sides[s]
        new_deferred = np.array(rel.is_missing(side.attr))
        if side.snapshot is None:
            side.snapshot = rel.copy()
            side.alive = np.ones(side.snapshot.num_rows, dtype=bool)
            side.deferred_mask = new_deferred
        else:
            side.snapshot = concat_relations([side.snapshot, rel.copy()])
            side.alive = np.concatenate(
                [side.alive, np.ones(rel.num_rows, dtype=bool)]
            )
            side.deferred_mask = np.concatenate(
                [side.deferred_mask, new_deferred]
            )

    def record_deferred(self, s: str, tids: np.ndarray) -> None:
        side = self.sides[s]
        prev = side.deferred_tids
        side.deferred_tids = (
            np.asarray(tids, dtype=np.int64)
            if prev is None
            else np.concatenate([prev, np.asarray(tids, dtype=np.int64)])
        )

    def finalize_deferred(self) -> None:
        """Once both operands are consumed: pick Flag = smaller deferred side
        and store its base tids (L_temp), per paper Case 3."""
        nl = len(self.sides["L"].deferred_tids) if self.sides["L"].deferred_tids is not None else 0
        nr = len(self.sides["R"].deferred_tids) if self.sides["R"].deferred_tids is not None else 0
        if nl == 0 and nr == 0:
            return
        self.flag = "L" if nl <= nr else "R"
        t = self.sides[self.flag].deferred_tids
        self.l_temp = set(t.tolist()) if t is not None else set()

    # ------------------------------------------------------------------ #
    # snapshot writeback of imputed key values (+ verify-failure kills)
    # ------------------------------------------------------------------ #
    def writeback(self, attr: str, tids: np.ndarray, values: np.ndarray,
                  passed: np.ndarray) -> None:
        s = self.attr_side(attr)
        if s is None:
            return
        side = self.sides[s]
        if side.snapshot is None or side.snapshot.num_rows == 0:
            return
        snap_tids = side.snapshot.tids.get(side.table)
        if snap_tids is None:
            return
        # match snapshot rows carrying these base tids
        p_idx, s_idx = multi_match(
            snap_tids, np.asarray(tids, dtype=np.int64), impl=self.join_impl
        )
        if len(s_idx) == 0:
            return
        vals = np.asarray(values)[p_idx]
        ok = np.asarray(passed, dtype=bool)[p_idx]
        # only write rows where the key is actually still missing
        still = side.snapshot.is_missing(side.attr)[s_idx]
        side.snapshot.set_values(side.attr, s_idx[still], vals[still])
        dead = s_idx[~ok]
        side.alive[dead] = False

    # ------------------------------------------------------------------ #
    # BF_Join (Algorithm 2): resolve rows of `rel` (rows index array) whose
    # key on side `s` is now known against the OTHER side's snapshot.
    # Returns (expanded_relation_or_None, resolved_mask) where resolved rows
    # are removed by the caller and replaced by the expansion.
    #
    # Dedup (paper footnote 7, adapted): the paper removes L2⋈R2 duplicates
    # by excluding L_temp tids.  Deferred rows in our executor can resolve
    # *after* lower-join expansion (their tid combination is then absent
    # from the snapshots), so tid-set exclusion both over- and under-counts.
    # For left-deep plans the equivalent canonical rule is direction-based:
    # L-side resolvers match every alive partner row (deferred partners'
    # keys are written back); R-side resolvers skip partner rows that were
    # deferred at snapshot time — those are pool rows that produce the pair
    # themselves from the L side.
    # ------------------------------------------------------------------ #
    def bf_join(self, rel: MaskedRelation, rows: np.ndarray, s: str,
                counters=None, bloom_impl: Optional[str] = None
                ) -> Tuple[Optional[MaskedRelation], np.ndarray]:
        me = self.sides[s]
        other = self.sides[self.other(s)]
        bloom_other = self.blooms[self.other(s)]
        keys = rel.values(me.attr)[rows]

        # cheap pre-filter: bloom has no false negatives (paper §5.3)
        if bloom_other.complete and len(rows):
            hit = bloom_other.might_contain(keys, impl=bloom_impl)
            if counters is not None:
                counters.filtered_by_bloom += int((~hit).sum())
        else:
            hit = np.ones(len(rows), dtype=bool)

        snap = other.snapshot
        if snap is None or snap.num_rows == 0:
            return None, np.ones(len(rows), dtype=bool)  # nothing can match: all drop
        okeys = snap.values(other.attr)
        opresent = snap.is_present(other.attr) & other.alive
        if s == "R" and other.deferred_mask is not None:
            opresent &= ~other.deferred_mask  # canonical-direction dedup
        cand_rows = rows[hit]
        cand_keys = keys[hit]
        p_idx, b_idx = multi_match(
            np.where(opresent, okeys, np.int64(-(2**62))), cand_keys,
            impl=self.join_impl,
        )
        if counters is not None:
            counters.trigger_joins += len(cand_rows)

        resolved = np.ones(len(rows), dtype=bool)  # every row is consumed
        if len(b_idx) == 0:
            return None, resolved

        # expansion: own columns repeated × matched other-side columns
        own_cols = [c.name for c in rel.schema.columns if snap.has_column(c.name) is False]
        mine = rel.take(rows[hit][p_idx]).project(own_cols)
        theirs = snap.take(b_idx)
        joined = mine.hstack(theirs) if s == "L" else theirs.hstack(mine)
        # normalize column order to rel's schema
        joined = joined.project([c.name for c in rel.schema.columns])
        return joined, resolved
