"""Columnar relations with missing/NULL bitmasks — the TPU-native analogue of
QUIP's NULL-bit-extended schema (paper §5).

A :class:`MaskedRelation` is a struct-of-arrays: every column is a dense
``jnp`` array; two bitmask arrays per column distinguish the paper's two NULL
kinds:

* ``missing``  — a value that *exists* but is unknown (imputable; paper's
  "missing NULL", bit set).
* ``absent``   — a regular NULL introduced by outer-join padding (not
  imputable; paper's plain NULL, bit clear).

Rows additionally carry per-base-table provenance ids (``tids``) so join
triggers (paper Alg. 1–2) can deduplicate L2⋈R2 and re-join deferred rows.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.schema import ColumnSpec, Schema

__all__ = ["MaskedRelation", "concat_relations"]

_INT_FILL = np.int64(-(2**31))  # sentinel payload under a missing/absent bit
_FLT_FILL = np.float64(np.nan)


def _fill_for(dtype) -> np.generic:
    return _FLT_FILL if np.issubdtype(np.dtype(dtype), np.floating) else _INT_FILL


@dataclasses.dataclass
class MaskedRelation:
    """Columnar relation: ``cols[name] -> (n,)`` arrays plus mask planes."""

    schema: Schema
    cols: Dict[str, np.ndarray]
    missing: Dict[str, np.ndarray]  # bool, True => imputable missing value
    absent: Dict[str, np.ndarray]  # bool, True => regular NULL (join padding)
    tids: Dict[str, np.ndarray]  # base table -> row id (or -1 for padded rows)

    # ------------------------------------------------------------------ #
    # constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_columns(
        schema: Schema,
        cols: Mapping[str, np.ndarray],
        missing: Optional[Mapping[str, np.ndarray]] = None,
        base_table: Optional[str] = None,
    ) -> "MaskedRelation":
        n = len(next(iter(cols.values()))) if cols else 0
        out_cols, out_mis, out_abs = {}, {}, {}
        for spec in schema.columns:
            c = np.asarray(cols[spec.name], dtype=spec.np_dtype)
            m = (
                np.asarray(missing[spec.name], dtype=bool)
                if missing and spec.name in missing
                else np.zeros(n, dtype=bool)
            )
            out_cols[spec.name] = c
            out_mis[spec.name] = m
            out_abs[spec.name] = np.zeros(n, dtype=bool)
        tids = {base_table or schema.name: np.arange(n, dtype=np.int64)}
        return MaskedRelation(schema, out_cols, out_mis, out_abs, tids)

    # ------------------------------------------------------------------ #
    # basic properties
    # ------------------------------------------------------------------ #
    @property
    def num_rows(self) -> int:
        if not self.cols:
            return 0
        return len(next(iter(self.cols.values())))

    def column_names(self) -> List[str]:
        return [c.name for c in self.schema.columns]

    def has_column(self, name: str) -> bool:
        return name in self.cols

    def values(self, name: str) -> np.ndarray:
        return self.cols[name]

    def is_missing(self, name: str) -> np.ndarray:
        return self.missing[name]

    def is_absent(self, name: str) -> np.ndarray:
        return self.absent[name]

    def is_present(self, name: str) -> np.ndarray:
        """Value exists and is known (neither missing nor padded-NULL)."""
        return ~(self.missing[name] | self.absent[name])

    def missing_count(self, name: str) -> int:
        return int(self.missing[name].sum())

    # ------------------------------------------------------------------ #
    # row selection / mutation
    # ------------------------------------------------------------------ #
    def take(self, idx: np.ndarray) -> "MaskedRelation":
        idx = np.asarray(idx)
        return MaskedRelation(
            self.schema,
            {k: v[idx] for k, v in self.cols.items()},
            {k: v[idx] for k, v in self.missing.items()},
            {k: v[idx] for k, v in self.absent.items()},
            {k: v[idx] for k, v in self.tids.items()},
        )

    def filter(self, keep: np.ndarray) -> "MaskedRelation":
        keep = np.asarray(keep, dtype=bool)
        return self.take(np.nonzero(keep)[0])

    def set_values(self, name: str, rows: np.ndarray, values: np.ndarray) -> None:
        """Write imputed values in-place and clear the missing bit."""
        self.cols[name] = np.array(self.cols[name])
        self.missing[name] = np.array(self.missing[name])
        self.cols[name][rows] = np.asarray(values, dtype=self.cols[name].dtype)
        self.missing[name][rows] = False

    def copy(self) -> "MaskedRelation":
        return MaskedRelation(
            self.schema,
            {k: np.array(v) for k, v in self.cols.items()},
            {k: np.array(v) for k, v in self.missing.items()},
            {k: np.array(v) for k, v in self.absent.items()},
            {k: np.array(v) for k, v in self.tids.items()},
        )

    # ------------------------------------------------------------------ #
    # join-support
    # ------------------------------------------------------------------ #
    def pad_like(self, n: int) -> "MaskedRelation":
        """``n`` rows of this schema fully absent (outer-join padding)."""
        cols, mis, ab = {}, {}, {}
        for spec in self.schema.columns:
            cols[spec.name] = np.full(n, _fill_for(spec.np_dtype), dtype=spec.np_dtype)
            mis[spec.name] = np.zeros(n, dtype=bool)
            ab[spec.name] = np.ones(n, dtype=bool)
        tids = {k: np.full(n, -1, dtype=np.int64) for k in self.tids}
        return MaskedRelation(self.schema, cols, mis, ab, tids)

    def hstack(self, other: "MaskedRelation") -> "MaskedRelation":
        """Concatenate columns of two equal-length relations (join output)."""
        assert self.num_rows == other.num_rows, (self.num_rows, other.num_rows)
        schema = Schema(
            f"({self.schema.name}*{other.schema.name})",
            list(self.schema.columns) + list(other.schema.columns),
        )
        cols = {**self.cols, **other.cols}
        mis = {**self.missing, **other.missing}
        ab = {**self.absent, **other.absent}
        tids = dict(self.tids)
        for k, v in other.tids.items():
            if k in tids:
                # merge provenance: prefer valid (>= 0) ids from either side
                tids[k] = np.where(tids[k] >= 0, tids[k], v)
            else:
                tids[k] = v
        return MaskedRelation(schema, cols, mis, ab, tids)

    def project(self, names: Iterable[str]) -> "MaskedRelation":
        names = list(names)
        specs = [self.schema.column(n) for n in names]
        return MaskedRelation(
            Schema(self.schema.name, specs),
            {n: self.cols[n] for n in names},
            {n: self.missing[n] for n in names},
            {n: self.absent[n] for n in names},
            dict(self.tids),
        )

    # ------------------------------------------------------------------ #
    # answer-set comparison (tests / SMAPE experiments)
    # ------------------------------------------------------------------ #
    def to_sorted_tuples(self, names: Optional[List[str]] = None) -> List[tuple]:
        names = names or self.column_names()
        rows = []
        for i in range(self.num_rows):
            row = []
            for n in names:
                if self.absent[n][i] or self.missing[n][i]:
                    row.append(None)
                else:
                    v = self.cols[n][i]
                    row.append(float(v) if np.issubdtype(v.dtype, np.floating) else int(v))
            rows.append(tuple(row))
        return sorted(rows, key=lambda r: tuple((x is None, x) for x in r))

    def device_column(self, name: str) -> jnp.ndarray:
        """Column as a JAX array (for jit'd vectorized stages)."""
        return jnp.asarray(self.cols[name])


def concat_relations(rels: List[MaskedRelation]) -> MaskedRelation:
    rels = [r for r in rels if r is not None and r.num_rows >= 0]
    assert rels
    base = rels[0]
    if len(rels) == 1:
        return base
    cols = {k: np.concatenate([r.cols[k] for r in rels]) for k in base.cols}
    mis = {k: np.concatenate([r.missing[k] for r in rels]) for k in base.missing}
    ab = {k: np.concatenate([r.absent[k] for r in rels]) for k in base.absent}
    tid_keys = set()
    for r in rels:
        tid_keys |= set(r.tids)
    tids = {}
    for k in tid_keys:
        parts = [
            r.tids.get(k, np.full(r.num_rows, -1, dtype=np.int64)) for r in rels
        ]
        tids[k] = np.concatenate(parts)
    return MaskedRelation(base.schema, cols, mis, ab, tids)
