"""QuipService in miniature: a skewed multi-tenant stream served with plan
caching and cross-query imputation sharing, vs cold-engine serial replay.

    PYTHONPATH=src python examples/quip_serve_demo.py
"""
from repro.core.executor import execute_quip
from repro.data.queries import serving_workload
from repro.data.synthetic import wifi_dataset
from repro.imputers import ImputationEngine, KnnImputer
from repro.service import QuipService


def main():
    tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
    stream = list(serving_workload("wifi", tables, n_queries=12,
                                   n_templates=4, n_tenants=3, seed=2))
    factory = lambda: KnnImputer(k=5, cost_per_value=2e-3)

    # cold-engine serial replay: what every query costs without the service
    serial_imps = serial_batches = 0
    for _tenant, q in stream:
        eng = ImputationEngine(
            {t: r.copy() for t, r in tables.items()}, default=factory
        )
        res = execute_quip(q, tables, eng, strategy="adaptive")
        serial_imps += res.counters.imputations
        serial_batches += res.counters.impute_batches

    svc = QuipService(tables, factory, max_inflight=4, shared_impute=True)
    tickets = [svc.submit(q, tenant=tenant) for tenant, q in stream]
    svc.run_until_idle()

    print(f"{'ticket':>6} {'tenant':>6} {'plan':>5} {'wait ms':>8} "
          f"{'latency ms':>10} {'imputed':>8} {'cross-hits':>10}")
    for ticket in tickets:
        rec = next(r for r in svc.serving.records if r.ticket == ticket)
        print(f"{rec.ticket:>6} {rec.tenant:>6} "
              f"{'hit' if rec.plan_cache_hit else 'miss':>5} "
              f"{rec.queue_wait_s * 1e3:>8.2f} {rec.latency_s * 1e3:>10.2f} "
              f"{rec.counters.imputations:>8} "
              f"{rec.counters.impute_cross_hits:>10}")

    s = svc.summary()
    print(f"\nplan cache: {s['plan_cache_hits']} hits / "
          f"{s['plan_cache_misses']} misses (size {s['plan_cache_size']})")
    print(f"latency: p50 {s['p50_latency_s'] * 1e3:.1f} ms, "
          f"p95 {s['p95_latency_s'] * 1e3:.1f} ms; "
          f"peak concurrency {s['max_concurrent']}")
    print(f"imputer invocations: {s['impute_batches']} "
          f"(serial replay paid {serial_batches}); "
          f"values computed: {s['imputations']} vs {serial_imps} serial — "
          f"{serial_imps - s['imputations']} served from the shared store")


if __name__ == "__main__":
    main()
