"""QUIP on the synthetic UCI-WiFi workload: per-strategy imputation counts
and runtimes with an expensive (KNN) imputer — paper Experiment 1 in
miniature.

    PYTHONPATH=src python examples/quip_sql_demo.py
"""
from repro.data.queries import workload
from repro.data.synthetic import wifi_dataset
from repro.imputers import ImputationEngine, KnnImputer
from repro.core.executor import execute_offline, execute_quip


def main():
    tables, _ = wifi_dataset(n_users=200, n_wifi=4000, n_occ=2000)
    queries = workload("wifi", tables, kind="low", n_queries=4, seed=1)
    factory = lambda: KnnImputer(k=5, cost_per_value=2e-3)
    for strategy in ("offline", "imputedb", "lazy", "adaptive"):
        imps = wall = 0
        for q in queries:
            eng = ImputationEngine(
                {t: r.copy() for t, r in tables.items()}, default=factory
            )
            if strategy == "offline":
                res = execute_offline(q, tables, eng)
            else:
                res = execute_quip(q, tables, eng, strategy=strategy)
            imps += res.counters.imputations
            wall += res.counters.wall_seconds
        print(f"{strategy:>9}: imputations={imps:6d} runtime={wall*1e3:8.1f} ms")


if __name__ == "__main__":
    main()
