"""End-to-end driver: train a ~100M-param qwen2.5-family model on batches
materialized through the QUIP cleaning stage, with checkpoint/restart fault
tolerance (one injected failure).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_arch
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M-param member of the qwen2.5 family (12 layers, d=768)
    cfg = dataclasses.replace(
        get_arch("qwen2.5-3b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=32768, dtype="float32",
    )
    n = cfg.num_params()
    print(f"training {n/1e6:.0f}M-param model for {args.steps} steps "
          f"on QUIP-cleaned data (1 injected failure at step 60)")
    with tempfile.TemporaryDirectory() as ckpt:
        out = train_loop(cfg, args.steps, args.batch, args.seq,
                         ckpt_dir=ckpt, fail_at=(60,))
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}; "
          f"restarts={out['restarts']}; {out['seconds']:.0f}s")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
