"""Batched serving example: greedy decode with KV/SSM caches on a reduced
mamba2 (O(1)-state decode) and a reduced GQA transformer.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import get_arch
from repro.launch.serve import serve_batch


def main():
    for arch in ("mamba2-370m", "qwen2.5-3b"):
        cfg = get_arch(arch).reduced()
        out = serve_batch(cfg, batch=4, prompt_len=32, gen=16)
        print(f"{arch:>14}: generated {out['tokens'].shape}, "
              f"{out['tok_per_s']:.0f} tok/s (reduced config, CPU)")


if __name__ == "__main__":
    main()
