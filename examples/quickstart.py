"""Quickstart: the paper's motivating example (Tables 1-3, Figure-1 query)
through QUIP — lazy vs adaptive vs ImputeDB-style eager vs offline.

    PYTHONPATH=src:tests python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from paper_example import paper_tables, paper_query, oracle_engine
from repro.core.executor import execute_quip, execute_offline, make_plan
from repro.core.plan import plan_string


def main():
    tables = paper_tables()
    query = paper_query()
    print("Query plan (ImputeDB-style external optimizer):")
    print(plan_string(make_plan(query, tables)))
    for strategy in ("lazy", "adaptive", "imputedb"):
        eng = oracle_engine({t: tables[t].copy() for t in tables})
        res = execute_quip(query, tables, eng, strategy=strategy)
        print(f"{strategy:>9}: answer={res.answer_tuples()} "
              f"imputations={res.counters.imputations} "
              f"temp_tuples={res.counters.temp_tuples}")
    eng = oracle_engine({t: tables[t].copy() for t in tables})
    res = execute_offline(query, tables, eng)
    print(f"{'offline':>9}: answer={res.answer_tuples()} "
          f"imputations={res.counters.imputations}")


if __name__ == "__main__":
    main()
