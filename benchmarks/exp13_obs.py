"""Experiment 13: observability — tracing changes nothing, and costs
nothing when off.

Runs the exp8-style skewed multi-tenant serving stream twice over the
same tables: once plain (tracer off — the default), once with the unit-
clock tracer **and** explain provenance on.  Acceptance invariants, all
deterministic (wall clock is recorded, never asserted — CI runners flake):

* **bit-identical execution** — per-ticket answers, total imputations and
  scheduler morsel steps are equal between the two runs (tracing is
  observation, not participation);
* **explain reconciles** — every ticket's provenance report totals equal
  its recorded ``ExecutionCounters.imputations`` exactly;
* **zero-overhead off mode** — a service without ``QUIP_TRACE`` holds the
  shared :data:`NULL_TRACER`, whose ``span()`` returns the shared
  :data:`NULL_SPAN` singleton and which records nothing;
* **bounded on-mode footprint** — spans recorded per unit of Python work
  (temp tuples + imputations + morsel steps) stay under 5%, so tracing
  cannot silently become a second execution engine;
* **valid exports** — the Chrome trace-event JSON and the Prometheus
  exposition pass schema validation, and both land in
  ``benchmarks/artifacts/`` (uploaded by the CI smoke step).
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES
from repro.data.queries import serving_workload
from repro.data.synthetic import wifi_dataset
from repro.obs import NULL_SPAN, NULL_TRACER, Tracer
from repro.service import QuipService

NAME = "exp13_obs"

STRATEGY = "adaptive"
MORSEL_ROWS = 4096
IMPUTER = "knn"
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# deterministic on-mode footprint gate: recorded spans per Python-work
# unit (temp tuples + imputations + morsel steps — counters that are
# bit-identical run-to-run, unlike wall time)
MAX_SPANS_PER_WORK_UNIT = 0.05


def _run_stream(stream, tables, *, tracer=None, explain=None) -> Dict:
    svc = QuipService(
        tables, IMPUTER_FACTORIES[IMPUTER], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, shared_impute=False, max_inflight=4,
        cost_model="unit", tracer=tracer, explain=explain,
    )
    t0 = time.perf_counter()
    tickets = [svc.submit(q, tenant=tenant) for tenant, q in stream]
    svc.run_until_idle()
    wall = time.perf_counter() - t0
    answers = [sorted(svc.answers(t)) for t in tickets]
    total = svc.serving.total_counters()
    summary = svc.summary()
    return {
        "svc": svc, "tickets": tickets, "answers": answers,
        "wall_s": round(wall, 4),
        "imputations": total.imputations,
        "morsel_steps": summary["morsel_steps"],
        "work_units": (total.temp_tuples + total.imputations
                       + summary["morsel_steps"]),
    }


# --------------------------------------------------------------------------- #
# export-format validators (schema only — no golden values)
# --------------------------------------------------------------------------- #
def _validate_chrome_trace(doc: Dict) -> int:
    assert set(doc) >= {"traceEvents", "metadata"}, sorted(doc)
    assert doc["metadata"]["clock"] == "unit"
    json.dumps(doc)  # must round-trip as-is
    events = doc["traceEvents"]
    assert events, "empty trace"
    for ev in events:
        assert ev["ph"] in ("X", "i", "M"), ev
        assert isinstance(ev["name"], str) and "pid" in ev and "tid" in ev
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and "ts" in ev
        elif ev["ph"] == "i":
            assert ev["s"] == "t"
    return sum(1 for ev in events if ev["ph"] != "M")


def _validate_prometheus(text: str) -> int:
    types: Dict[str, str] = {}
    helped = set()
    assert text.endswith("\n")
    for line in text.strip().splitlines():
        assert line, "blank line inside exposition"
        if line.startswith("# HELP "):
            helped.add(line.split()[2])
            continue
        if line.startswith("# TYPE "):
            _h, _t, name, kind = line.split()
            assert name in helped, f"# TYPE before # HELP for {name}"
            assert kind in ("counter", "gauge", "histogram"), kind
            types[name] = kind
            continue
        name = line.split()[0].split("{")[0]
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in types:
                base = name[: -len(suffix)]
        assert base in types, f"sample {name} has no # TYPE"
        float(line.rsplit(" ", 1)[1].replace("+Inf", "inf"))
    assert any(k == "histogram" for k in types.values())
    return len(types)


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
        n_queries = 20
    else:
        tables, _ = wifi_dataset()
        n_queries = 40
    stream = list(serving_workload("wifi", tables, n_queries=n_queries,
                                   n_templates=6, n_tenants=4, seed=5))

    plain = _run_stream(stream, tables)
    tracer = Tracer(enabled=True, clock="unit")
    traced = _run_stream(stream, tables, tracer=tracer, explain=True)

    # -- zero-overhead off mode: structural no-op contract ----------------- #
    svc_plain = plain.pop("svc")
    assert svc_plain.tracer is NULL_TRACER, "untraced service built a tracer"
    assert svc_plain.tracer.span("probe") is NULL_SPAN
    assert svc_plain.tracer.spans() == [], "disabled tracer recorded spans"
    assert not svc_plain.explain_enabled

    # -- explain reconciliation across every ticket ------------------------ #
    svc = traced.pop("svc")
    reconciled = 0
    for record in svc.serving.records:
        report = svc.explain(record.ticket)
        assert report["totals"]["imputed_cells"] \
            == record.counters.imputations, (
                record.ticket, report["totals"], record.counters.imputations)
        reconciled += 1

    # -- artifacts + schema validation ------------------------------------- #
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    trace_path = os.path.join(ARTIFACT_DIR, "exp13_trace.json")
    doc = svc.export_trace(trace_path)
    n_events = _validate_chrome_trace(json.loads(open(trace_path).read()))
    prom_path = os.path.join(ARTIFACT_DIR, "exp13_metrics.prom")
    prom = svc.metrics(fmt="prometheus")
    with open(prom_path, "w") as fh:
        fh.write(prom)
    n_metrics = _validate_prometheus(prom)

    spans_recorded = len(tracer.spans())
    plain.pop("tickets"), traced.pop("tickets")
    base_answers = plain.pop("answers")
    rows = [
        dict(mode="plain", queries=len(stream), **plain),
        dict(mode="traced", queries=len(stream),
             answers_match_plain=int(traced.pop("answers") == base_answers),
             spans_recorded=spans_recorded,
             trace_events=n_events,
             chrome_events_total=len(doc["traceEvents"]),
             prometheus_metrics=n_metrics,
             explains_reconciled=reconciled,
             **traced),
    ]
    svc.close()
    svc_plain.close()
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    plain, traced = by_mode["plain"], by_mode["traced"]
    # acceptance invariants — all deterministic (wall recorded, not asserted)
    assert traced["answers_match_plain"] == 1, "tracing changed the answers"
    assert traced["imputations"] == plain["imputations"], \
        "tracing changed the imputation total"
    assert traced["morsel_steps"] == plain["morsel_steps"], \
        "tracing changed the scheduling"
    assert traced["explains_reconciled"] == traced["queries"], \
        "a ticket's explain report is missing"
    assert traced["spans_recorded"] > 0 and traced["prometheus_metrics"] > 0
    ratio = traced["spans_recorded"] / max(traced["work_units"], 1)
    assert ratio <= MAX_SPANS_PER_WORK_UNIT, (
        f"tracing footprint {ratio:.4f} spans/work-unit exceeds "
        f"{MAX_SPANS_PER_WORK_UNIT}"
    )
    return {
        "answers_match": float(traced["answers_match_plain"]),
        "explains_reconciled": traced["explains_reconciled"],
        "obs_span_count": traced["spans_recorded"],
        "obs_overhead_ratio": round(ratio, 5),
        "prometheus_metrics": traced["prometheus_metrics"],
        "trace_events": traced["trace_events"],
        "traced_wall_overhead": round(
            traced["wall_s"] / max(plain["wall_s"], 1e-9) - 1.0, 3
        ),
    }
