"""Experiment 2 (paper §7.5): answer quality (SMAPE).

QUIP trains the (blocking) imputer on the full base tables and verifies
imputed values ⇒ identical answers to the impute-everything-first baseline
(SMAPE 0).  ImputeDB trains the imputation model only on the subset of data
that reaches its imputation operator ⇒ slightly different imputations ⇒
SMAPE 0–4%."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.executor import execute_offline, execute_quip
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, wifi_dataset
from repro.imputers import ImputationEngine, KnnImputer

NAME = "exp2_quality"


def _smape(a: List[tuple], b: List[tuple]) -> float:
    """Tuple-wise symmetric mean absolute percentage error over aggregate
    answers (paper's metric)."""
    vals_a = [x for row in a for x in row if x is not None]
    vals_b = [x for row in b for x in row if x is not None]
    n = min(len(vals_a), len(vals_b))
    if n == 0:
        return 0.0
    va, vb = np.asarray(vals_a[:n], float), np.asarray(vals_b[:n], float)
    denom = (np.abs(va) + np.abs(vb)) / 2
    ok = denom > 1e-12
    if not ok.any():
        return 0.0
    return float(np.mean(np.abs(va - vb)[ok] / denom[ok]) * 100)


class SubsetKnn(KnnImputer):
    """KNN whose neighbour reference is a row subsample — the model an
    eager plan-embedded imputation operator would learn from the subset of
    data flowing through it (ImputeDB behaviour).  Query-row features still
    come from the full table (standard KNNImputer semantics)."""

    def __init__(self, frac: float = 0.55, seed: int = 0, **kw):
        super().__init__(**kw)
        self.frac = frac
        self.seed = seed
        self._sub = None

    def fit(self, table):
        super().fit(table)  # full-table features for query rows
        rng = np.random.default_rng(self.seed)
        keep = rng.random(table.num_rows) < self.frac
        if int(keep.sum()) > 10:
            sub = KnnImputer(k=self.k)
            sub.fit(table.filter(keep))
            self._sub = (sub, np.nonzero(keep)[0])

    def impute_attr(self, table, attr, tids):
        if self._sub is None:
            return super().impute_attr(table, attr, tids)
        sub, sub_rows = self._sub
        # swap the neighbour reference matrix to the subsample's
        saved = (self._feat, self._mask)
        full_feat, full_mask = saved
        self._feat = np.concatenate(
            [full_feat[tids], sub._feat], axis=0
        )
        self._mask = np.concatenate(
            [full_mask[tids], sub._mask], axis=0
        )
        try:
            # query rows are the first len(tids); reference excludes them by
            # construction of KnnImputer (neighbours must observe attr and
            # the query rows have it missing).
            out = super().impute_attr(
                _SubView(table, sub_rows, tids), attr,
                np.arange(len(tids)),
            )
        finally:
            self._feat, self._mask = saved
        return out


class _SubView:
    """Table view whose rows = [query tids rows..., subsample rows...]."""

    def __init__(self, table, sub_rows, tids):
        self._t = table
        self._idx = np.concatenate([np.asarray(tids), np.asarray(sub_rows)])
        self.cols = {k: v[self._idx] for k, v in table.cols.items()}

    def values(self, name):
        return self._t.values(name)[self._idx]

    def is_present(self, name):
        return self._t.is_present(name)[self._idx]


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    nq = 5 if fast else 20
    for ds, (tables, _clean) in (("cdc", cdc_dataset()),
                                 ("wifi", wifi_dataset())):
        queries = workload(ds, tables, kind="random", n_queries=nq, seed=11)
        for q_i, q in enumerate(queries):
            if q.aggregate is None:
                continue
            # ground truth: impute everything with the full-table model
            eng = ImputationEngine(
                {t: r.copy() for t, r in tables.items()},
                default=lambda: KnnImputer(k=5),
            )
            truth = execute_offline(q, tables, eng).answer_tuples()

            eng_q = ImputationEngine(
                {t: r.copy() for t, r in tables.items()},
                default=lambda: KnnImputer(k=5),
            )
            quip = execute_quip(q, tables, eng_q,
                                strategy="adaptive").answer_tuples()

            eng_i = ImputationEngine(
                {t: r.copy() for t, r in tables.items()},
                default=lambda: SubsetKnn(frac=0.8, k=5),
            )
            imputedb = execute_quip(q, tables, eng_i,
                                    strategy="imputedb").answer_tuples()
            rows.append({
                "dataset": ds, "query": q_i,
                "smape_quip": round(_smape(quip, truth), 4),
                "smape_imputedb": round(_smape(imputedb, truth), 4),
            })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    quip = [r["smape_quip"] for r in rows]
    idb = [r["smape_imputedb"] for r in rows]
    return {
        "max_smape_quip_pct": round(max(quip, default=0.0), 4),
        "max_smape_imputedb_pct": round(max(idb, default=0.0), 4),
    }
