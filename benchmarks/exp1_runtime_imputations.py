"""Experiment 1 (paper Figs. 9–10): runtime & #imputations for Offline /
ImputeDB(eager) / QUIP-lazy / QUIP-adaptive, per imputer, on the WiFi and
CDC data sets (random workload)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import run_workload
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, wifi_dataset

NAME = "exp1_runtime_imputations"


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    nq = 6 if fast else 20
    datasets = {
        "wifi": wifi_dataset()[0],
        "cdc": cdc_dataset()[0],
    }
    imputers = {"wifi": ["mean", "knn", "locater", "xgboost"],
                "cdc": ["mean", "knn", "xgboost"]}
    for ds, tables in datasets.items():
        queries = workload(ds, tables, kind="random", n_queries=nq, seed=7)
        for imp in imputers[ds]:
            res = run_workload(tables, queries, imp,
                               strategies=("offline", "imputedb", "lazy", "adaptive"))
            for strat, r in res.items():
                rows.append({
                    "dataset": ds, "imputer": imp, "strategy": strat,
                    "imputations": r.imputations,
                    "impute_batches": r.impute_batches,
                    "runtime_s": round(r.wall_seconds, 4),
                    "temp_tuples": r.temp_tuples,
                })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    """Paper claims: QUIP ≤ a few % of ImputeDB's imputations on expensive
    imputers; 2–10× runtime win; ≫ offline."""
    out = {}
    for ds in ("wifi", "cdc"):
        for imp in ("knn", "locater"):
            sub = {r["strategy"]: r for r in rows
                   if r["dataset"] == ds and r["imputer"] == imp}
            if not sub or "adaptive" not in sub:
                continue
            eager = max(sub["imputedb"]["imputations"], 1)
            off = max(sub["offline"]["imputations"], 1)
            ad = sub["adaptive"]
            out[f"{ds}/{imp}/imp_vs_eager"] = round(
                ad["imputations"] / eager, 4
            )
            out[f"{ds}/{imp}/imp_vs_offline"] = round(
                ad["imputations"] / off, 4
            )
            out[f"{ds}/{imp}/speedup_vs_eager"] = round(
                sub["imputedb"]["runtime_s"] / max(ad["runtime_s"], 1e-9), 2
            )
            out[f"{ds}/{imp}/speedup_vs_offline"] = round(
                sub["offline"]["runtime_s"] / max(ad["runtime_s"], 1e-9), 2
            )
            # batched-service trajectory: values per imputer invocation
            out[f"{ds}/{imp}/values_per_batch_adaptive"] = round(
                ad["imputations"] / max(ad["impute_batches"], 1), 2
            )
    return out
