"""Experiment 8: the QuipService serving layer on a skewed multi-tenant
stream — throughput, tail latency, and what cross-query sharing saves.

Three configurations over the same 20-query overlapping workload:

* ``serial``         — cold-engine replay, one query at a time (the pre-PR3
  world: every query re-plans and re-imputes from scratch);
* ``service``        — QuipService, morsel-interleaved, plan cache on,
  per-query imputation isolation (the safe default);
* ``service_shared`` — QuipService with ``QUIP_SHARED_IMPUTE`` semantics:
  one ImputeStore across all queries.

The acceptance invariant is asserted here and recorded in the derived
metrics: shared-store answers are bit-identical to serial replay while
total imputer invocations drop strictly and the plan cache hits > 0.
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES
from repro.core.executor import execute_quip
from repro.core.stats import nearest_rank_quantile
from repro.data.queries import serving_workload
from repro.data.synthetic import wifi_dataset
from repro.imputers.base import ImputationService
from repro.service import QuipService

NAME = "exp8_serving"

STRATEGY = "adaptive"
MORSEL_ROWS = 4096


def _serial(stream, tables, imputer) -> Dict:
    answers, latencies = [], []
    imps = batches = 0
    t0 = time.perf_counter()
    for _tenant, q in stream:
        # per-query latency spans engine construction (table copies),
        # planning and execution — the same span a session's latency_s
        # covers (setup happens at admission, inside the session clock)
        t1 = time.perf_counter()
        eng = ImputationService(
            {t: tables[t].copy() for t in q.tables},
            default=IMPUTER_FACTORIES[imputer],
        )
        res = execute_quip(q, tables, eng, strategy=STRATEGY,
                           morsel_rows=MORSEL_ROWS)
        latencies.append(time.perf_counter() - t1)
        answers.append(sorted(res.answer_tuples()))
        imps += res.counters.imputations
        batches += res.counters.impute_batches
    wall = time.perf_counter() - t0
    return {
        "mode": "serial", "queries": len(stream),
        "wall_s": round(wall, 4), "qps": round(len(stream) / wall, 2),
        "p50_ms": round(nearest_rank_quantile(latencies, 0.5) * 1e3, 3),
        "p95_ms": round(nearest_rank_quantile(latencies, 0.95) * 1e3, 3),
        "imputations": imps, "impute_batches": batches,
        "plan_cache_hits": 0, "impute_cross_hits": 0,
        "_answers": answers,
    }


def _served(stream, tables, imputer, shared: bool) -> Dict:
    svc = QuipService(
        tables, IMPUTER_FACTORIES[imputer], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, shared_impute=shared, max_inflight=4,
    )
    t0 = time.perf_counter()
    tickets = [svc.submit(q, tenant=tenant) for tenant, q in stream]
    svc.run_until_idle()
    wall = time.perf_counter() - t0
    answers = [sorted(svc.answers(t)) for t in tickets]
    summary = svc.summary()
    return {
        "mode": "service_shared" if shared else "service",
        "queries": len(stream),
        "wall_s": round(wall, 4), "qps": round(len(stream) / wall, 2),
        "p50_ms": round(summary["p50_latency_s"] * 1e3, 3),
        "p95_ms": round(summary["p95_latency_s"] * 1e3, 3),
        "imputations": summary["imputations"],
        "impute_batches": summary["impute_batches"],
        "plan_cache_hits": summary["plan_cache_hits"],
        "impute_cross_hits": summary["impute_cross_hits"],
        "queue_wait_s": summary["queue_wait_s"],
        "max_concurrent": summary["max_concurrent"],
        "_answers": answers,
    }


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
        n_queries = 20
    else:
        tables, _ = wifi_dataset()
        n_queries = 40
    stream = list(serving_workload("wifi", tables, n_queries=n_queries,
                                   n_templates=6, n_tenants=4, seed=5))
    imputer = "knn"
    rows = [
        _serial(stream, tables, imputer),
        _served(stream, tables, imputer, shared=False),
        _served(stream, tables, imputer, shared=True),
    ]
    serial_answers = rows[0].pop("_answers")
    for r in rows[1:]:
        r["answers_match_serial"] = int(r.pop("_answers") == serial_answers)
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    serial = by_mode["serial"]
    svc = by_mode["service"]
    shared = by_mode["service_shared"]
    saved_batches = serial["impute_batches"] - shared["impute_batches"]
    saved_values = serial["imputations"] - shared["imputations"]
    # acceptance invariants (CI runs this experiment as a smoke check):
    # identical answers, a strict invocation drop, and plan-cache hits
    assert svc["answers_match_serial"] == 1, "service answers diverged"
    assert shared["answers_match_serial"] == 1, "shared-store answers diverged"
    assert saved_batches > 0, "shared store saved no imputer invocations"
    assert shared["plan_cache_hits"] > 0, "no plan-cache hits on skewed stream"
    return {
        "serving_qps": shared["qps"],
        "serving_p50_ms": shared["p50_ms"],
        "serving_p95_ms": shared["p95_ms"],
        "serving_plan_cache_hits": shared["plan_cache_hits"],
        "serving_invocations_saved": saved_batches,
        "serving_values_saved": saved_values,
        "serving_invocations_saved_frac": round(
            saved_batches / max(serial["impute_batches"], 1), 4
        ),
        "serving_cross_hits": shared["impute_cross_hits"],
        "serving_answers_match": float(
            svc["answers_match_serial"] and shared["answers_match_serial"]
        ),
        "serving_speedup_vs_serial": round(
            serial["wall_s"] / max(shared["wall_s"], 1e-9), 2
        ),
    }
