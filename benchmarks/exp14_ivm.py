"""Experiment 14: delta-driven result-cache maintenance (QUIP_IVM).

A mutation-heavy repeat workload crafted so patching is *possible*: the
mutated table (``R0``) is fully present, while all missing values live on
the join partner (``R1``), which is never mutated.  Every cached answer
then depends on ``R0`` only through its stored values — the
imputation-interaction fallback cannot fire — so the IVM maintainer can
patch count/sum/avg aggregates and select/project answers in place
instead of evicting them.

The identical event stream (repeat-heavy query templates from a skewed
draw, interleaved with update/delete/insert commits on ``R0``) is
replayed against two services — ``ivm=False`` (evict-on-mutation, the
pre-IVM behaviour) and ``ivm=True`` — plus a cold replay oracle per
query.  Acceptance (asserted in ``derived``; CI runs this module as a
smoke check):

* ``results_patched > 0`` for the IVM service — maintenance actually ran;
* zero stale answers: every IVM-on answer is bit-identical to a cold
  execution over the post-mutation tables (and to the IVM-off service);
* hit-rate gain: the IVM service serves strictly more result-cache hits
  than the evicting service on the same stream — the point of patching.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import IMPUTER_FACTORIES
from repro.core.executor import execute_quip
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import ImputationService
from repro.service import QuipService, TableRegistry

NAME = "exp14_ivm"

STRATEGY = "adaptive"
MORSEL_ROWS = 4096
IMPUTER = "mean"
KEY_CARD = 8
VAL_CARD = 32


def _instance(rows: int, missing_rate: float, seed: int
              ) -> Dict[str, MaskedRelation]:
    """R0 ⋈ R1 on ``k``; missing cells only on ``R1.v`` (never mutated)."""
    rng = np.random.default_rng(seed)
    tables: Dict[str, MaskedRelation] = {}
    for name in ("R0", "R1"):
        schema = Schema(name, [ColumnSpec(f"{name}.k", "int"),
                               ColumnSpec(f"{name}.v", "int")])
        cols = {
            f"{name}.k": rng.integers(0, KEY_CARD, size=rows,
                                      dtype=np.int64),
            f"{name}.v": rng.integers(0, VAL_CARD, size=rows,
                                      dtype=np.int64),
        }
        missing = None
        if name == "R1":
            mask = rng.random(rows) < missing_rate
            missing = {f"{name}.v": mask}
        tables[name] = MaskedRelation.from_columns(
            schema, cols, missing=missing, base_table=name
        )
    return tables


def _templates() -> List[Query]:
    join = (JoinPredicate("R0.k", "R1.k"),)
    return [
        # single-table select/project on the mutated side (tuple patches)
        Query(("R0",), (SelectionPredicate("R0.v", "<=", 12),), (),
              ("R0.v",)),
        Query(("R0",), (SelectionPredicate("R0.v", ">", 20),), (),
              ("R0.k", "R0.v")),
        # join aggregates over the imputed side (agg-sidecar patches)
        Query(("R0", "R1"), (SelectionPredicate("R0.v", "<=", 16),), join,
              (), aggregate=Aggregate("count", None)),
        Query(("R0", "R1"), (), join, (),
              aggregate=Aggregate("sum", "R1.v")),
        Query(("R0", "R1"), (SelectionPredicate("R0.v", ">", 8),), join,
              (), aggregate=Aggregate("avg", "R1.v", group_by="R1.k")),
        Query(("R0", "R1"), (), join, (),
              aggregate=Aggregate("count", "R1.v", group_by="R0.k")),
    ]


def _events(n_queries: int, mutate_every: int, rows: int, seed: int
            ) -> List[Tuple]:
    """One deterministic stream applied to every service: skewed repeats
    over the templates, a mutation commit on R0 every ``mutate_every``
    queries (update- heavy, some deletes and inserts)."""
    rng = np.random.default_rng(seed)
    templates = _templates()
    weights = np.array([2.0 ** -i for i in range(len(templates))])
    weights /= weights.sum()
    out: List[Tuple] = []
    n_rows = rows  # track R0's row count without a registry
    for i in range(n_queries):
        out.append(("query", templates[int(rng.choice(len(templates),
                                                      p=weights))]))
        if (i + 1) % mutate_every:
            continue
        r = rng.random()
        if r < 0.6:
            k = int(rng.integers(2, 6))
            ids = rng.choice(n_rows, size=k, replace=False).astype(np.int64)
            vals = rng.integers(0, VAL_CARD, size=k).astype(np.int64)
            out.append(("mutate", "update", ids, {"R0.v": vals}))
        elif r < 0.8:
            k = int(rng.integers(1, 4))
            ids = rng.choice(n_rows, size=k, replace=False).astype(np.int64)
            out.append(("mutate", "delete", ids, None))
            n_rows -= k
        else:
            k = int(rng.integers(1, 4))
            values = {
                "R0.k": rng.integers(0, KEY_CARD, size=k, dtype=np.int64),
                "R0.v": rng.integers(0, VAL_CARD, size=k, dtype=np.int64),
            }
            out.append(("mutate", "insert", None, values))
            n_rows += k
    return out


def _cold_answers(query: Query, registry: TableRegistry) -> List[tuple]:
    tables = {t: registry[t].copy() for t in query.tables}
    engine = ImputationService(tables, default=IMPUTER_FACTORIES[IMPUTER])
    return sorted(execute_quip(query, tables, engine, strategy=STRATEGY,
                               morsel_rows=MORSEL_ROWS).answer_tuples())


def _serve(events: List[Tuple], tables: Dict[str, MaskedRelation], *,
           ivm: bool, check_cold: bool) -> Dict:
    registry = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = QuipService(
        registry, IMPUTER_FACTORIES[IMPUTER], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, result_cache_size=128, ivm=ivm,
    )
    answers: List[List[tuple]] = []
    queries = mutations = stale = 0
    t0 = time.perf_counter()
    for event in events:
        if event[0] == "mutate":
            _kind, op, ids, payload = event
            if op == "update":
                registry.update_rows("R0", ids, payload)
            elif op == "delete":
                registry.delete_rows("R0", ids)
            else:
                registry.insert_rows("R0", payload)
            mutations += 1
            continue
        _kind, query = event
        got = sorted(svc.answers(svc.submit(query)))
        answers.append(got)
        queries += 1
        if check_cold:
            stale += int(got != _cold_answers(query, registry))
    wall = time.perf_counter() - t0
    summary = svc.summary()
    row = {
        "mode": f"ivm_{'on' if ivm else 'off'}",
        "queries": queries, "mutations": mutations,
        "wall_s": round(wall, 4),
        "result_cache_hits": summary["result_cache_hits"],
        "queries_result_cache_hit": summary["queries_result_cache_hit"],
        "results_patched": summary["results_patched"],
        "ivm_fallbacks": summary["ivm_fallbacks"],
        "results_invalidated": summary["results_invalidated"],
        "imputations": summary["imputations"],
        "stale_answers": stale,
        "_answers": answers,
    }
    if ivm:
        row["fallback_reasons"] = dict(svc._ivm.fallback_reasons)
    return row


def run(fast: bool = True) -> List[Dict]:
    rows, n_queries = (1500, 60) if fast else (6000, 160)
    tables = _instance(rows, missing_rate=0.25, seed=14)
    events = _events(n_queries, mutate_every=4, rows=rows, seed=14)
    out = [
        _serve(events, tables, ivm=False, check_cold=False),
        _serve(events, tables, ivm=True, check_cold=True),
    ]
    base = out[0].pop("_answers")
    out[1]["answers_match_evicting"] = int(out[1].pop("_answers") == base)
    return out


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    off, on = by_mode["ivm_off"], by_mode["ivm_on"]
    # acceptance invariants (CI smoke) — deterministic counters only
    assert on["results_patched"] > 0, (
        f"IVM never patched: {on.get('fallback_reasons')}"
    )
    assert on["stale_answers"] == 0, "patched answer diverged from cold replay"
    assert on["answers_match_evicting"] == 1, \
        "IVM-on answers diverged from the evicting service"
    assert on["queries_result_cache_hit"] > off["queries_result_cache_hit"], \
        "patching produced no hit-rate gain over evicting"
    assert off["results_patched"] == 0 and off["ivm_fallbacks"] == 0
    return {
        "ivm_results_patched": on["results_patched"],
        "ivm_fallbacks": on["ivm_fallbacks"],
        "ivm_stale_answers": on["stale_answers"],
        "ivm_hits": on["queries_result_cache_hit"],
        "evicting_hits": off["queries_result_cache_hit"],
        "ivm_hit_gain": (
            on["queries_result_cache_hit"] - off["queries_result_cache_hit"]
        ),
        "ivm_imputations_saved": off["imputations"] - on["imputations"],
    }
