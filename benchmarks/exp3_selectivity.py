"""Experiment 3 (paper Figs. 11–12): selectivity effects.

Query template: SELECT a, AVG(b) FROM R1..Rn WHERE Pred_J, Pred_S GROUP BY a
with selection selectivity swept over {0, .2, .4, .6, .8, 1} and join
selectivity ∈ {low, high} on the synthetic (Smart-Campus-like) data."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import run_workload
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema

NAME = "exp3_selectivity"


def _synth(join_sel: str, rng) -> Dict[str, MaskedRelation]:
    """Two-table join with controllable join selectivity (key cardinality)."""
    n = 3000
    card = 40 if join_sel == "high" else 1500  # few keys ⇒ many matches
    tables = {}
    for name in ("A", "B"):
        k = rng.integers(0, card, n).astype(np.int64)
        v = rng.integers(0, 100, n).astype(np.int64)
        m_k = rng.random(n) < 0.25
        m_v = rng.random(n) < 0.25
        schema = Schema(name, [ColumnSpec(f"{name}.k"), ColumnSpec(f"{name}.v")])
        tables[name] = MaskedRelation.from_columns(
            schema,
            {f"{name}.k": np.where(m_k, 0, k), f"{name}.v": np.where(m_v, 0, v)},
            missing={f"{name}.k": m_k, f"{name}.v": m_v},
            base_table=name,
        )
    return tables


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    rng = np.random.default_rng(3)
    sels = (0.2, 0.6, 1.0) if fast else (0.0, 0.2, 0.4, 0.6, 0.8, 1.0)
    for join_sel in ("low", "high"):
        tables = _synth(join_sel, rng)
        for s in sels:
            x = int(np.quantile(np.arange(100), 1 - s)) if s < 1 else 0
            q = Query(
                tables=("A", "B"),
                selections=(SelectionPredicate("A.v", ">=", x),
                            SelectionPredicate("B.v", ">=", x)),
                joins=(JoinPredicate("A.k", "B.k"),),
                projection=(),
                aggregate=Aggregate("avg", "B.v", group_by=None),
            )
            res = run_workload(tables, [q], "knn",
                               strategies=("imputedb", "adaptive"))
            for strat, r in res.items():
                rows.append({
                    "join_sel": join_sel, "sel": s, "strategy": strat,
                    "imputations": r.imputations,
                    "runtime_s": round(r.wall_seconds, 4),
                })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    out = {}
    for js in ("low", "high"):
        ad = sum(r["imputations"] for r in rows
                 if r["join_sel"] == js and r["strategy"] == "adaptive")
        eg = sum(r["imputations"] for r in rows
                 if r["join_sel"] == js and r["strategy"] == "imputedb")
        out[f"{js}_join/imputation_ratio_adaptive_vs_imputedb"] = round(
            ad / max(eg, 1), 4
        )
    # monotonicity: imputations increase with selectivity (paper trend)
    for strat in ("adaptive", "eager"):
        seq = [r["imputations"] for r in sorted(
            (r for r in rows if r["strategy"] == strat and r["join_sel"] == "low"),
            key=lambda r: r["sel"])]
        out[f"low_join/{strat}_monotone"] = float(
            all(a <= b * 1.15 for a, b in zip(seq, seq[1:]))
        )
    return out
