"""Experiment 10: per-tenant QoS scheduling under an aggressor tenant.

One skewed two-tenant stream (tenant 0 floods most of the queries, tenant 1
is the low-traffic victim), executed under four serving configurations that
differ ONLY in scheduling/admission policy:

* ``rr``        — the pre-QoS FIFO ring: the aggressor gets one ring slot
  per flooded session, so its morsel share grows with its flood;
* ``rr_quota``  — round-robin plus a per-tenant admission quota capping the
  aggressor's concurrently admitted sessions;
* ``wfq``       — weighted fair queueing over tenants (equal weights): the
  per-tenant morsel share is pinned at the weight ratio no matter how many
  sessions the aggressor floods;
* ``deadline``  — earliest-deadline-first with a deadline class on the
  victim tenant (sized from a probe of its own per-query step counts).

All runs use the scheduler's ``unit`` cost model, so every fairness metric
below is **deterministic step accounting** — scheduler-clock steps, not
wall-clock — and the acceptance asserts in :func:`derived` cannot flake on
machine load:

* every policy's answers are bit-identical to cold serial replay;
* the victim's morsel-share deficit shrinks under wfq vs round-robin;
* the victim's p95 turnaround (admission → completion, in steps) improves;
* the victim's deadline hit-rate under the deadline policy is at least its
  round-robin hit-rate.
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES
from repro.core.executor import execute_quip
from repro.data.queries import serving_workload
from repro.data.synthetic import wifi_dataset
from repro.imputers.base import ImputationService
from repro.service import QuipService

NAME = "exp10_qos"

STRATEGY = "adaptive"
MORSEL_ROWS = 16  # small morsels: many scheduler steps per query
MAX_INFLIGHT = 6
AGGRESSOR, VICTIM = 0, 1  # zipf rank 1 floods; rank 2 is the victim


def _serial_answers(stream, tables, imputer) -> List[list]:
    answers = []
    for _tenant, q in stream:
        eng = ImputationService(
            {t: tables[t].copy() for t in q.tables},
            default=IMPUTER_FACTORIES[imputer],
        )
        res = execute_quip(q, tables, eng, strategy=STRATEGY,
                           morsel_rows=MORSEL_ROWS)
        answers.append(sorted(res.answer_tuples()))
    return answers


def _run_policy(stream, tables, imputer, mode: str,
                victim_deadline: float) -> Dict:
    policy = {"rr": "rr", "rr_quota": "rr", "wfq": "wfq",
              "deadline": "deadline"}[mode]
    svc = QuipService(
        tables, IMPUTER_FACTORIES[imputer], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, shared_impute=False,
        max_inflight=MAX_INFLIGHT,
        result_cache_size=0,  # every repeat re-executes: pure scheduling
        scheduler_policy=policy,
        cost_model="unit",  # deterministic step accounting, no wall clock
        tenant_deadlines={VICTIM: victim_deadline},
        tenant_quotas={AGGRESSOR: 2} if mode == "rr_quota" else None,
    )
    t0 = time.perf_counter()
    tickets = [svc.submit(q, tenant=tenant) for tenant, q in stream]
    svc.run_until_idle()
    wall = time.perf_counter() - t0
    answers = [sorted(svc.answers(t)) for t in tickets]
    ts = svc.tenant_summary()
    victim_recs = [r for r in svc.serving.records if r.tenant == VICTIM]
    # residency share: of the scheduler steps that elapsed while a victim
    # query was in the system (admission → completion), how many did that
    # query get?  1/2 is the two-tenant fair share; round-robin under an
    # aggressor flood of k sessions degrades it toward 1/(k+1).  Clock
    # units == steps under the unit model, so this is deterministic.
    victim_share = sum(
        r.steps / r.turnaround_cost for r in victim_recs
    ) / len(victim_recs)
    return {
        "mode": mode,
        "queries": len(stream),
        "victim_queries": len(victim_recs),
        "wall_s": round(wall, 4),
        "total_steps": int(svc.summary()["morsel_steps"]),
        "victim_steps": int(ts[VICTIM]["steps"]),
        "victim_share": round(victim_share, 4),
        "victim_p95_turnaround_steps": round(
            ts[VICTIM]["p95_turnaround_cost"], 1
        ),
        "victim_deadline_hit_rate": ts[VICTIM]["deadline_hit_rate"],
        "aggressor_share": round(ts[AGGRESSOR]["cost_share"], 4),
        "_answers": answers,
    }


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=120, n_wifi=1500, n_occ=800)
        n_queries = 30
    else:
        tables, _ = wifi_dataset()
        n_queries = 60
    imputer = "knn"
    stream = list(serving_workload(
        "wifi", tables, n_queries=n_queries, n_templates=6,
        n_tenants=2, seed=5, tenant_skew=1.8,
    ))
    serial = _serial_answers(stream, tables, imputer)

    # probe: the victim's own per-query step counts under round-robin size
    # its deadline class — generous vs its own work, tight vs queueing
    # behind the aggressor's flood
    probe = _run_policy(stream, tables, imputer, "rr",
                        victim_deadline=float("inf"))
    mean_steps = probe["victim_steps"] / max(probe["victim_queries"], 1)
    victim_deadline = 1.5 * mean_steps

    rows = [
        _run_policy(stream, tables, imputer, mode, victim_deadline)
        for mode in ("rr", "rr_quota", "wfq", "deadline")
    ]
    for r in rows:
        r["answers_match_serial"] = int(r.pop("_answers") == serial)
        r["victim_deadline_steps"] = round(victim_deadline, 1)
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    rr = by_mode["rr"]
    quota = by_mode["rr_quota"]
    wfq = by_mode["wfq"]
    deadline = by_mode["deadline"]
    # acceptance invariants (CI runs this experiment as a smoke check);
    # every metric here is deterministic step accounting, not wall-clock
    for r in rows:
        assert r["answers_match_serial"] == 1, (
            f"{r['mode']} answers diverged from serial replay"
        )
    assert wfq["victim_share"] > rr["victim_share"], (
        "weighted-fair did not improve the victim's morsel-step share "
        f"({wfq['victim_share']} <= {rr['victim_share']})"
    )
    assert (wfq["victim_p95_turnaround_steps"]
            < rr["victim_p95_turnaround_steps"]), (
        "weighted-fair did not improve the victim's p95 turnaround"
    )
    assert (deadline["victim_deadline_hit_rate"]
            >= rr["victim_deadline_hit_rate"]), (
        "deadline policy hit fewer victim deadlines than round-robin"
    )
    fair = 0.5  # two tenants, equal weights
    return {
        "qos_victim_share_rr": rr["victim_share"],
        "qos_victim_share_rr_quota": quota["victim_share"],
        "qos_victim_share_wfq": wfq["victim_share"],
        "qos_victim_share_deficit_rr": round(
            max(0.0, fair - rr["victim_share"]), 4
        ),
        "qos_victim_share_deficit_wfq": round(
            max(0.0, fair - wfq["victim_share"]), 4
        ),
        "qos_victim_p95_turnaround_rr": rr["victim_p95_turnaround_steps"],
        "qos_victim_p95_turnaround_wfq": wfq["victim_p95_turnaround_steps"],
        "qos_deadline_hit_rate_rr": rr["victim_deadline_hit_rate"],
        "qos_deadline_hit_rate_deadline":
            deadline["victim_deadline_hit_rate"],
        "qos_answers_match": 1.0,
    }
