"""Experiment 12: compiled tensor plans on a repeat-heavy workload.

The compiled executor (docs/compiled.md) pays one lowering per hot query
signature to replace the morsel interpreter's per-(morsel × operator)
Python round-trips with a single vectorized whole-relation program.  This
experiment measures exactly the serving scenario the tentpole targets:

* **repeat-heavy, unmutated** — a skewed 4-template stream served
  sequentially by two otherwise-identical services (result cache OFF so
  every repeat re-executes): ``exec_impl="interp"`` vs
  ``exec_impl="compiled"`` with ``compile_after_hits=K``.  Acceptance:
  answers AND imputation totals bit-identical; ``compiled_hits`` equals
  the per-signature prediction ``Σ max(0, occurrences − K)``;
  ``compile_fallbacks == 0`` (eager + no VF + no MIN/MAX pushdown is
  always eligible); and the deterministic speedup proxy — **Python work
  units**, scheduler morsel steps + impute-batch flushes, the two
  counters that scale with the interpreter's per-(morsel × operator)
  round-trips and that a compiled session collapses to one step and
  O(operators) flushes — drops by ≥2×.
* **mutation-interleaved** — the ``mutating_workload`` replay against an
  epoch-versioned registry with compilation ON, every answer compared to a
  cold interpreter service built on post-mutation table copies.
  Acceptance: zero mismatches (mutations must invalidate compiled
  artifacts — stale ones are unreachable by construction) and
  invalidation events > 0.

Wall-clock speedup is recorded but not asserted (CI runners flake); the
work-unit ratio is the load-bearing, deterministic proxy — on this
workload it tracks the measured wall ratio closely (~2.2× both).
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES
from repro.data.queries import mutating_workload, serving_workload
from repro.data.synthetic import wifi_dataset
from repro.service import QuipService, TableRegistry
from repro.service.plan_cache import query_signature

NAME = "exp12_compiled"

MORSEL_ROWS = 8  # small on purpose: the interpreter pays per morsel
IMPUTER = "mean"
K = 2  # compile_after_hits

# eager + use_vf=False + minmax_opt=False: every signature in the stream
# is lowering-eligible, so compile_fallbacks must stay 0
_KNOBS = dict(strategy="eager", use_vf=False, minmax_opt=False,
              morsel_rows=MORSEL_ROWS, result_cache_size=0,
              shared_impute=False)


def _expected_compiled(stream) -> int:
    """Per-signature prediction: occurrence i (1-based) runs compiled iff
    its plan-cache hit count i−1 has reached K, i.e. i ≥ K+1 — so each
    signature with c occurrences contributes max(0, c − K)."""
    counts: Dict = {}
    for _tenant, q in stream:
        sig = query_signature(q)
        counts[sig] = counts.get(sig, 0) + 1
    return sum(max(0, c - K) for c in counts.values())


def _sequential(stream, tables, *, exec_impl: str) -> Dict:
    svc = QuipService(
        tables, IMPUTER_FACTORIES[IMPUTER],
        exec_impl=exec_impl, compile_after_hits=K, **_KNOBS,
    )
    answers = []
    t0 = time.perf_counter()
    for tenant, q in stream:
        ticket = svc.submit(q, tenant=tenant)
        answers.append(sorted(svc.answers(ticket), key=repr))
    wall = time.perf_counter() - t0
    summary = svc.summary()
    return {
        "mode": exec_impl,
        "queries": len(answers),
        "wall_s": round(wall, 4),
        "morsel_steps": summary["morsel_steps"],
        "imputations": summary["imputations"],
        "impute_batches": summary["impute_batches"],
        "compiled_hits": summary["compiled_hits"],
        "compile_fallbacks": summary["compile_fallbacks"],
        "plan_cache_compiled": summary["plan_cache_compiled"],
        "_answers": answers,
    }


def _mutation_replay(tables) -> Dict:
    """Long-lived compiling service vs a cold interpreter service per
    query: bit-identical answers across every mutation epoch — compiled
    artifacts must die with their table's epoch."""
    registry = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = QuipService(
        registry, IMPUTER_FACTORIES[IMPUTER],
        exec_impl="compiled", compile_after_hits=1, **_KNOBS,
    )
    events = list(mutating_workload("wifi", tables, n_queries=12,
                                    mutate_every=3, n_templates=4, seed=9))
    queries = mutations = mismatches = 0
    for event in events:
        if event[0] == "mutate":
            event[1].apply(registry)
            mutations += 1
            continue
        _kind, tenant, q = event
        got = sorted(svc.answers(svc.submit(q, tenant=tenant)), key=repr)
        cold = QuipService(
            {t: registry[t].copy() for t in registry},
            IMPUTER_FACTORIES[IMPUTER], exec_impl="interp", **_KNOBS,
        )
        want = sorted(cold.answers(cold.submit(q)), key=repr)
        queries += 1
        mismatches += int(got != want)
    summary = svc.summary()
    return {
        "mode": "mutation_replay",
        "queries": queries,
        "mutations": mutations,
        "registry_epoch": summary["registry_epoch"],
        "invalidation_events": summary["invalidation_events"],
        "plans_invalidated": summary["plans_invalidated"],
        "compiled_hits": summary["compiled_hits"],
        "compile_fallbacks": summary["compile_fallbacks"],
        "mismatches": mismatches,
    }


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
        n_queries = 24
    else:
        tables, _ = wifi_dataset()
        n_queries = 48
    # repeat-heavy: few templates, strong skew → hot signatures cross K fast
    stream = list(serving_workload("wifi", tables, n_queries=n_queries,
                                   n_templates=4, n_tenants=4, skew=1.4,
                                   seed=5))
    rows = [
        _sequential(stream, tables, exec_impl="interp"),
        _sequential(stream, tables, exec_impl="compiled"),
        _mutation_replay(tables),
    ]
    base_answers = rows[0].pop("_answers")
    rows[1]["answers_match_interp"] = int(
        rows[1].pop("_answers") == base_answers
    )
    rows[1]["expected_compiled_hits"] = _expected_compiled(stream)
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    interp = by_mode["interp"]
    comp = by_mode["compiled"]
    replay = by_mode["mutation_replay"]
    # acceptance invariants — all deterministic (no wall-clock asserts)
    assert comp["answers_match_interp"] == 1, "compiled answers diverged"
    assert comp["imputations"] == interp["imputations"], \
        "compiled path changed the deduplicated imputation total"
    assert comp["compiled_hits"] == comp["expected_compiled_hits"], (
        comp["compiled_hits"], comp["expected_compiled_hits"])
    assert comp["compiled_hits"] > 0, "no signature ever got promoted"
    assert comp["compile_fallbacks"] == 0, \
        "an eligible signature fell back to the interpreter"
    work = lambda r: r["morsel_steps"] + r["impute_batches"]
    step_speedup = work(interp) / max(work(comp), 1)
    assert step_speedup >= 2.0, \
        f"compiled Python-work-unit speedup only {step_speedup:.2f}x"
    assert replay["mismatches"] == 0, \
        "stale compiled answer leaked across a mutation"
    assert replay["invalidation_events"] > 0, "mutations did not invalidate"
    return {
        "answers_match": float(comp["answers_match_interp"]),
        "compiled_hits": comp["compiled_hits"],
        "compile_fallbacks": comp["compile_fallbacks"],
        "step_speedup": round(step_speedup, 2),
        "wall_speedup": round(
            interp["wall_s"] / max(comp["wall_s"], 1e-9), 2
        ),
        "impute_batches_saved": (
            interp["impute_batches"] - comp["impute_batches"]
        ),
        "mutation_answers_match": float(replay["mismatches"] == 0),
        "mutation_compiled_hits": replay["compiled_hits"],
        "mutation_epochs": replay["registry_epoch"],
        "mutation_plans_invalidated": replay["plans_invalidated"],
    }
