"""Shared benchmark harness: run a workload under the paper's strategies and
collect (#imputations, runtime, temp tuples) — the quantities of every table
and figure in §7."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.executor import execute_offline, execute_quip, make_plan
from repro.core.plan import Query
from repro.core.relation import MaskedRelation
from repro.imputers import (
    GbdtImputer,
    ImputationEngine,
    KnnImputer,
    LocaterImputer,
    MeanImputer,
)

__all__ = ["IMPUTER_FACTORIES", "run_workload", "StrategyResult"]

# Simulated per-value / training costs follow the paper's Fig. 2 profile:
# KNN: expensive inference; XGBoost: training dominates; LOCATER: expensive
# per value; Mean: free.
IMPUTER_FACTORIES: Dict[str, Callable[[], object]] = {
    "mean": lambda: MeanImputer(),
    "knn": lambda: KnnImputer(k=5, cost_per_value=2e-3),
    "xgboost": lambda: GbdtImputer(rounds=16, train_cost=1.0,
                                   cost_per_value=2e-5),
    "locater": lambda: LocaterImputer(cost_per_value=4e-3),
}


@dataclasses.dataclass
class StrategyResult:
    strategy: str
    imputations: int
    impute_batches: int  # imputer invocations (batched-service flush batches)
    wall_seconds: float
    temp_tuples: int
    filtered_by_bloom: int
    trigger_joins: int
    answers: List[tuple]


def _engine(tables, imputer: str) -> ImputationEngine:
    return ImputationEngine(
        {t: r.copy() for t, r in tables.items()},
        default=IMPUTER_FACTORIES[imputer],
    )


def run_workload(
    tables: Dict[str, MaskedRelation],
    queries: List[Query],
    imputer: str,
    strategies=("offline", "eager", "lazy", "adaptive"),
    planner: str = "imputedb",
    morsel_rows: int = 4096,
    minmax_opt: bool = True,
) -> Dict[str, StrategyResult]:
    out: Dict[str, StrategyResult] = {}
    for strat in strategies:
        imps = batches = wall = temps = bloom = trig = 0
        answers: List[tuple] = []
        for q in queries:
            eng = _engine(tables, imputer)
            if strat == "offline":
                res = execute_offline(q, tables, eng)
            else:
                res = execute_quip(
                    q, tables, eng, strategy=strat, planner=planner,
                    morsel_rows=morsel_rows, minmax_opt=minmax_opt,
                )
            imps += res.counters.imputations
            batches += res.counters.impute_batches
            wall += res.counters.wall_seconds
            temps += res.counters.temp_tuples
            bloom += res.counters.filtered_by_bloom
            trig += res.counters.trigger_joins
            answers.extend(res.answer_tuples())
        out[strat] = StrategyResult(
            strat, imps, batches, wall, temps, bloom, trig, answers
        )
    return out
