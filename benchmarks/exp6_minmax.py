"""Table 7 (paper §9.4.2): MAX/MIN pushdown optimization — #imputations,
running time, and |RT| (tuples removed by the dynamic predicate) with the
optimization on (QUIP) vs off (QUIP-)."""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import IMPUTER_FACTORIES, run_workload
from repro.core.executor import execute_quip
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, wifi_dataset
from repro.imputers import ImputationEngine

NAME = "exp6_minmax"


def _minmax_queries(ds: str, tables) -> List[Query]:
    qs = []
    base = workload(ds, tables, kind="random", n_queries=12, seed=29)
    for q in base:
        if q.aggregate is None or len(q.tables) < 2:
            continue
        qs.append(Query(
            tables=q.tables, selections=q.selections, joins=q.joins,
            projection=(),
            aggregate=Aggregate("max" if len(qs) % 2 == 0 else "min",
                                q.aggregate.attr, group_by=None),
        ))
    return qs[:4]


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    for ds, tables in (("cdc", cdc_dataset()[0]),
                       ("wifi", wifi_dataset()[0])):
        for qi, q in enumerate(_minmax_queries(ds, tables)):
            rec = {"dataset": ds, "query": f"{ds}-Q{qi}"}
            for on in (True, False):
                eng = ImputationEngine(
                    {t: r.copy() for t, r in tables.items()},
                    default=IMPUTER_FACTORIES["knn"],
                )
                res = execute_quip(q, tables, eng, strategy="adaptive",
                                   minmax_opt=on, morsel_rows=256)
                tag = "on" if on else "off"
                rec[f"imputations_{tag}"] = res.counters.imputations
                rec[f"runtime_ms_{tag}"] = round(
                    res.counters.wall_seconds * 1e3, 2
                )
                if on:
                    rec["removed_RT"] = res.counters.minmax_removed
                    rec["answer"] = str(res.answer_tuples())
                else:
                    rec["answer_off"] = str(res.answer_tuples())
            rec["answers_equal"] = rec["answer"] == rec.pop("answer_off")
            rows.append(rec)
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    out = {}
    tot_on = sum(r["imputations_on"] for r in rows)
    tot_off = sum(r["imputations_off"] for r in rows)
    out["imputation_reduction"] = round(1 - tot_on / max(tot_off, 1), 4)
    out["total_RT_removed"] = sum(r["removed_RT"] for r in rows)
    out["all_answers_equal"] = float(all(r["answers_equal"] for r in rows))
    return out
