"""Experiment 4 (paper Table 4): bloom-filter effect — Δruntime,
Δ|temporary tuples|, Δimputations between QUIP and QUIP-without-bloom.
Blooms act only when join attributes have missing values (WiFi / SM, not
CDC)."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import run_workload
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, smartcampus_dataset, wifi_dataset
import repro.core.operators as ops

NAME = "exp4_bloom"


class _DisableBloomFilters:
    """Context: make every bloom filter incomplete (probes skipped)."""

    def __enter__(self):
        from repro.core.bloom import BloomFilter

        self._orig = BloomFilter.mark_complete
        BloomFilter.mark_complete = lambda self: None
        return self

    def __exit__(self, *a):
        from repro.core.bloom import BloomFilter

        BloomFilter.mark_complete = self._orig


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    nq = 5 if fast else 20
    datasets = {
        "cdc": cdc_dataset()[0],
        "wifi": wifi_dataset()[0],
        "smartcampus": smartcampus_dataset()[0],
    }
    for ds, tables in datasets.items():
        queries = workload(ds, tables, kind="random", n_queries=nq, seed=17)
        with_bloom = run_workload(tables, queries, "mean",
                                  strategies=("adaptive",))["adaptive"]
        with _DisableBloomFilters():
            without = run_workload(tables, queries, "mean",
                                   strategies=("adaptive",))["adaptive"]
        rows.append({
            "dataset": ds,
            "d_runtime_ms": round(
                (without.wall_seconds - with_bloom.wall_seconds) * 1e3, 2
            ),
            "d_temp_tuples": without.temp_tuples - with_bloom.temp_tuples,
            "d_imputations": without.imputations - with_bloom.imputations,
            "bloom_filtered": with_bloom.filtered_by_bloom,
            "answers_equal": sorted(without.answers) == sorted(with_bloom.answers),
        })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    out = {}
    for r in rows:
        out[f"{r['dataset']}/d_temp_tuples"] = r["d_temp_tuples"]
        out[f"{r['dataset']}/d_imputations"] = r["d_imputations"]
        out[f"{r['dataset']}/answers_equal"] = float(r["answers_equal"])
    return out
