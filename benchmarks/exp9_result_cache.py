"""Experiment 9: the answer-level result cache and mutation invalidation.

Two phases over the wifi serving workload:

* **repeat-heavy, unmutated** — the same skewed stream served sequentially
  (submit → drain, so repeats can hit the result cache) by two services:
  result cache off (PR-3 serving: plans and imputations shared, answers
  re-executed) vs on.  Acceptance: result-cache hits > 0, answers
  bit-identical, and an end-to-end speedup.
* **mutation-interleaved** — the ``mutating_workload`` stream replayed
  against an epoch-versioned ``TableRegistry``-backed service with the
  result cache AND shared impute store on.  After every event, each
  query's answer is compared against a cold ``QuipService`` constructed on
  a copy of the post-mutation tables — the acceptance invariant from the
  staleness fix: no stale plan, imputation, or cached answer may leak
  across a mutation epoch.

Both invariants are asserted in ``derived`` so CI runs this module as a
smoke check (like exp8).
"""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES
from repro.data.queries import mutating_workload, serving_workload
from repro.data.synthetic import wifi_dataset
from repro.service import QuipService, TableRegistry

NAME = "exp9_result_cache"

STRATEGY = "adaptive"
MORSEL_ROWS = 4096
IMPUTER = "knn"


def _sequential(stream, tables, *, result_cache_size: int) -> Dict:
    """Submit → drain each query in turn (the pattern under which repeats
    are eligible for result-cache hits at submit time)."""
    svc = QuipService(
        tables, IMPUTER_FACTORIES[IMPUTER], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, result_cache_size=result_cache_size,
    )
    answers, latencies = [], []
    t0 = time.perf_counter()
    for tenant, q in stream:
        t1 = time.perf_counter()
        ticket = svc.submit(q, tenant=tenant)
        answers.append(sorted(svc.answers(ticket)))
        latencies.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    summary = svc.summary()
    return {
        "mode": f"result_cache_{'on' if result_cache_size else 'off'}",
        "queries": len(answers),
        "wall_s": round(wall, 4), "qps": round(len(answers) / wall, 2),
        "p50_ms": round(summary["p50_latency_s"] * 1e3, 3),
        "p95_ms": round(summary["p95_latency_s"] * 1e3, 3),
        "imputations": summary["imputations"],
        "plan_cache_hits": summary["plan_cache_hits"],
        "result_cache_hits": summary.get("result_cache_hits", 0),
        "_answers": answers,
    }


def _mutation_replay(tables) -> Dict:
    """The long-lived service vs a cold service per query: bit-identical
    answers across every mutation epoch."""
    registry = TableRegistry({t: r.copy() for t, r in tables.items()})
    svc = QuipService(
        registry, IMPUTER_FACTORIES[IMPUTER], strategy=STRATEGY,
        morsel_rows=MORSEL_ROWS, shared_impute=True,
    )
    events = list(mutating_workload("wifi", tables, n_queries=12,
                                    mutate_every=3, n_templates=4, seed=9))
    queries = mutations = mismatches = 0
    for event in events:
        if event[0] == "mutate":
            event[1].apply(registry)
            mutations += 1
            continue
        _kind, tenant, q = event
        got = sorted(svc.answers(svc.submit(q, tenant=tenant)))
        cold = QuipService(
            {t: registry[t].copy() for t in registry},
            IMPUTER_FACTORIES[IMPUTER], strategy=STRATEGY,
            morsel_rows=MORSEL_ROWS, result_cache_size=0,
        )
        want = sorted(cold.answers(cold.submit(q)))
        queries += 1
        mismatches += int(got != want)
    summary = svc.summary()
    return {
        "mode": "mutation_replay",
        "queries": queries,
        "mutations": mutations,
        "registry_epoch": summary["registry_epoch"],
        "invalidation_events": summary["invalidation_events"],
        "plans_invalidated": summary["plans_invalidated"],
        "results_invalidated": summary["results_invalidated"],
        "store_cells_invalidated": summary["store_cells_invalidated"],
        "result_cache_hits": summary["result_cache_hits"],
        "mismatches": mismatches,
    }


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
        n_queries = 24
    else:
        tables, _ = wifi_dataset()
        n_queries = 48
    # repeat-heavy: few templates, strong skew → many repeated signatures
    stream = list(serving_workload("wifi", tables, n_queries=n_queries,
                                   n_templates=4, n_tenants=4, skew=1.4,
                                   seed=5))
    rows = [
        _sequential(stream, tables, result_cache_size=0),
        _sequential(stream, tables, result_cache_size=128),
        _mutation_replay(tables),
    ]
    base_answers = rows[0].pop("_answers")
    rows[1]["answers_match_uncached"] = int(
        rows[1].pop("_answers") == base_answers
    )
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    off = by_mode["result_cache_off"]
    on = by_mode["result_cache_on"]
    replay = by_mode["mutation_replay"]
    # acceptance invariants (CI runs this experiment as a smoke check) —
    # all deterministic counters, no wall-clock comparisons that could
    # flake on a loaded runner; the end-to-end speedup is recorded as a
    # derived metric instead of asserted
    assert on["result_cache_hits"] > 0, "result cache never hit"
    assert on["answers_match_uncached"] == 1, "cached answers diverged"
    assert on["imputations"] < off["imputations"], \
        "cached repeats re-ran imputation work"
    assert replay["mismatches"] == 0, "stale answer leaked across a mutation"
    assert replay["invalidation_events"] > 0, "mutations did not invalidate"
    return {
        "result_cache_hits": on["result_cache_hits"],
        "result_cache_speedup": round(
            off["wall_s"] / max(on["wall_s"], 1e-9), 2
        ),
        "result_cache_p50_ms": on["p50_ms"],
        "result_cache_p95_ms": on["p95_ms"],
        "result_cache_imputations_saved": (
            off["imputations"] - on["imputations"]
        ),
        "mutation_answers_match": float(replay["mismatches"] == 0),
        "mutation_epochs": replay["registry_epoch"],
        "mutation_plans_invalidated": replay["plans_invalidated"],
        "mutation_results_invalidated": replay["results_invalidated"],
        "mutation_store_cells_invalidated": replay[
            "store_cells_invalidated"
        ],
    }
