"""Table 8 (paper §9.4.3): comparison with a QuERy-style baseline.

QuERy [Altwaijry et al., VLDB'15] targets entity resolution: when a join
input is dirty it falls back to cartesian-product-style evaluation and uses
sampling to drive its decision function.  Re-implementation approximation
(documented): *QuERy-Adaptive* = eager imputation of all join keys before
every join (its cartesian fallback makes preserving missing keys too costly,
pushing its DF to impute early) + a 10% sampling surcharge on imputations;
*QuERy-Lazy* = QUIP-lazy with outer-join preservation replaced by full
pair-wise expansion at joins (counted, not materialized, beyond a cap)."""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import IMPUTER_FACTORIES, run_workload
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, smartcampus_dataset, wifi_dataset
from repro.imputers import ImputationEngine

NAME = "exp7_query_baseline"


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    nq = 4 if fast else 20
    datasets = {
        "cdc": cdc_dataset()[0],
        "wifi": wifi_dataset()[0],
        "smartcampus": smartcampus_dataset()[0],
    }
    for ds, tables in datasets.items():
        queries = workload(ds, tables, kind="random", n_queries=nq, seed=31)
        quip = run_workload(tables, queries, "knn",
                            strategies=("adaptive",))["adaptive"]
        # QuERy-Adaptive: impute join keys eagerly everywhere (+ sampling)
        qa = run_workload(tables, queries, "knn",
                          strategies=("imputedb",))["imputedb"]
        qa_imps = int(qa.imputations * 1.10)  # sampling surcharge
        qa_wall = qa.wall_seconds * 1.10
        # QuERy-Lazy: lazy but with cartesian-style join expansion — model
        # the blow-up via temp-tuple accounting on the lazy run
        ql = run_workload(tables, queries, "knn",
                          strategies=("lazy",))["lazy"]
        cart_factor = 25.0  # measured expansion of pairwise vs outer-join
        rows.append({
            "dataset": ds,
            "quip_T_ms": round(quip.wall_seconds * 1e3, 1),
            "query_adaptive_T_ms": round(qa_wall * 1e3, 1),
            "query_lazy_T_ms": round(ql.wall_seconds * cart_factor * 1e3, 1),
            "quip_imps": quip.imputations,
            "query_adaptive_imps": qa_imps,
            "query_lazy_imps": ql.imputations,
        })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    out = {}
    for r in rows:
        out[f"{r['dataset']}/T_ratio_queryadaptive_vs_quip"] = round(
            r["query_adaptive_T_ms"] / max(r["quip_T_ms"], 1e-9), 2
        )
        out[f"{r['dataset']}/imps_ratio_queryadaptive_vs_quip"] = round(
            r["query_adaptive_imps"] / max(r["quip_imps"], 1), 2
        )
    return out
