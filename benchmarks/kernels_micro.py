"""Kernel microbenchmarks: bloom probe + masked-KNN distance + hash join —
wall time of the jitted ref path on CPU and allclose vs oracle for the Pallas
kernels in interpret mode (the perf numbers that matter are the dry-run
rooflines; this is the correctness+overhead record).

The hash-join cases track the QUIP join spine's kernel trajectory: build and
probe sides at 10^4–10^7 keys across duplication factors and missing-key
rates, NumPy sort-join (oracle) vs the jnp ref path, with the Pallas pair
verified at the smallest size (sequential interpret-mode build is a
correctness tool, not a perf path).

The neighbour-aggregation and knn-impute cases track the imputation
trajectory (paper Fig. 2: KNN inference dominates): the vectorized
bincount-argmax mode vs the seed per-row Python loop, and the end-to-end
``KnnImputer.impute_attr`` batch cost on synthetic masked tables.

The segment-reduce cases cover the compiled executor's grouped-aggregate
lowering (docs/compiled.md): per-group Python loop vs the numpy
sort-and-slice path (the bit-identical serving default) vs the jitted
``jax.ops`` ref path, with the Pallas kernel verified at the smallest
shape."""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.core.triggers import multi_match
from repro.imputers.knn import KnnImputer
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.hashing import fold64, hash_positions_np

NAME = "kernels_micro"


def _time(fn, *args, reps=5):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jnp.asarray(out).block_until_ready() if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # bloom probe
    log2m, k = 20, 4
    bits = np.zeros((1 << log2m) // 32, dtype=np.uint32)
    keys = rng.integers(0, 1 << 40, 1 << 14).astype(np.int64)
    pos = hash_positions_np(keys[: 1 << 13], k, log2m).ravel()
    np.bitwise_or.at(bits, pos >> 5, np.uint32(1) << (pos & 31))
    folded = fold64(keys)
    us_ref = _time(
        lambda: kops.bloom_probe(jnp.asarray(bits), jnp.asarray(folded),
                                 num_hashes=k, log2m=log2m, impl="ref")
    )
    ref_out = np.asarray(kref.bloom_probe_ref(
        jnp.asarray(bits), jnp.asarray(folded), k, log2m))
    pl_out = np.asarray(kops.bloom_probe(
        jnp.asarray(bits), jnp.asarray(folded), num_hashes=k, log2m=log2m,
        impl="pallas"))
    rows.append({
        "kernel": "bloom_probe", "n": len(keys),
        "us_per_call_ref": round(us_ref, 1),
        "pallas_matches_ref": bool((ref_out == pl_out).all()),
        "hit_rate": float(ref_out.mean()),
    })

    # masked knn distance
    nq, nr, d = (128, 512, 64) if fast else (512, 4096, 128)
    q = rng.normal(size=(nq, d)).astype(np.float32)
    r = rng.normal(size=(nr, d)).astype(np.float32)
    qm = (rng.random((nq, d)) > 0.3).astype(np.float32)
    rm = (rng.random((nr, d)) > 0.3).astype(np.float32)
    us_ref = _time(
        lambda: kops.masked_distance(q, qm, r, rm, impl="ref")
    )
    ref_d = np.asarray(kops.masked_distance(q, qm, r, rm, impl="ref"))
    pl_d = np.asarray(kops.masked_distance(q, qm, r, rm, impl="pallas"))
    finite = np.isfinite(ref_d)
    err = float(np.max(np.abs(ref_d[finite] - pl_d[finite])))
    rows.append({
        "kernel": "masked_knn_distance", "shape": f"{nq}x{nr}x{d}",
        "us_per_call_ref": round(us_ref, 1),
        "pallas_max_abs_err": err,
        "pallas_inf_match": bool(
            (np.isinf(ref_d) == np.isinf(pl_d)).all()
        ),
    })

    # hash join (the ⋈̂ / BF_Join core)
    sizes = [10**4, 10**5] if fast else [10**4, 10**5, 10**6, 10**7]
    for n in sizes:
        for dup in (1, 8):
            for miss_rate in (0.0, 0.5):
                build = np.repeat(
                    rng.integers(0, 1 << 40, max(n // dup, 1)), dup
                ).astype(np.int64)
                n_hit = int(len(build) * (1.0 - miss_rate))
                probe = np.concatenate([
                    rng.choice(build, n_hit),
                    rng.integers(1 << 41, 1 << 42, len(build) - n_hit),
                ]).astype(np.int64)
                rng.shuffle(probe)
                # impl pinned so a stray QUIP_JOIN_IMPL can't redirect the
                # oracle side of the comparison through the kernel path
                us_np = _time(lambda: multi_match(build, probe, impl="numpy"))
                us_ref_join = _time(
                    lambda: kops.hash_join_match(build, probe, impl="ref")
                )
                p0, b0 = multi_match(build, probe, impl="numpy")
                p1, b1 = kops.hash_join_match(build, probe, impl="ref")
                row = {
                    "kernel": "hash_join", "n_build": len(build),
                    "n_probe": len(probe), "dup": dup,
                    "miss_rate": miss_rate, "pairs": len(p0),
                    "us_per_call_numpy": round(us_np, 1),
                    "us_per_call_ref": round(us_ref_join, 1),
                    "ref_matches_numpy": bool(
                        np.array_equal(p0, p1) and np.array_equal(b0, b1)
                    ),
                }
                if n == sizes[0]:
                    p2, b2 = kops.hash_join_match(
                        build, probe, impl="pallas"
                    )
                    row["pallas_matches_numpy"] = bool(
                        np.array_equal(p0, p2) and np.array_equal(b0, b2)
                    )
                rows.append(row)

    # neighbour aggregation (KNN categorical mode / float mean)
    def _mode_loop(m):
        out = []
        for r_ in m:
            u, c = np.unique(r_, return_counts=True)
            out.append(u[np.argmax(c)])
        return np.asarray(out, dtype=np.float64)

    agg_shapes = [(1 << 12, 5, 64), (1 << 14, 9, 512)] if fast else [
        (1 << 12, 5, 64), (1 << 16, 9, 512), (1 << 18, 17, 4096),
    ]
    for b, k, vocab in agg_shapes:
        neigh = rng.integers(0, vocab, size=(b, k)).astype(np.int64)
        us_loop = _time(lambda: _mode_loop(neigh), reps=2)
        us_np = _time(
            lambda: kops.neighbor_aggregate(neigh, categorical=True,
                                            impl="numpy")
        )
        us_ref = _time(
            lambda: kops.neighbor_aggregate(neigh, categorical=True,
                                            impl="ref")
        )
        exp = _mode_loop(neigh)
        row = {
            "kernel": "neighbor_aggregate", "b": b, "k": k, "vocab": vocab,
            "us_per_call_loop": round(us_loop, 1),
            "us_per_call_numpy": round(us_np, 1),
            "us_per_call_ref": round(us_ref, 1),
            "numpy_matches_loop": bool(np.array_equal(
                kops.neighbor_aggregate(neigh, categorical=True,
                                        impl="numpy"), exp)),
            "ref_matches_loop": bool(np.array_equal(
                kops.neighbor_aggregate(neigh, categorical=True, impl="ref"),
                exp)),
        }
        if (b, k, vocab) == agg_shapes[0]:
            row["pallas_matches_loop"] = bool(np.array_equal(
                kops.neighbor_aggregate(neigh, categorical=True,
                                        impl="pallas"), exp))
        rows.append(row)

    # segment reductions (compiled grouped aggregates — docs/compiled.md)
    def _seg_loop(vals, seg, s, op):
        red = {"sum": np.sum, "min": np.min, "max": np.max}[op]
        ident = {"sum": 0, "min": np.iinfo(np.int64).max,
                 "max": np.iinfo(np.int64).min}[op]
        return np.asarray([
            red(vals[seg == i]) if (seg == i).any() else ident
            for i in range(s)
        ], dtype=np.int64)

    seg_shapes = [(1 << 14, 64), (1 << 16, 1024)] if fast else [
        (1 << 14, 64), (1 << 18, 1024), (1 << 20, 8192),
    ]
    for n, s in seg_shapes:
        seg = rng.integers(0, s, size=n).astype(np.int64)
        vals = rng.integers(-1000, 1000, size=n).astype(np.int64)
        for op in ("count", "sum", "max"):
            us_np = _time(
                lambda: kops.segment_reduce(vals, seg, s, op, impl="numpy")
            )
            us_ref = _time(
                lambda: kops.segment_reduce(vals, seg, s, op, impl="ref")
            )
            got_np = kops.segment_reduce(vals, seg, s, op, impl="numpy")
            got_ref = kops.segment_reduce(vals, seg, s, op, impl="ref")
            exp = _seg_loop(vals, seg, s, op) if op != "count" else \
                np.bincount(seg, minlength=s)
            row = {
                "kernel": "segment_reduce", "op": op, "n": n, "segments": s,
                "us_per_call_numpy": round(us_np, 1),
                "us_per_call_ref": round(us_ref, 1),
                "numpy_matches_loop": bool(np.array_equal(got_np, exp)),
                "ref_matches_numpy": bool(np.array_equal(got_ref, got_np)),
            }
            if (n, s) == seg_shapes[0]:
                got_pl = kops.segment_reduce(vals, seg, s, op, impl="pallas")
                row["pallas_matches_numpy"] = bool(
                    np.array_equal(got_pl, got_np)
                )
            rows.append(row)

    # end-to-end KNN impute batch (fit + one impute_attr flush)
    knn_shapes = [(2000, 8, 512)] if fast else [(2000, 8, 512), (20000, 16, 4096)]
    for n, d, batch in knn_shapes:
        for kind in ("int", "float"):
            specs = [ColumnSpec(f"B.c{i}", kind) for i in range(d)]
            data, miss = {}, {}
            for i, spec in enumerate(specs):
                v = rng.integers(0, 32, n).astype(np.int64)
                data[spec.name] = (
                    v.astype(np.float64) + 0.5 if kind == "float" else v
                )
                miss[spec.name] = rng.random(n) < 0.2
            table = MaskedRelation.from_columns(
                Schema("B", specs), data, missing=miss, base_table="B"
            )
            imp = KnnImputer(k=5)
            t_fit0 = time.perf_counter()
            imp.fit(table)
            fit_ms = (time.perf_counter() - t_fit0) * 1e3
            tids = np.nonzero(miss["B.c0"])[0][:batch].astype(np.int64)
            us = _time(lambda: imp.impute_attr(table, "B.c0", tids), reps=3)
            rows.append({
                "kernel": f"knn_impute_{kind}", "n": n, "d": d,
                "batch": len(tids), "fit_ms": round(fit_ms, 1),
                "us_per_call": round(us, 1),
                "us_per_value": round(us / max(len(tids), 1), 2),
            })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by = lambda name: [r for r in rows if r["kernel"] == name]
    join_rows = by("hash_join")
    agg_rows = by("neighbor_aggregate")
    biggest = max(join_rows, key=lambda r: (r["n_build"], r["dup"]))
    big_agg = max(agg_rows, key=lambda r: r["b"] * r["k"])
    knn_int = by("knn_impute_int")
    knn_flt = by("knn_impute_float")
    return {
        "bloom_pallas_ok": float(by("bloom_probe")[0]["pallas_matches_ref"]),
        "knn_pallas_err": by("masked_knn_distance")[0]["pallas_max_abs_err"],
        "join_ref_ok": float(
            all(r["ref_matches_numpy"] for r in join_rows)
        ),
        "join_pallas_ok": float(
            all(
                r["pallas_matches_numpy"]
                for r in join_rows
                if "pallas_matches_numpy" in r
            )
        ),
        "join_ref_us_max": biggest["us_per_call_ref"],
        "join_numpy_us_max": biggest["us_per_call_numpy"],
        "neighbor_agg_ok": float(
            all(
                r["numpy_matches_loop"] and r["ref_matches_loop"]
                and r.get("pallas_matches_loop", True)
                for r in agg_rows
            )
        ),
        "neighbor_agg_loop_us_max": big_agg["us_per_call_loop"],
        "neighbor_agg_numpy_us_max": big_agg["us_per_call_numpy"],
        "neighbor_agg_speedup": round(
            big_agg["us_per_call_loop"] / max(big_agg["us_per_call_numpy"], 1e-9), 1
        ),
        "knn_impute_int_us_per_value": knn_int[-1]["us_per_value"],
        "knn_impute_float_us_per_value": knn_flt[-1]["us_per_value"],
        "segment_ok": float(
            all(
                r["numpy_matches_loop"] and r["ref_matches_numpy"]
                and r.get("pallas_matches_numpy", True)
                for r in by("segment_reduce")
            )
        ),
        "segment_numpy_us_max": max(
            r["us_per_call_numpy"] for r in by("segment_reduce")
        ),
        "segment_ref_us_max": max(
            r["us_per_call_ref"] for r in by("segment_reduce")
        ),
    }
