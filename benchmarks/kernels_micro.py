"""Kernel microbenchmarks: bloom probe + masked-KNN distance — wall time of
the jitted ref path on CPU and allclose vs oracle for the Pallas kernels in
interpret mode (the perf numbers that matter are the dry-run rooflines; this
is the correctness+overhead record)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.hashing import fold64, hash_positions_np

NAME = "kernels_micro"


def _time(fn, *args, reps=5):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jnp.asarray(out).block_until_ready() if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = True) -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # bloom probe
    log2m, k = 20, 4
    bits = np.zeros((1 << log2m) // 32, dtype=np.uint32)
    keys = rng.integers(0, 1 << 40, 1 << 14).astype(np.int64)
    pos = hash_positions_np(keys[: 1 << 13], k, log2m).ravel()
    np.bitwise_or.at(bits, pos >> 5, np.uint32(1) << (pos & 31))
    folded = fold64(keys)
    us_ref = _time(
        lambda: kops.bloom_probe(jnp.asarray(bits), jnp.asarray(folded),
                                 num_hashes=k, log2m=log2m, impl="ref")
    )
    ref_out = np.asarray(kref.bloom_probe_ref(
        jnp.asarray(bits), jnp.asarray(folded), k, log2m))
    pl_out = np.asarray(kops.bloom_probe(
        jnp.asarray(bits), jnp.asarray(folded), num_hashes=k, log2m=log2m,
        impl="pallas"))
    rows.append({
        "kernel": "bloom_probe", "n": len(keys),
        "us_per_call_ref": round(us_ref, 1),
        "pallas_matches_ref": bool((ref_out == pl_out).all()),
        "hit_rate": float(ref_out.mean()),
    })

    # masked knn distance
    nq, nr, d = (128, 512, 64) if fast else (512, 4096, 128)
    q = rng.normal(size=(nq, d)).astype(np.float32)
    r = rng.normal(size=(nr, d)).astype(np.float32)
    qm = (rng.random((nq, d)) > 0.3).astype(np.float32)
    rm = (rng.random((nr, d)) > 0.3).astype(np.float32)
    us_ref = _time(
        lambda: kops.masked_distance(q, qm, r, rm, impl="ref")
    )
    ref_d = np.asarray(kops.masked_distance(q, qm, r, rm, impl="ref"))
    pl_d = np.asarray(kops.masked_distance(q, qm, r, rm, impl="pallas"))
    finite = np.isfinite(ref_d)
    err = float(np.max(np.abs(ref_d[finite] - pl_d[finite])))
    rows.append({
        "kernel": "masked_knn_distance", "shape": f"{nq}x{nr}x{d}",
        "us_per_call_ref": round(us_ref, 1),
        "pallas_max_abs_err": err,
        "pallas_inf_match": bool(
            (np.isinf(ref_d) == np.isinf(pl_d)).all()
        ),
    })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    return {
        "bloom_pallas_ok": float(rows[0]["pallas_matches_ref"]),
        "knn_pallas_err": rows[1]["pallas_max_abs_err"],
    }
