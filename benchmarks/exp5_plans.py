"""Experiment 5 (paper Fig. 13): QUIP robustness to the external plan —
ImputeDB-style joint plan vs PostgreSQL-style (naive) plan."""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import run_workload
from repro.data.queries import workload
from repro.data.synthetic import cdc_dataset, wifi_dataset

NAME = "exp5_plans"


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    nq = 5 if fast else 20
    for ds, tables in (("wifi", wifi_dataset()[0]),
                       ("cdc", cdc_dataset()[0])):
        queries = workload(ds, tables, kind="random", n_queries=nq, seed=23)
        for planner in ("imputedb", "naive"):
            for strat in ("lazy", "adaptive"):
                res = run_workload(
                    tables, queries, "knn", strategies=(strat,),
                    planner=planner,
                )[strat]
                rows.append({
                    "dataset": ds, "planner": planner, "strategy": strat,
                    "imputations": res.imputations,
                    "runtime_s": round(res.wall_seconds, 4),
                })
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    out = {}
    for ds in ("wifi", "cdc"):
        for strat in ("lazy", "adaptive"):
            sub = {r["planner"]: r for r in rows
                   if r["dataset"] == ds and r["strategy"] == strat}
            if len(sub) == 2:
                out[f"{ds}/{strat}/naive_vs_imputedb_runtime"] = round(
                    sub["naive"]["runtime_s"]
                    / max(sub["imputedb"]["runtime_s"], 1e-9), 3
                )
                # lazy imputations are plan-independent (paper observation)
                out[f"{ds}/{strat}/naive_vs_imputedb_imputations"] = round(
                    sub["naive"]["imputations"]
                    / max(sub["imputedb"]["imputations"], 1), 3
                )
    return out
