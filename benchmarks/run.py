"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only exp1,...]

Prints a per-experiment summary plus a ``name,value`` derived-metrics CSV,
and writes benchmarks/results.json.  Each experiment also appends one
JSONL line — timestamp, scale, wall seconds, derived metrics — to
``benchmarks/history/<name>.jsonl`` so runs accumulate a machine-readable
timing history (``--history-dir`` to relocate, ``--no-history`` to skip).
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import time

from benchmarks import (
    exp1_runtime_imputations,
    exp2_quality,
    exp3_selectivity,
    exp4_bloom,
    exp5_plans,
    exp6_minmax,
    exp7_query_baseline,
    exp8_serving,
    exp9_result_cache,
    exp10_qos,
    exp11_workers,
    exp12_compiled,
    exp13_obs,
    exp14_ivm,
    kernels_micro,
)

MODULES = [
    exp1_runtime_imputations,
    exp2_quality,
    exp3_selectivity,
    exp4_bloom,
    exp5_plans,
    exp6_minmax,
    exp7_query_baseline,
    exp8_serving,
    exp9_result_cache,
    exp10_qos,
    exp11_workers,
    exp12_compiled,
    exp13_obs,
    exp14_ivm,
    kernels_micro,
]


def _append_history(history_dir: str, name: str, entry: dict) -> None:
    """One JSONL line per run per experiment — append-only, best-effort
    (a read-only checkout must not fail the benchmark)."""
    try:
        os.makedirs(history_dir, exist_ok=True)
        path = os.path.join(history_dir, f"{name}.jsonl")
        with open(path, "a") as fh:
            fh.write(json.dumps(entry, default=str) + "\n")
    except OSError as e:  # pragma: no cover - exotic fs only
        print(f"history append failed for {name}: {e}", file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale workloads (slower)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="benchmarks/results.json")
    ap.add_argument("--history-dir", default="benchmarks/history",
                    help="where per-experiment timing history accumulates")
    ap.add_argument("--no-history", action="store_true",
                    help="do not append to the timing history")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    all_results = {}
    failures = []
    for mod in MODULES:
        if only and mod.NAME not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(fast=not args.full)
            der = mod.derived(rows)
            wall = time.time() - t0
            all_results[mod.NAME] = {"rows": rows, "derived": der}
            print(f"\n=== {mod.NAME} ({wall:.1f}s) ===")
            for k, v in der.items():
                print(f"{mod.NAME}/{k},{v}")
            if not args.no_history:
                _append_history(args.history_dir, mod.NAME, {
                    "ts": datetime.datetime.now(
                        datetime.timezone.utc
                    ).isoformat(timespec="seconds"),
                    "fast": not args.full,
                    "wall_s": round(wall, 3),
                    "derived": der,
                })
        except Exception as e:  # noqa: BLE001
            failures.append((mod.NAME, repr(e)))
            print(f"\n=== {mod.NAME} FAILED: {e!r} ===")
            import traceback

            traceback.print_exc()
    try:
        with open(args.out, "w") as f:
            json.dump(all_results, f, indent=2, default=str)
        print(f"\nwrote {args.out}")
    except OSError:
        pass
    print(f"{len(all_results)} experiments ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
