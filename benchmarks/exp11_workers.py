"""Experiment 11: the threaded morsel worker pool — throughput scaling
and the bit-identical-answers invariant under real concurrency.

Setup: the exp8 skewed multi-tenant wifi stream, served by QuipService
with ``workers`` ∈ {1, 2, 4} (threads pulling morsel steps through the
scheduler's checkout/checkin split) against a cold serial replay.

Pure-Python morsel stepping is GIL-bound, so raw relational work cannot
scale across threads — what *does* scale is imputation inference, which
in production blocks on a model server / native kernel that releases
the GIL.  The workload therefore uses a KNN imputer wrapped with a
per-invocation ``time.sleep`` (an inference-latency model that releases
the GIL exactly like native inference would), and the scaling assertion
is on that regime: **QPS at 4 workers ≥ 2× QPS at 1 worker**.

Acceptance invariants (CI runs this experiment as a smoke check):

* every pool configuration's answers are bit-identical to the cold
  serial replay — including the full scheduler-policy × shared-impute
  matrix at 4 workers;
* throughput scales ≥ 2× from 1 to 4 workers.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.executor import execute_quip
from repro.data.queries import serving_workload
from repro.data.synthetic import wifi_dataset
from repro.imputers.base import Imputer, ImputationService
from repro.imputers.knn import KnnImputer
from repro.service import QuipService

NAME = "exp11_workers"

STRATEGY = "lazy"
MORSEL_ROWS = 1024
SLEEP_S = 0.040  # per impute_attr invocation — the GIL-releasing part
WORKER_COUNTS = (1, 2, 4)
POLICIES = ("rr", "wfq", "deadline")


class _InferenceLatencyImputer(Imputer):
    """KNN with a fixed per-invocation sleep, modeling a model server /
    native inference call that releases the GIL while it runs."""

    def __init__(self, sleep_s: float = SLEEP_S):
        self._inner = KnnImputer(k=5, cost_per_value=2e-3)
        self._sleep_s = sleep_s
        self.blocking = self._inner.blocking
        self.cost_per_value = self._inner.cost_per_value
        self.train_cost = self._inner.train_cost

    def fit(self, table) -> None:
        self._inner.fit(table)

    def impute_attr(self, table, attr: str, tids: np.ndarray) -> np.ndarray:
        time.sleep(self._sleep_s)
        return self._inner.impute_attr(table, attr, tids)


def _factory() -> Imputer:
    return _InferenceLatencyImputer()


def _serial(stream, tables) -> Dict:
    answers = []
    t0 = time.perf_counter()
    for _tenant, q in stream:
        eng = ImputationService(
            {t: tables[t].copy() for t in q.tables}, default=_factory
        )
        res = execute_quip(q, tables, eng, strategy=STRATEGY,
                           morsel_rows=MORSEL_ROWS)
        answers.append(sorted(res.answer_tuples()))
    wall = time.perf_counter() - t0
    return {
        "mode": "serial", "workers": 0, "policy": "-", "shared": 0,
        "queries": len(stream), "wall_s": round(wall, 4),
        "qps": round(len(stream) / wall, 2), "_answers": answers,
    }


def _pooled(stream, tables, workers: int, policy: str = "rr",
            shared: bool = False) -> Dict:
    # result cache off: repeated templates must re-execute, or the pool
    # has nothing to parallelize and QPS measures cache lookups
    svc = QuipService(
        tables, _factory, strategy=STRATEGY, morsel_rows=MORSEL_ROWS,
        shared_impute=shared, max_inflight=8, result_cache_size=0,
        scheduler_policy=policy, workers=workers,
    )
    t0 = time.perf_counter()
    tickets = [svc.submit(q, tenant=tenant) for tenant, q in stream]
    svc.run_until_idle()
    wall = time.perf_counter() - t0
    answers = [sorted(svc.answers(t)) for t in tickets]
    summary = svc.summary()
    svc.close()
    assert summary["failed"] == 0, f"pool run failed queries: {summary}"
    return {
        "mode": f"pool{workers}_{policy}" + ("_shared" if shared else ""),
        "workers": workers, "policy": policy, "shared": int(shared),
        "queries": len(stream), "wall_s": round(wall, 4),
        "qps": round(len(stream) / wall, 2), "_answers": answers,
    }


def run(fast: bool = True) -> List[Dict]:
    if fast:
        tables, _ = wifi_dataset(n_users=100, n_wifi=1200, n_occ=600)
        n_queries = 16
    else:
        tables, _ = wifi_dataset(n_users=150, n_wifi=2000, n_occ=1000)
        n_queries = 32
    stream = list(serving_workload("wifi", tables, n_queries=n_queries,
                                   n_templates=6, n_tenants=4, seed=5))

    rows = [_serial(stream, tables)]
    # throughput scaling: isolation + rr so the only cross-thread
    # serialization is the scheduler checkout, not the shared store
    for workers in WORKER_COUNTS:
        rows.append(_pooled(stream, tables, workers))
    # answer matrix at 4 workers: every policy × sharing mode must stay
    # bit-identical to the cold serial replay
    for policy in POLICIES:
        for shared in (False, True):
            if policy == "rr" and not shared:
                continue  # already measured in the scaling sweep
            rows.append(_pooled(stream, tables, 4, policy, shared))

    serial_answers = rows[0].pop("_answers")
    for r in rows[1:]:
        r["answers_match_serial"] = int(r.pop("_answers") == serial_answers)
    return rows


def derived(rows: List[Dict]) -> Dict[str, float]:
    by_mode = {r["mode"]: r for r in rows}
    qps1 = by_mode["pool1_rr"]["qps"]
    qps2 = by_mode["pool2_rr"]["qps"]
    qps4 = by_mode["pool4_rr"]["qps"]
    matches = [r["answers_match_serial"] for r in rows[1:]]
    # acceptance invariants
    assert all(matches), (
        "pool answers diverged from serial replay: "
        f"{[r['mode'] for r in rows[1:] if not r['answers_match_serial']]}"
    )
    assert qps4 >= 2.0 * qps1, (
        f"worker pool failed to scale: qps1={qps1} qps4={qps4} "
        f"({qps4 / max(qps1, 1e-9):.2f}x < 2x)"
    )
    return {
        "workers_qps_serial": by_mode["serial"]["qps"],
        "workers_qps_1": qps1,
        "workers_qps_2": qps2,
        "workers_qps_4": qps4,
        "workers_scaling_4v1": round(qps4 / max(qps1, 1e-9), 2),
        "workers_scaling_2v1": round(qps2 / max(qps1, 1e-9), 2),
        "workers_answers_match": float(all(matches)),
        "workers_configs_verified": float(len(matches)),
    }
