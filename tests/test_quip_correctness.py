"""QUIP correctness: every strategy must return exactly the offline answer.

The property harness generates ground-truth (complete) tables, masks random
cells, and gives QUIP an oracle imputer that returns the ground truth — so
for any query/plan/strategy the answer multiset must equal evaluation over
the clean tables (paper §3 "lazy but correct").
"""

from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from paper_example import EXPECTED, oracle_engine, paper_query, paper_tables
from repro.core.executor import (
    evaluate_clean,
    execute_offline,
    execute_quip,
    make_plan,
)
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import ImputationEngine, Imputer

STRATEGIES = ["lazy", "adaptive", "eager"]


# --------------------------------------------------------------------------- #
# paper's motivating example
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("morsel", [2, 3, 100])
def test_paper_example_answer(strategy, morsel):
    tables = paper_tables()
    q = paper_query()
    eng = oracle_engine({t: tables[t].copy() for t in tables})
    res = execute_quip(q, tables, eng, strategy=strategy, morsel_rows=morsel)
    assert res.answer_tuples() == EXPECTED


def test_paper_example_imputation_counts():
    """Paper §1.2: the preserving strategy answers with 3 imputations; the
    offline baseline imputes all 9 missing values."""
    tables = paper_tables()
    q = paper_query()
    eng = oracle_engine({t: tables[t].copy() for t in tables})
    lazy = execute_quip(q, tables, eng, strategy="lazy", morsel_rows=100)
    assert lazy.counters.imputations == 3

    eng2 = oracle_engine({t: tables[t].copy() for t in tables})
    off = execute_offline(q, tables, eng2)
    assert off.counters.imputations == 9
    assert off.answer_tuples() == EXPECTED


@pytest.mark.parametrize("planner", ["imputedb", "naive"])
def test_paper_example_plan_robustness(planner):
    """Paper Experiment 5: QUIP is correct on either external plan."""
    tables = paper_tables()
    q = paper_query()
    plan = make_plan(q, tables, planner=planner)
    eng = oracle_engine({t: tables[t].copy() for t in tables})
    res = execute_quip(q, tables, eng, plan=plan, strategy="adaptive")
    assert res.answer_tuples() == EXPECTED


# --------------------------------------------------------------------------- #
# property harness
# --------------------------------------------------------------------------- #
class GroundTruthImputer(Imputer):
    """Returns the pre-masking ground truth (deterministic oracle)."""

    blocking = False
    cost_per_value = 1e-4

    def __init__(self, truth: dict):
        self.truth = truth  # attr -> ndarray of true values

    def impute_attr(self, table, attr, tids):
        return self.truth[attr][np.asarray(tids, dtype=np.int64)]


def _build_instance(rng: np.random.Generator, n_tables: int, rows: int,
                    missing_rate: float, key_card: int):
    """Chain-join schema R0 ⋈ R1 ⋈ ... with one value column each."""
    tables, clean, truth = {}, {}, {}
    for i in range(n_tables):
        name = f"R{i}"
        cols = [ColumnSpec(f"{name}.k{i}", "int")]
        if i + 1 < n_tables:
            cols.append(ColumnSpec(f"{name}.k{i+1}", "int"))
        cols.append(ColumnSpec(f"{name}.v", "int"))
        schema = Schema(name, cols)
        data, miss = {}, {}
        n = rows
        for c in cols:
            vals = rng.integers(0, key_card, size=n).astype(np.int64)
            truth[c.name] = vals
            m = rng.random(n) < missing_rate
            data[c.name] = np.where(m, 0, vals)
            miss[c.name] = m
        tables[name] = MaskedRelation.from_columns(
            schema, data, missing=miss, base_table=name
        )
        clean[name] = MaskedRelation.from_columns(
            schema, {c.name: truth[c.name] for c in cols}, base_table=name
        )
    return tables, clean, truth


def _rand_query(rng: np.random.Generator, n_tables: int, key_card: int,
                with_agg: bool):
    joins = tuple(
        JoinPredicate(f"R{i}.k{i+1}", f"R{i+1}.k{i+1}")
        for i in range(n_tables - 1)
    )
    sels = []
    for i in range(n_tables):
        if rng.random() < 0.7:
            op = rng.choice(["<=", ">=", "==", "in"])
            if op == "in":
                val = frozenset(
                    rng.integers(0, key_card, size=3).tolist()
                )
            else:
                val = int(rng.integers(0, key_card))
            attr = f"R{i}.v" if rng.random() < 0.7 else f"R{i}.k{i}"
            sels.append(SelectionPredicate(attr, op, val))
    agg = None
    projection = tuple(f"R{i}.v" for i in range(n_tables))
    if with_agg:
        op = rng.choice(["count", "sum", "avg", "max", "min"])
        gb = "R0.v" if rng.random() < 0.5 else None
        agg = Aggregate(op, f"R{n_tables-1}.v", group_by=gb)
        projection = ()
    return Query(
        tables=tuple(f"R{i}" for i in range(n_tables)),
        selections=tuple(sels),
        joins=joins,
        projection=projection,
        aggregate=agg,
    )


def _answers_match(a, b, float_cols=False):
    if float_cols:
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert len(x) == len(y)
            for u, v in zip(x, y):
                if u is None or v is None:
                    assert u == v
                else:
                    np.testing.assert_allclose(u, v, rtol=1e-9, atol=1e-9)
    else:
        assert a == b


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_tables=st.integers(2, 3),
    rows=st.integers(5, 60),
    missing_pct=st.integers(0, 60),
    key_card=st.integers(2, 12),
    strategy=st.sampled_from(STRATEGIES),
    with_agg=st.booleans(),
    morsel=st.sampled_from([7, 64, 4096]),
)
def test_quip_equals_offline_property(
    seed, n_tables, rows, missing_pct, key_card, strategy, with_agg, morsel
):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(
        rng, n_tables, rows, missing_pct / 100.0, key_card
    )
    q = _rand_query(rng, n_tables, key_card, with_agg)
    expected = evaluate_clean(q, clean).to_sorted_tuples()

    eng = ImputationEngine(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )
    res = execute_quip(q, tables, eng, strategy=strategy, morsel_rows=morsel)
    _answers_match(
        res.answer_tuples(), expected,
        float_cols=with_agg and q.aggregate.op == "avg",
    )
    # QUIP never imputes more values than exist
    total_missing = sum(
        tables[t].is_missing(a).sum()
        for t in tables for a in tables[t].column_names()
    )
    assert res.counters.imputations <= total_missing


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), strategy=st.sampled_from(STRATEGIES))
def test_minmax_optimization_correct(seed, strategy):
    """Paper §9.3 Table 7: the MIN/MAX pushdown must not change answers."""
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, 2, 50, 0.3, 8)
    q = Query(
        tables=("R0", "R1"),
        selections=(SelectionPredicate("R0.v", "<=", 6),),
        joins=(JoinPredicate("R0.k1", "R1.k1"),),
        projection=(),
        aggregate=Aggregate("max", "R1.v"),
    )
    expected = evaluate_clean(q, clean).to_sorted_tuples()
    for minmax in (True, False):
        eng = ImputationEngine(
            {t: tables[t].copy() for t in tables},
            default=lambda: GroundTruthImputer(truth),
        )
        res = execute_quip(
            q, tables, eng, strategy=strategy, morsel_rows=16, minmax_opt=minmax
        )
        assert res.answer_tuples() == expected


def test_aggregate_over_all_absent_is_null():
    """Regression: min/max over rows whose agg attr is entirely absent
    (outer-pad NULLs) must answer NULL, not push NaN through the int cast
    (which silently yielded INT64_MIN).  Same for empty group-by groups."""
    from repro.core.executor import _aggregate
    from repro.core.plan import Aggregate as Agg

    schema = Schema("T", [ColumnSpec("T.g", "int"), ColumnSpec("T.v", "int")])
    rel = MaskedRelation.from_columns(
        schema, {"T.g": np.array([1, 1, 2]), "T.v": np.array([0, 0, 5])},
        base_table="T",
    )
    rel.absent["T.v"][:2] = True  # group 1 has zero non-NULL inputs
    out = _aggregate(rel, Agg("min", "T.v"))
    assert out.to_sorted_tuples() == [(5,)]
    rel_all = rel.filter(np.array([True, True, False]))
    out = _aggregate(rel_all, Agg("min", "T.v"))
    assert out.to_sorted_tuples() == [(None,)]  # NULL, not INT64_MIN
    out = _aggregate(rel, Agg("count", "T.v", group_by="T.g"))
    assert out.to_sorted_tuples() == [(1, 0), (2, 1)]  # COUNT skips NULLs
    out = _aggregate(rel, Agg("max", "T.v", group_by="T.g"))
    assert out.to_sorted_tuples() == [(1, None), (2, 5)]


def test_lazy_never_more_imputations_than_eager_on_paper():
    tables = paper_tables()
    q = paper_query()
    eng_l = oracle_engine({t: tables[t].copy() for t in tables})
    eng_e = oracle_engine({t: tables[t].copy() for t in tables})
    lazy = execute_quip(q, tables, eng_l, strategy="lazy")
    eager = execute_quip(q, tables, eng_e, strategy="eager")
    assert lazy.counters.imputations <= eager.counters.imputations


def test_quip_with_pallas_bloom_probe():
    """End-to-end QUIP run using the Pallas bloom-probe kernel (interpret
    mode) in the semi-join filters / BF_Join path."""
    tables = paper_tables()
    q = paper_query()
    eng = oracle_engine({t: tables[t].copy() for t in tables})
    res = execute_quip(q, tables, eng, strategy="adaptive",
                       bloom_impl="pallas")
    assert res.answer_tuples() == EXPECTED
