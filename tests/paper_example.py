"""The paper's motivating example (Tables 1–3 + the Figure-1 query) as a
shared fixture.  String values are dictionary-encoded; the mapping below
mirrors the paper exactly, including the ground-truth imputations N1–N9."""

from __future__ import annotations

import numpy as np

from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import Imputer

# dictionary encodings
MACS = {"4fep": 1, "3a4b": 2, "25ya": 3, "fff1": 4, "9aa4": 5}
TIMES = {"12pm": 12, "1pm": 13, "2pm": 14, "3pm": 15}
BUILDINGS = {"DBH": 1, "ICS": 2}
RS = frozenset({2065, 2011, 2082, 2035, 2206})

# ground-truth values for N1..N9 (paper blue values)
TRUTH = {
    "N1": 3001, "N2": 2082, "N3": 2099,           # T.room_location
    "N4": BUILDINGS["DBH"], "N6": BUILDINGS["ICS"], "N7": BUILDINGS["DBH"],
    "N5": 2,                                       # S.floor
    "N8": MACS["fff1"], "N9": MACS["9aa4"],        # U.mac_address
}


def paper_tables():
    t_schema = Schema("T", [
        ColumnSpec("T.mac_address", "int"),
        ColumnSpec("T.time", "int"),
        ColumnSpec("T.room_location", "int"),
    ])
    t = MaskedRelation.from_columns(
        t_schema,
        {
            "T.mac_address": [MACS["4fep"], MACS["3a4b"], MACS["4fep"],
                              MACS["25ya"], MACS["fff1"], MACS["9aa4"]],
            "T.time": [TIMES["12pm"], TIMES["2pm"], TIMES["1pm"],
                       TIMES["3pm"], TIMES["1pm"], TIMES["2pm"]],
            "T.room_location": [2206, 0, 0, 0, 3119, 2214],
        },
        missing={"T.room_location": [False, True, True, True, False, False]},
        base_table="T",
    )
    s_schema = Schema("S", [
        ColumnSpec("S.room", "int"),
        ColumnSpec("S.floor", "int"),
        ColumnSpec("S.building", "int"),
    ])
    s = MaskedRelation.from_columns(
        s_schema,
        {
            "S.room": [2214, 2206, 2011, 3119, 2065],
            "S.floor": [2, 0, 2, 3, 2],
            "S.building": [0, BUILDINGS["DBH"], BUILDINGS["DBH"], 0, 0],
        },
        missing={
            "S.floor": [False, True, False, False, False],
            "S.building": [True, False, False, True, True],
        },
        base_table="S",
    )
    u_schema = Schema("U", [
        ColumnSpec("U.name", "int"),
        ColumnSpec("U.email", "int"),
        ColumnSpec("U.mac_address", "int"),
    ])
    u = MaskedRelation.from_columns(
        u_schema,
        {
            "U.name": [1, 2, 3],  # Mike, Robert, John
            "U.email": [1, 2, 3],
            "U.mac_address": [0, MACS["4fep"], 0],
        },
        missing={"U.mac_address": [True, False, True]},
        base_table="U",
    )
    return {"T": t, "S": s, "U": u}


def paper_query() -> Query:
    return Query(
        tables=("T", "S", "U"),
        selections=(
            SelectionPredicate("S.building", "==", BUILDINGS["DBH"]),
            SelectionPredicate("T.room_location", "in", RS),
        ),
        joins=(
            JoinPredicate("T.mac_address", "U.mac_address"),
            JoinPredicate("T.room_location", "S.room"),
        ),
        projection=("U.name", "T.time", "T.room_location"),
    )


class OracleImputer(Imputer):
    """Imputes the paper's ground-truth values (the blue bracket values)."""

    blocking = False
    cost_per_value = 1e-3

    GROUND = {
        ("T", "T.room_location"): {1: TRUTH["N1"], 2: TRUTH["N2"], 3: TRUTH["N3"]},
        ("S", "S.building"): {0: TRUTH["N4"], 3: TRUTH["N6"], 4: TRUTH["N7"]},
        ("S", "S.floor"): {1: TRUTH["N5"]},
        ("U", "U.mac_address"): {0: TRUTH["N8"], 2: TRUTH["N9"]},
    }

    def __init__(self, table_name: str):
        self.table_name = table_name

    def impute_attr(self, table, attr, tids):
        mapping = self.GROUND.get((self.table_name, attr), {})
        return np.asarray([mapping.get(int(t), 0) for t in tids], dtype=np.int64)


def oracle_engine(tables):
    """Engine with per-table oracle imputers."""
    from repro.imputers.base import ImputationEngine

    class _PerTable(Imputer):
        blocking = False
        cost_per_value = 1e-3

        def impute_attr(self, table, attr, tids):
            tname = attr.split(".")[0]
            return OracleImputer(tname).impute_attr(table, attr, tids)

    return ImputationEngine(tables, default=_PerTable)


# Expected answer (paper Fig. 6-g): (Robert=2, 12pm=12, 2206)
EXPECTED = [(2, 12, 2206)]
