"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracles in repro.kernels.ref, plus hypothesis property tests for
the bloom filter's no-false-negative invariant."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bloom import BloomFilter
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.bloom_probe import bloom_probe_pallas
from repro.kernels.hashing import fold64, hash_positions_np
from repro.kernels.knn_distance import masked_distance_pallas


# --------------------------------------------------------------------------- #
# bloom probe kernel
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("log2m", [14, 18, 20])
@pytest.mark.parametrize("num_hashes", [2, 4, 6])
@pytest.mark.parametrize("n", [1, 7, 1024, 5000])
def test_bloom_probe_pallas_matches_ref(log2m, num_hashes, n):
    rng = np.random.default_rng(log2m * 100 + num_hashes * 10 + n)
    bits = rng.integers(0, 2**32, (1 << log2m) // 32, dtype=np.uint32)
    keys = rng.integers(-(2**62), 2**62, n).astype(np.int64)
    folded = jnp.asarray(fold64(keys))
    bits_j = jnp.asarray(bits)
    ref = np.asarray(kref.bloom_probe_ref(bits_j, folded, num_hashes, log2m))
    pl = np.asarray(
        bloom_probe_pallas(bits_j, folded, num_hashes=num_hashes,
                           log2m=log2m, interpret=True)
    )
    np.testing.assert_array_equal(ref, pl)


@settings(max_examples=20, deadline=None)
@given(
    keys=st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=200),
    probes=st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=200),
)
def test_bloom_no_false_negatives(keys, probes):
    bf = BloomFilter("x", log2m=16, num_hashes=4)
    bf.insert(np.asarray(keys, dtype=np.int64))
    bf.mark_complete()
    out = bf.might_contain(np.asarray(keys, dtype=np.int64))
    assert out.all(), "bloom filter must never produce false negatives"
    # probes of non-inserted keys may collide but mostly miss
    out2 = bf.might_contain(np.asarray(probes, dtype=np.int64))
    inserted = set(keys)
    for p, hit in zip(probes, out2):
        if p in inserted:
            assert hit


# --------------------------------------------------------------------------- #
# masked knn distance kernel
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("nq,nr,d", [
    (1, 1, 1), (3, 5, 7), (64, 64, 32), (130, 200, 96), (128, 256, 128),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_masked_distance_pallas_matches_ref(nq, nr, d, dtype):
    rng = np.random.default_rng(nq * 1000 + nr + d)
    q = rng.normal(size=(nq, d)).astype(dtype)
    r = rng.normal(size=(nr, d)).astype(dtype)
    qm = (rng.random((nq, d)) > 0.35).astype(dtype)
    rm = (rng.random((nr, d)) > 0.35).astype(dtype)
    ref = np.asarray(kref.masked_distance_ref(
        jnp.asarray(q), jnp.asarray(qm), jnp.asarray(r), jnp.asarray(rm)))
    pl = np.asarray(masked_distance_pallas(
        jnp.asarray(q), jnp.asarray(qm), jnp.asarray(r), jnp.asarray(rm),
        interpret=True))
    assert ref.shape == pl.shape == (nq, nr)
    finite = np.isfinite(ref)
    np.testing.assert_array_equal(finite, np.isfinite(pl))
    np.testing.assert_allclose(ref[finite], pl[finite], rtol=2e-4, atol=2e-4)


def test_masked_knn_neighbours_agree():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(32, 24)).astype(np.float32)
    r = rng.normal(size=(100, 24)).astype(np.float32)
    qm = (rng.random((32, 24)) > 0.3).astype(np.float32)
    rm = (rng.random((100, 24)) > 0.3).astype(np.float32)
    d_ref, i_ref = kref.masked_knn_ref(q, qm, r, rm, k=5)
    d_pl, i_pl = kops.masked_knn(q, qm, r, rm, k=5, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(d_ref), np.asarray(d_pl), rtol=1e-3, atol=1e-3
    )
    # neighbour sets may differ only at distance ties
    same = np.asarray(i_ref) == np.asarray(i_pl)
    frac = same.mean()
    assert frac > 0.95


def test_hash_positions_consistent_numpy_vs_jnp():
    keys = np.array([0, 1, -1, 2**40, -(2**40), 12345], dtype=np.int64)
    pos_np = hash_positions_np(keys, 4, 20)
    folded = jnp.asarray(fold64(keys))
    bits = jnp.zeros((1 << 20) // 32, dtype=jnp.uint32)
    # insert via numpy positions, probe via jnp path: full agreement
    arr = np.zeros((1 << 20) // 32, dtype=np.uint32)
    np.bitwise_or.at(arr, pos_np.ravel() >> 5,
                     np.uint32(1) << (pos_np.ravel() & 31))
    hit = kref.bloom_probe_ref(jnp.asarray(arr), folded, 4, 20)
    assert np.asarray(hit).all()


# --------------------------------------------------------------------------- #
# hash-join build/probe kernel pair
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n,card", [(1, 1), (37, 5), (300, 40), (1000, 10**9)])
def test_hash_join_build_table_invariants(n, card):
    from repro.kernels.hash_join import hash_join_build_pallas, table_log2cap

    rng = np.random.default_rng(n)
    keys = rng.integers(-card, card, n).astype(np.int64)
    folded = fold64(keys)
    log2cap = table_log2cap(n)
    slot_key, slot_idx = hash_join_build_pallas(
        jnp.asarray(folded), log2cap=log2cap, interpret=True
    )
    slot_key, slot_idx = np.asarray(slot_key), np.asarray(slot_idx)
    occupied = slot_idx >= 0
    # every build row in exactly one slot, carrying its own folded key
    assert int(occupied.sum()) == n
    assert sorted(slot_idx[occupied].tolist()) == list(range(n))
    np.testing.assert_array_equal(slot_key[occupied], folded[slot_idx[occupied]])


@pytest.mark.parametrize("nb,np_,card", [(64, 256, 7), (500, 100, 3), (200, 200, 10**9)])
def test_hash_join_probe_pallas_matches_ref(nb, np_, card):
    from repro.kernels.hash_join import (
        hash_join_build_pallas,
        hash_join_probe_pallas,
        table_log2cap,
    )

    rng = np.random.default_rng(nb * 1000 + np_)
    build = fold64(rng.integers(-card, card, nb).astype(np.int64))
    probe = fold64(rng.integers(-card, card, np_).astype(np.int64))
    max_dup = int(np.unique(build, return_counts=True)[1].max())
    c_ref, m_ref = kref.hash_join_ref(
        jnp.asarray(build), jnp.asarray(probe), max_dup
    )
    log2cap = table_log2cap(nb)
    slot_key, slot_idx = hash_join_build_pallas(
        jnp.asarray(build), log2cap=log2cap, interpret=True
    )
    c_pl, m_pl = hash_join_probe_pallas(
        slot_key, slot_idx, jnp.asarray(probe),
        log2cap=log2cap, max_dup=max_dup, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pl))
    np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m_pl))


# --------------------------------------------------------------------------- #
# neighbour aggregation kernels (KNN mean / categorical mode)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,k,classes", [
    (1, 1, 1), (7, 3, 5), (128, 5, 130), (200, 9, 260), (130, 8, 1),
])
def test_neighbor_mode_pallas_matches_ref(b, k, classes):
    from repro.kernels.neighbor_agg import neighbor_mode_pallas

    rng = np.random.default_rng(b * 100 + k * 10 + classes)
    codes = rng.integers(0, classes, size=(b, k)).astype(np.int32)
    ref = np.asarray(kref.neighbor_mode_ref(jnp.asarray(codes), classes))
    pl = np.asarray(neighbor_mode_pallas(
        jnp.asarray(codes), num_classes=classes, interpret=True
    ))
    np.testing.assert_array_equal(ref, pl)


@pytest.mark.parametrize("b,k", [(1, 1), (5, 4), (128, 5), (300, 9)])
def test_neighbor_mean_pallas_matches_ref(b, k):
    from repro.kernels.neighbor_agg import neighbor_mean_pallas

    rng = np.random.default_rng(b + k)
    vals = rng.normal(size=(b, k)).astype(np.float32)
    ref = np.asarray(kref.neighbor_mean_ref(jnp.asarray(vals)))
    pl = np.asarray(neighbor_mean_pallas(jnp.asarray(vals), interpret=True))
    np.testing.assert_allclose(ref, pl, rtol=1e-6, atol=1e-6)


def test_neighbor_mode_tie_breaks_to_smallest_value():
    # two classes with equal count: the smaller value must win in every impl
    neigh = np.array([[9, 2, 2, 9], [5, 5, 1, 1]], dtype=np.int64)
    for impl in ("numpy", "ref", "pallas"):
        got = kops.neighbor_aggregate(neigh, categorical=True, impl=impl)
        np.testing.assert_array_equal(got, [2.0, 1.0], err_msg=f"impl={impl}")


# --------------------------------------------------------------------------- #
# flash attention kernel
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 16, 2, 1, 8), (2, 64, 4, 2, 16), (1, 96, 8, 2, 32), (2, 100, 4, 4, 16),
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (False, None), (True, 24),
])
def test_flash_attention_pallas_matches_ref(b, s, h, kv, d, causal, window):
    from repro.kernels.flash_attention import flash_attention_pallas

    rng = np.random.default_rng(s * 10 + h)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    ref = kref.attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, bq=32, bk=32, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


def test_flash_attention_pallas_bf16():
    from repro.kernels.flash_attention import flash_attention_pallas

    rng = np.random.default_rng(3)
    b, s, h, kv, d = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d))).astype(jnp.bfloat16)
    ref = kref.attention_ref(q, k, v)
    out = flash_attention_pallas(q, k, v, bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref, dtype=np.float32),
        rtol=3e-2, atol=3e-2,
    )
