"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes and finiteness, a decode step for decoder
archs, and chunked-vs-naive attention equivalence."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, get_arch, runnable
from repro.launch.steps import build_train_step, init_train_state
from repro.models import (
    decode_step,
    init_caches,
    init_params,
    loss_fn,
    prefill,
    uses_embeds,
)

ARCHS = all_archs()

# Compiling every architecture's train/decode graph takes minutes on CPU, so
# the default (tier-1) suite runs one representative decoder + the encoder
# path; the full sweep runs with --runslow (see conftest.py).
FAST_TRAIN_ARCHS = frozenset({"qwen2.5-3b", "hubert-xlarge"})
FAST_DECODE_ARCHS = frozenset({"qwen2.5-3b"})
FAST_PREFILL_ARCHS = frozenset({"qwen2.5-3b", "mamba2-370m"})


def _arch_params(archs, fast):
    return [
        pytest.param(a, marks=() if a in fast else pytest.mark.slow)
        for a in archs
    ]


def _batch(cfg, key, b=2, s=32):
    if uses_embeds(cfg):
        return {
            "embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                        dtype=jnp.float32),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", _arch_params(ARCHS, FAST_TRAIN_ARCHS))
def test_train_step_reduced(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    state = init_train_state(cfg, params)
    step = jax.jit(build_train_step(cfg, remat="none"))
    batch = _batch(cfg, key)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state["params"], new_state["params"],
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", _arch_params(ARCHS, FAST_DECODE_ARCHS))
def test_decode_step_reduced(arch):
    cfg = get_arch(arch).reduced()
    if cfg.encoder_only:
        pytest.skip("encoder-only: no decode")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    caches = init_caches(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), S // 2, jnp.int32)
    logits, new_caches = jax.jit(
        lambda p, c, t, q: decode_step(p, c, cfg, t, q)
    )(params, caches, tok, pos)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize(
    "arch",
    _arch_params(
        ["qwen2.5-3b", "gemma2-27b", "qwen3-8b", "deepseek-v3-671b"],
        FAST_DECODE_ARCHS,
    ),
)
def test_chunked_attention_matches_naive(arch):
    cfg_c = dataclasses.replace(
        get_arch(arch).reduced(), attn_q_chunk=16, attn_k_chunk=16
    )
    cfg_n = dataclasses.replace(cfg_c, attn_impl="naive")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg_n, key)
    batch = _batch(cfg_n, key, b=2, s=48)
    ln = float(loss_fn(params, cfg_n, batch, remat="none"))
    lc = float(loss_fn(params, cfg_c, batch, remat="none"))
    np.testing.assert_allclose(ln, lc, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(
    "prefix",
    [12, pytest.param(32, marks=pytest.mark.slow)],  # eager decode ∝ S
)
@pytest.mark.parametrize(
    "arch",
    _arch_params(
        ["qwen2.5-3b", "mamba2-370m", "zamba2-1.2b", "gemma2-27b"],
        FAST_PREFILL_ARCHS,
    ),
)
def test_decode_matches_prefill(arch, prefix):
    """Greedy next-token from decode(cache of prefix) equals next-token from
    prefill(prefix) — KV/SSM cache consistency."""
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 1, prefix
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    logits_pre = prefill(params, cfg, {"tokens": toks}, remat="none")

    # feed tokens one by one through decode
    caches = init_caches(cfg, B, S)
    logits = None
    for t in range(S):
        logits, caches = decode_step(
            params, caches, cfg, toks[:, t : t + 1],
            jnp.full((B,), t, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(logits), rtol=2e-3, atol=2e-3
    )


def test_shape_skip_rules():
    cells = 0
    for a in ARCHS:
        cfg = get_arch(a)
        for s in SHAPES.values():
            ok, why = runnable(cfg, s)
            cells += ok
            if cfg.encoder_only and s.kind == "decode":
                assert not ok
            if s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
                assert not ok
    assert cells == 31  # 40 assigned − 2 (encoder decode) − 7 (500k full-attn)


def test_param_counts_match_public_sizes():
    """Analytic parameter counts land near the public model sizes."""
    expect = {
        "qwen2.5-3b": (2.5e9, 4.0e9),
        "gemma-7b": (7.5e9, 9.5e9),
        "qwen3-8b": (7.0e9, 9.0e9),
        "gemma2-27b": (26e9, 30e9),
        "pixtral-12b": (11e9, 14e9),
        "mamba2-370m": (3.0e8, 4.5e8),   # SSD single-group B/C
        "deepseek-v3-671b": (6.3e11, 7.2e11),
        "moonshot-v1-16b-a3b": (25e9, 30e9),  # assigned 48L spec (real moonlight is 27L/16B)
        "zamba2-1.2b": (0.9e9, 1.8e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
    }
    for a, (lo, hi) in expect.items():
        n = get_arch(a).num_params()
        assert lo <= n <= hi, f"{a}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
