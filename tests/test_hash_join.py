"""Hash-join kernel subsystem: ``ops.hash_join_match`` (ref and
pallas-interpret) vs a naive O(n·m) nested-loop oracle and vs the NumPy
sort-join (``core.triggers.multi_match``), on adversarial inputs — empty
sides, all-duplicate keys, uint32 fold collisions, keys absent from the
build side — plus a fixed-corpus property sweep."""

from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.triggers import multi_match, resolve_join_impl
from repro.kernels import ops as kops
from repro.kernels.hashing import fold64

IMPLS = ["ref", "pallas"]


def nested_loop_oracle(build, probe):
    """O(n·m) ground truth, ordered (probe asc, build asc)."""
    pairs = [
        (i, j)
        for i, pk in enumerate(probe)
        for j, bk in enumerate(build)
        if bk == pk
    ]
    if not pairs:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    arr = np.asarray(pairs, dtype=np.int64)
    return arr[:, 0], arr[:, 1]


def _assert_matches_oracle(build, probe):
    build = np.asarray(build, dtype=np.int64)
    probe = np.asarray(probe, dtype=np.int64)
    want_p, want_b = nested_loop_oracle(build, probe)
    got_np_p, got_np_b = multi_match(build, probe)
    np.testing.assert_array_equal(got_np_p, want_p)
    np.testing.assert_array_equal(got_np_b, want_b)
    for impl in IMPLS:
        got_p, got_b = kops.hash_join_match(build, probe, impl=impl)
        np.testing.assert_array_equal(got_p, want_p, err_msg=impl)
        np.testing.assert_array_equal(got_b, want_b, err_msg=impl)


def _fold_colliding_pair(lo: int):
    """Two distinct int64 keys with equal fold64: fold = lo ^ (hi·PHI)."""
    phi = 0x9E3779B9
    k1 = lo & 0xFFFFFFFF
    k2 = (1 << 32) | ((k1 ^ phi) & 0xFFFFFFFF)
    assert fold64([k1])[0] == fold64([k2])[0] and k1 != k2
    return k1, k2


# --------------------------------------------------------------------------- #
# adversarial fixed cases
# --------------------------------------------------------------------------- #
def test_empty_sides():
    _assert_matches_oracle([], [])
    _assert_matches_oracle([], [1, 2, 3])
    _assert_matches_oracle([1, 2, 3], [])


def test_singleton_and_absent_keys():
    _assert_matches_oracle([5], [5])
    _assert_matches_oracle([5], [6])
    _assert_matches_oracle([1, 2, 3], [4, 5, 6, 7])  # all probes miss


def test_all_duplicate_build_keys():
    _assert_matches_oracle([7] * 40, [7, 8, 7, 7])


def test_all_duplicate_both_sides():
    _assert_matches_oracle([3] * 25, [3] * 17)


def test_negative_and_extreme_keys():
    _assert_matches_oracle(
        [-(2**62), -1, 0, 1, 2**62, -(2**62)],
        [0, -(2**62), 2**62, -5, -1],
    )


def test_uint32_fold_collisions():
    """Distinct 64-bit keys that fold to the same uint32 must not join."""
    k1, k2 = _fold_colliding_pair(12345)
    k3, k4 = _fold_colliding_pair(987654321)
    build = [k1, k2, k3, k1, k4]
    probe = [k1, k2, k3, k4, 999, k2]
    _assert_matches_oracle(build, probe)


def test_probe_chunking_preserves_order(monkeypatch):
    """Shrinking the dense budget forces the chunked probe path."""
    monkeypatch.setattr(kops, "_DENSE_BUDGET", 512)
    rng = np.random.default_rng(7)
    build = rng.integers(0, 40, 700)
    probe = rng.integers(0, 40, 900)
    _assert_matches_oracle(build, probe)


def test_resolve_join_impl(monkeypatch):
    assert resolve_join_impl(None) == "numpy"
    assert resolve_join_impl("pallas") == "pallas"
    monkeypatch.setenv("QUIP_JOIN_IMPL", "ref")
    assert resolve_join_impl(None) == "ref"
    assert resolve_join_impl("numpy") == "numpy"  # explicit beats env
    with pytest.raises(ValueError):
        resolve_join_impl("cuda")


# --------------------------------------------------------------------------- #
# property sweep
# --------------------------------------------------------------------------- #
# sizes from a small fixed set so the per-shape jit compiles amortize across
# examples while still covering the empty / tiny / non-aligned / large edges
_SIZES = [0, 1, 17, 64, 120]


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_build=st.sampled_from(_SIZES),
    n_probe=st.sampled_from(_SIZES),
    key_card=st.integers(1, 25),
)
def test_hash_join_matches_nested_loop_property(
    seed, n_build, n_probe, key_card
):
    rng = np.random.default_rng(seed)
    build = rng.integers(-key_card, key_card, n_build)
    probe = rng.integers(-key_card, key_card, n_probe)
    _assert_matches_oracle(build, probe)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([1, 50, 300]))
def test_hash_join_sparse_wide_keys_property(seed, n):
    rng = np.random.default_rng(seed)
    build = rng.integers(-(2**62), 2**62, n)
    probe = np.concatenate([build[:: 3], rng.integers(-(2**62), 2**62, n)])
    _assert_matches_oracle(build, probe)
