"""Compiled tensor plans (docs/compiled.md): lowering, fallback, segment
kernels, plan-cache hotness/artifacts, and the serving-layer promotion path.

The correctness contract under test everywhere: the compiled whole-relation
program and the morsel interpreter return **bit-identical answers and
imputation counts** — compilation is an optimization, never a semantics
change.  The complementary strategy-matrix test lives in
``test_strategy_equivalence.py::test_compiled_exec_matches_interp``.
"""

from __future__ import annotations

from collections import Counter

import numpy as np
import pytest

from repro.core.compiled import (
    CompileFallback,
    CompiledPlan,
    compile_plan,
    resolve_exec_impl,
)
from repro.core.env import env_choice
from repro.core.executor import execute_quip, make_plan
from repro.core.plan import Aggregate, Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.triggers import resolve_join_impl
from repro.imputers.base import ImputationEngine
from repro.kernels import ops as kops
from repro.service.plan_cache import PlanCache
from repro.service.registry import TableRegistry
from repro.service.server import QuipService
from test_quip_correctness import GroundTruthImputer, _build_instance


# --------------------------------------------------------------------- #
# instance helpers
# --------------------------------------------------------------------- #
def _instance(seed: int = 7, rows: int = 24):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, 2, rows, 0.3, 5)
    return tables, clean, truth


def _query(agg=None):
    return Query(
        tables=("R0", "R1"),
        selections=(SelectionPredicate("R0.v", "<=", 3),),
        joins=(JoinPredicate("R0.k1", "R1.k1"),),
        projection=() if agg is not None else ("R0.v", "R1.v"),
        aggregate=agg,
    )


def _engine(tables, truth):
    return ImputationEngine(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )


# --------------------------------------------------------------------- #
# env_choice (satellite: shared env-var parsing)
# --------------------------------------------------------------------- #
def test_env_choice_parses_and_defaults(monkeypatch):
    monkeypatch.delenv("QUIP_TEST_CHOICE", raising=False)
    assert env_choice("QUIP_TEST_CHOICE", ("a", "b"), "a") == "a"
    monkeypatch.setenv("QUIP_TEST_CHOICE", "")
    assert env_choice("QUIP_TEST_CHOICE", ("a", "b"), "a") == "a"
    monkeypatch.setenv("QUIP_TEST_CHOICE", "  B ")
    assert env_choice("QUIP_TEST_CHOICE", ("a", "b"), "a") == "b"


def test_env_choice_garbage_raises(monkeypatch):
    monkeypatch.setenv("QUIP_TEST_CHOICE", "banana")
    with pytest.raises(ValueError, match="QUIP_TEST_CHOICE"):
        env_choice("QUIP_TEST_CHOICE", ("a", "b"), "a")


@pytest.mark.parametrize(
    "var,resolver",
    [
        ("QUIP_EXEC_IMPL", resolve_exec_impl),
        ("QUIP_JOIN_IMPL", resolve_join_impl),
        ("QUIP_KNN_IMPL", kops.resolve_knn_impl),
        ("QUIP_SEGMENT_IMPL", kops.resolve_segment_impl),
    ],
)
def test_impl_env_garbage_raises(var, resolver, monkeypatch):
    monkeypatch.setenv(var, "warp-drive")
    with pytest.raises(ValueError, match=var):
        resolver()


def test_resolve_exec_impl_explicit(monkeypatch):
    monkeypatch.setenv("QUIP_EXEC_IMPL", "compiled")
    assert resolve_exec_impl("interp") == "interp"  # explicit beats env
    assert resolve_exec_impl() == "compiled"
    with pytest.raises(ValueError, match="unknown exec impl"):
        resolve_exec_impl("jit")


# --------------------------------------------------------------------- #
# segment reductions (kernels/segment_ops.py + kernels/ops.py)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("impl", ["numpy", "ref", "pallas"])
@pytest.mark.parametrize("op", ["count", "sum", "min", "max"])
def test_segment_reduce_impls_agree(impl, op):
    rng = np.random.default_rng(3)
    seg = rng.integers(0, 9, size=300).astype(np.int64)
    seg[seg == 7] = 8  # leave segment 7 empty
    vals = rng.integers(-50, 50, size=300).astype(np.int64)
    got = kops.segment_reduce(vals, seg, 10, op, impl=impl)
    ref = kops.segment_reduce(vals, seg, 10, op, impl="numpy")
    np.testing.assert_array_equal(got, ref)


def test_segment_reduce_numpy_bit_identical_to_groupwise_float():
    """The serving-default numpy impl must reproduce the interpreter's
    per-group ``group.sum()`` at full float64 bit precision (stable argsort
    → contiguous slice → pairwise sum)."""
    rng = np.random.default_rng(5)
    seg = rng.integers(0, 6, size=500).astype(np.int64)
    vals = rng.normal(size=500)
    got = kops.segment_reduce(vals, seg, 6, "sum", impl="numpy")
    oracle = np.array([vals[seg == s].sum() for s in range(6)])
    assert got.tolist() == oracle.tolist()  # exact equality, not allclose


def test_segment_reduce_negative_ids_dropped():
    seg = np.array([0, -1, 1, -1, 0], dtype=np.int64)
    vals = np.array([1, 100, 2, 100, 3], dtype=np.int64)
    for impl in ("numpy", "ref", "pallas"):
        assert kops.segment_reduce(vals, seg, 2, "sum", impl=impl).tolist() \
            == [4, 2]
        assert kops.segment_reduce(vals, seg, 2, "count", impl=impl).tolist() \
            == [2, 1]


# --------------------------------------------------------------------- #
# compile_plan: eligibility + aggregate lowering
# --------------------------------------------------------------------- #
def test_compile_fallback_reasons():
    tables, _clean, truth = _instance()
    q = _query()
    plan = make_plan(q, tables)
    with pytest.raises(CompileFallback, match="defer"):
        compile_plan(q, plan, tables, "lazy", use_vf=False, minmax_opt=False)
    with pytest.raises(CompileFallback, match="VF"):
        compile_plan(q, plan, tables, "eager", use_vf=True, minmax_opt=False)
    qm = _query(Aggregate("max", "R1.v"))
    pm = make_plan(qm, tables)
    with pytest.raises(CompileFallback, match="MIN/MAX"):
        compile_plan(qm, pm, tables, "eager", use_vf=False, minmax_opt=True)
    # the imputedb alias forces eager + use_vf=False itself → compiles
    cp = compile_plan(q, plan, tables, "imputedb")
    assert isinstance(cp, CompiledPlan)


@pytest.mark.parametrize("group_by", [None, "R1.v"])
@pytest.mark.parametrize("op", ["count", "sum", "avg", "min", "max"])
def test_compiled_aggregates_match_interp(op, group_by):
    tables, _clean, truth = _instance(seed=11)
    q = _query(Aggregate(op, "R0.v", group_by=group_by))
    kwargs = dict(strategy="eager", morsel_rows=7, use_vf=False,
                  minmax_opt=False)
    base = execute_quip(q, tables, _engine(tables, truth), **kwargs)
    comp = execute_quip(q, tables, _engine(tables, truth),
                        exec_impl="compiled", **kwargs)
    assert comp.counters.exec_impl == "compiled"
    assert comp.counters.compiled_hits == 1
    assert Counter(comp.answer_tuples()) == Counter(base.answer_tuples())
    assert comp.counters.imputations == base.counters.imputations


@pytest.mark.parametrize("segment_impl", ["numpy", "ref", "pallas"])
def test_compiled_grouped_agg_segment_impls(segment_impl, monkeypatch):
    """QUIP_SEGMENT_IMPL routes the grouped reduction through the numpy /
    jax.ops / Pallas segment kernels; integer aggregates stay identical."""
    monkeypatch.setenv("QUIP_SEGMENT_IMPL", segment_impl)
    tables, _clean, truth = _instance(seed=13)
    q = _query(Aggregate("sum", "R0.v", group_by="R1.v"))
    kwargs = dict(strategy="eager", morsel_rows=7, use_vf=False,
                  minmax_opt=False)
    base = execute_quip(q, tables, _engine(tables, truth), **kwargs)
    comp = execute_quip(q, tables, _engine(tables, truth),
                        exec_impl="compiled", **kwargs)
    assert Counter(comp.answer_tuples()) == Counter(base.answer_tuples())


# --------------------------------------------------------------------- #
# PlanCache: per-signature hits, eviction, artifacts (satellite 2)
# --------------------------------------------------------------------- #
def test_plan_cache_hit_counts_and_eviction_at_capacity_one():
    tables, _clean, _truth = _instance()
    cache = PlanCache(capacity=1)
    q1, q2 = _query(), _query(Aggregate("count", None))

    cache.get(q1, tables)  # miss → planned + interned
    assert cache.hit_count(q1) == 0
    cache.get(q1, tables)  # hit
    cache.get(q1, tables)  # hit
    assert cache.hit_count(q1) == 2

    cache.get(q2, tables)  # miss at capacity 1 → evicts q1's entry
    assert cache.stats()["evictions"] == 1
    assert cache.hit_count(q1) == 0  # hotness died with the entry
    _plan, hit = cache.get(q1, tables)  # re-planned from scratch
    assert not hit

    summary = cache.summary()
    assert summary["size"] == 1
    assert summary["compiled"] == 0
    assert sum(summary["signature_hits"].values()) == 0


def test_plan_cache_artifact_epoch_gate():
    tables, _clean, _truth = _instance()
    cache = PlanCache(capacity=4)
    q = _query()
    plan, _hit = cache.get(q, tables)
    artifact = compile_plan(q, plan, tables, "eager", use_vf=False,
                            minmax_opt=False)

    cache.store_compiled(q, "eager", (0, 0), artifact)
    assert cache.compiled_artifact(q, "eager", (0, 0)) is artifact
    assert cache.compiled_count() == 1
    # stale epochs: never served, and dropped on sight
    assert cache.compiled_artifact(q, "eager", (1, 0)) is None
    assert cache.compiled_count() == 0
    # cached fallbacks are artifacts too, but not "compiled" in telemetry
    cache.store_compiled(q, "lazy", (0, 0), CompileFallback("nope"))
    assert cache.compiled_count() == 0
    assert isinstance(cache.compiled_artifact(q, "lazy", (0, 0)),
                      CompileFallback)
    # table mutation hook drops the whole entry, artifacts included
    cache.store_compiled(q, "eager", (0, 0), artifact)
    assert cache.invalidate_table("R0") == 1
    assert cache.compiled_count() == 0
    assert cache.hit_count(q) == 0


# --------------------------------------------------------------------- #
# QuipService: promotion on the Kth hit + epoch invalidation
# --------------------------------------------------------------------- #
def _service(tables, truth, **kw):
    registry = TableRegistry({t: r.copy() for t, r in tables.items()})
    service = QuipService(
        registry,
        imputer_factory=lambda: GroundTruthImputer(truth),
        strategy="eager",
        use_vf=False,
        minmax_opt=False,
        morsel_rows=7,
        result_cache_size=0,
        shared_impute=False,
        **kw,
    )
    return registry, service


def _canon(answers):
    return Counter(tuple(repr(v) for v in t) for t in answers)


def test_service_promotes_on_kth_hit_and_invalidates_on_mutation():
    tables, _clean, truth = _instance()
    q = _query()
    reg_c, svc_c = _service(tables, truth, exec_impl="compiled",
                            compile_after_hits=2)
    reg_i, svc_i = _service(tables, truth)

    def run(svc):
        return _canon(svc.answers(svc.submit(q)))

    for _ in range(4):
        assert run(svc_c) == run(svc_i)
    impls = [r.counters.exec_impl for r in svc_c.serving.records]
    # submissions 1–2 are hits 0 and 1 (< K=2); 3–4 run compiled
    assert impls == ["interp", "interp", "compiled", "compiled"]
    summary = svc_c.summary()
    assert summary["compiled_hits"] == 2
    assert summary["compile_fallbacks"] == 0
    assert summary["plan_cache_compiled"] == 1
    assert summary["exec_impl"] == "compiled"

    # mutation bumps the epoch: the artifact (and plan) die with the entry
    rows = np.array([0, 1])
    vals = {"R0.v": np.array([2, 3], dtype=np.int64)}
    reg_c.update_rows("R0", rows, vals)
    reg_i.update_rows("R0", rows, vals)
    assert svc_c.plan_cache.compiled_count() == 0
    for _ in range(4):
        assert run(svc_c) == run(svc_i)  # zero stale answers
    impls = [r.counters.exec_impl for r in svc_c.serving.records[4:]]
    assert impls == ["interp", "interp", "compiled", "compiled"]


def test_service_caches_fallback_for_ineligible_strategy():
    tables, _clean, truth = _instance()
    q = _query()
    _reg, svc = _service(tables, truth, exec_impl="compiled",
                         compile_after_hits=1)
    for _ in range(3):
        svc.answers(svc.submit(q, strategy="lazy"))
    summary = svc.summary()
    # hits 1 and 2 consult the (cached) fallback — lowering ran only once
    assert summary["compile_fallbacks"] == 2
    assert summary["compiled_hits"] == 0
    assert svc.plan_cache.compiled_count() == 0
    impls = [r.counters.exec_impl for r in svc.serving.records]
    assert impls == ["interp"] * 3


def test_service_rejects_bad_compile_knobs():
    tables, _clean, truth = _instance()
    with pytest.raises(ValueError, match="compile_after_hits"):
        _service(tables, truth, compile_after_hits=0)
    with pytest.raises(ValueError, match="unknown exec impl"):
        _service(tables, truth, exec_impl="jit")
