"""Batched columnar ImputationService: request-queue semantics, vectorized
dedup, int-cast rounding (regression), vectorized KNN mode, and the
batched-vs-seed equivalence invariants (same answers, same
``counters.imputations``, strictly fewer ``counters.impute_batches`` on
multi-morsel queries)."""

from __future__ import annotations

import functools
import os
from collections import Counter

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.executor import evaluate_clean, execute_offline, execute_quip
from repro.core.plan import Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import ImputationService, Imputer
from repro.imputers.knn import KnnImputer
from repro.kernels import ops as kops
from test_quip_correctness import GroundTruthImputer, _build_instance


class CountingImputer(Imputer):
    """Deterministic f(tid) imputer that records every invocation."""

    def __init__(self, fn=lambda t: t.astype(np.float64)):
        self.fn = fn
        self.calls = []  # list of tid batches, in invocation order

    def impute_attr(self, table, attr, tids):
        tids = np.asarray(tids, dtype=np.int64)
        self.calls.append(tids.copy())
        return self.fn(tids)


def _one_table(n=10, kind="int"):
    schema = Schema("T", [ColumnSpec("T.x", kind)])
    vals = np.zeros(n, dtype=np.float64 if kind == "float" else np.int64)
    rel = MaskedRelation.from_columns(
        schema, {"T.x": vals}, missing={"T.x": np.ones(n, dtype=bool)},
        base_table="T",
    )
    return {"T": rel}


# --------------------------------------------------------------------------- #
# cache / queue semantics
# --------------------------------------------------------------------------- #
def test_int_cast_rounds_half_even():
    """Regression: a float imputation written into an int column must round
    (half-even), not truncate — the seed engine cast 2.7 to 2."""
    fills = {0: 2.7, 1: 2.5, 2: 3.5, 3: -0.5, 4: -1.7}
    imp = CountingImputer(fn=lambda t: np.array([fills[int(i)] for i in t]))
    svc = ImputationService(_one_table(), default=lambda: imp)
    got = svc.impute("T", "T.x", np.arange(5))
    assert got.dtype == np.int64
    assert got.tolist() == [3, 2, 4, 0, -2]


def test_float_columns_cast_unrounded():
    imp = CountingImputer(fn=lambda t: t + 0.25)
    svc = ImputationService(_one_table(kind="float"), default=lambda: imp)
    assert svc.impute("T", "T.x", np.array([3, 7])).tolist() == [3.25, 7.25]


def test_enqueue_flush_coalesces_and_dedups():
    """Requests from several operators/morsels coalesce into one sorted,
    deduplicated model batch; cached tids never recompute."""
    imp = CountingImputer()
    svc = ImputationService(_one_table(), default=lambda: imp)
    svc.enqueue("T", "T.x", np.array([5, 1, 5]))  # σ̂ morsel 1
    svc.enqueue("T", "T.x", np.array([2, 1]))  # σ̂ morsel 2
    svc.enqueue("T", "T.x", np.array([5, 9]))  # join pipeline copy
    assert svc.pending_requests() == 7
    svc.flush()
    assert [c.tolist() for c in imp.calls] == [[1, 2, 5, 9]]
    assert svc.counters.imputations == 4
    assert svc.counters.impute_batches == 1
    assert svc.counters.impute_flushes == 1
    # second round: overlap is served from the dense cache
    svc.enqueue("T", "T.x", np.array([9, 2, 0]))
    svc.flush()
    assert [c.tolist() for c in imp.calls] == [[1, 2, 5, 9], [0]]
    assert svc.counters.imputations == 5
    assert svc.counters.impute_batches == 2
    assert svc.lookup("T", "T.x", np.array([5, 5, 0])).tolist() == [5, 5, 0]
    assert svc.stats.mean_flush_size("T.x") == pytest.approx(2.5)


def test_lookup_before_flush_raises():
    svc = ImputationService(_one_table(), default=CountingImputer)
    svc.enqueue("T", "T.x", np.array([1]))
    with pytest.raises(KeyError):
        svc.lookup("T", "T.x", np.array([1]))


def test_writeback_snapshot_matches_lookup():
    imp = CountingImputer(fn=lambda t: t + 0.7)
    svc = ImputationService(_one_table(), default=lambda: imp)
    svc.impute("T", "T.x", np.array([2, 8, 3]))
    snap = svc.writeback_snapshot()
    assert set(snap) == {("T", "T.x")}
    tids, vals = snap[("T", "T.x")]
    assert tids.tolist() == [2, 3, 8]
    np.testing.assert_array_equal(
        vals, svc.lookup("T", "T.x", tids)
    )
    assert svc.writeback_snapshot(table="S") == {}


def test_batching_env_gate(monkeypatch):
    monkeypatch.setenv("QUIP_IMPUTE_BATCH", "0")
    assert not ImputationService(_one_table(), default=CountingImputer).batching
    monkeypatch.delenv("QUIP_IMPUTE_BATCH")
    assert ImputationService(_one_table(), default=CountingImputer).batching
    assert not ImputationService(
        _one_table(), default=CountingImputer, batching=False
    ).batching


# --------------------------------------------------------------------------- #
# vectorized KNN categorical mode (satellite: bincount trick vs per-row loop)
# --------------------------------------------------------------------------- #
def _mode_per_row_loop(neigh: np.ndarray) -> np.ndarray:
    """The seed imputer's per-row mode loop — the semantics oracle."""
    vals = []
    for row in neigh:
        u, c = np.unique(row, return_counts=True)
        vals.append(u[np.argmax(c)])
    return np.asarray(vals, dtype=np.float64)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    b=st.integers(1, 70),
    k=st.integers(1, 9),
    lo=st.integers(-50, 0),
    span=st.integers(1, 400),
)
def test_neighbor_mode_matches_per_row_loop(seed, b, k, lo, span):
    rng = np.random.default_rng(seed)
    neigh = rng.integers(lo, lo + span, size=(b, k)).astype(np.int64)
    expected = _mode_per_row_loop(neigh)
    for impl in ("numpy", "ref"):
        got = kops.neighbor_aggregate(neigh, categorical=True, impl=impl)
        np.testing.assert_array_equal(got, expected, err_msg=f"impl={impl}")


def test_neighbor_mode_pallas_matches_loop():
    """Pallas pair at fixed shapes (per-shape interpret compiles are slow)."""
    rng = np.random.default_rng(7)
    for b, k, span in ((5, 3, 9), (130, 5, 300)):
        neigh = rng.integers(0, span, size=(b, k)).astype(np.int64)
        got = kops.neighbor_aggregate(neigh, categorical=True, impl="pallas")
        np.testing.assert_array_equal(got, _mode_per_row_loop(neigh))


def test_non_finite_int_imputation_raises():
    """np.round(nan).astype(int64) would silently yield INT64_MIN; the
    service must fail loudly like the seed engine's element-wise cast did."""
    imp = CountingImputer(fn=lambda t: np.where(t > 1, np.nan, 1.0))
    svc = ImputationService(_one_table(), default=lambda: imp)
    with pytest.raises(ValueError, match="non-finite"):
        svc.impute("T", "T.x", np.array([0, 3]))


def test_neighbor_mode_row_chunking_exact(monkeypatch):
    """The mode path chunks rows to bound the count-matrix memory; chunked
    and unchunked results must be identical for every impl."""
    rng = np.random.default_rng(11)
    neigh = rng.integers(0, 90, size=(67, 4)).astype(np.int64)
    expected = _mode_per_row_loop(neigh)
    monkeypatch.setattr(kops, "_AGG_BUDGET", 256)  # force many chunks
    for impl in ("numpy", "ref"):
        got = kops.neighbor_aggregate(neigh, categorical=True, impl=impl)
        np.testing.assert_array_equal(got, expected, err_msg=f"impl={impl}")


def test_neighbor_mean_numpy_bit_identical_to_seed():
    rng = np.random.default_rng(3)
    neigh = rng.normal(size=(40, 5))
    got = kops.neighbor_aggregate(neigh, categorical=False, impl="numpy")
    np.testing.assert_array_equal(got, neigh.mean(axis=1))


# --------------------------------------------------------------------------- #
# batched vs seed-call-pattern equivalence (the tentpole invariant)
# --------------------------------------------------------------------------- #
def _chain(seed: int, rows: int = 64):
    rng = np.random.default_rng(seed)
    tables, clean, truth = _build_instance(rng, 2, rows, 0.3, 6)
    q = Query(
        tables=("R0", "R1"),
        selections=(
            SelectionPredicate("R0.v", "<=", 4),
            SelectionPredicate("R1.v", ">=", 1),
        ),
        joins=(JoinPredicate("R0.k1", "R1.k1"),),
        projection=("R0.v", "R1.v"),
    )
    return tables, clean, truth, q


def _run(q, tables, truth, strategy, batching, morsel_rows=8, use_vf=True):
    eng = ImputationService(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
        batching=batching,
    )
    if strategy == "offline":
        return execute_offline(q, tables, eng)
    return execute_quip(
        q, tables, eng, strategy=strategy, morsel_rows=morsel_rows,
        use_vf=use_vf,
    )


@pytest.mark.parametrize("strategy", ["offline", "eager", "lazy"])
def test_batched_matches_sync_answers_and_imputations(strategy):
    """Coalescing must not change *what* gets imputed — only how often the
    imputer is invoked.  (adaptive is excluded from the counter check: its
    decisions are wall-clock-dependent in the seed engine too.)"""
    tables, clean, truth, q = _chain(101)
    sync = _run(q, tables, truth, strategy, batching=False)
    bat = _run(q, tables, truth, strategy, batching=True)
    assert Counter(bat.answer_tuples()) == Counter(sync.answer_tuples())
    assert Counter(bat.answer_tuples()) == Counter(
        evaluate_clean(q, clean).to_sorted_tuples()
    )
    assert bat.counters.imputations == sync.counters.imputations
    assert bat.counters.impute_batches <= sync.counters.impute_batches
    if strategy == "eager":
        # multi-morsel build side: σ̂/⋈̂ requests collapse into single flushes
        assert bat.counters.impute_batches < sync.counters.impute_batches


def test_adaptive_batched_answers_invariant():
    tables, clean, truth, q = _chain(202)
    res = _run(q, tables, truth, "adaptive", batching=True)
    assert Counter(res.answer_tuples()) == Counter(
        evaluate_clean(q, clean).to_sorted_tuples()
    )
    total_missing = sum(
        tables[t].is_missing(a).sum()
        for t in tables for a in tables[t].column_names()
    )
    assert res.counters.imputations <= total_missing
    assert res.counters.impute_batches >= 1


def test_rho_deferral_batches_without_vf():
    """With VF lists off (the imputedb-baseline configuration) ρ parks the
    whole stream and imputes it with one flush per attribute."""
    tables, clean, truth, q = _chain(303)
    sync = _run(q, tables, truth, "lazy", batching=False, use_vf=False)
    bat = _run(q, tables, truth, "lazy", batching=True, use_vf=False)
    assert Counter(bat.answer_tuples()) == Counter(sync.answer_tuples())
    assert bat.counters.imputations == sync.counters.imputations
    assert bat.counters.impute_batches < sync.counters.impute_batches


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), strategy=st.sampled_from(["eager", "lazy"]))
def test_batched_equivalence_property(seed, strategy):
    tables, clean, truth, q = _chain(seed, rows=40)
    sync = _run(q, tables, truth, strategy, batching=False, morsel_rows=7)
    bat = _run(q, tables, truth, strategy, batching=True, morsel_rows=7)
    assert Counter(bat.answer_tuples()) == Counter(sync.answer_tuples())
    assert bat.counters.imputations == sync.counters.imputations
    assert bat.counters.impute_batches <= sync.counters.impute_batches


# --------------------------------------------------------------------------- #
# strategy equivalence under the real KNN imputer, across QUIP_KNN_IMPL
# --------------------------------------------------------------------------- #
STRATEGIES = ["offline", "eager", "lazy", "adaptive"]


def _knn_run(q, tables, strategy):
    eng = ImputationService(
        {t: tables[t].copy() for t in tables},
        default=lambda: KnnImputer(k=3),
    )
    if strategy == "offline":
        return execute_offline(q, tables, eng)
    return execute_quip(q, tables, eng, strategy=strategy, morsel_rows=8)


def _knn_sweep(impl):
    """All four strategies under QUIP_KNN_IMPL=impl → (answers, imputations)."""
    prev = os.environ.get("QUIP_KNN_IMPL")
    os.environ["QUIP_KNN_IMPL"] = impl
    try:
        tables, _clean, _truth, q = _chain(404, rows=28)
        answers, imputations = {}, {}
        for strategy in STRATEGIES:
            res = _knn_run(q, tables, strategy)
            answers[strategy] = Counter(res.answer_tuples())
            imputations[strategy] = res.counters.imputations
        return answers, imputations
    finally:
        if prev is None:
            os.environ.pop("QUIP_KNN_IMPL", None)
        else:
            os.environ["QUIP_KNN_IMPL"] = prev


@functools.lru_cache(maxsize=1)
def _knn_numpy_baseline():
    return _knn_sweep("numpy")


@pytest.mark.parametrize("impl", ["numpy", "ref", "pallas"])
def test_knn_strategy_equivalence_across_impls(impl):
    """offline == eager == lazy == adaptive answers with a real (KNN)
    imputer, per aggregation impl; the integer mode path is bit-identical
    across impls, so ``counters.imputations`` must agree between numpy, ref
    and pallas-interpret too."""
    answers, imputations = _knn_sweep(impl)
    for strategy in STRATEGIES[1:]:
        assert answers[strategy] == answers["offline"], (impl, strategy)
    # order-independent cross-impl invariant: always compare against a
    # (cached) numpy-baseline sweep rather than sibling-parametrization state
    _base_answers, base_imputations = _knn_numpy_baseline()
    assert imputations == base_imputations, (impl, imputations)
