"""Optional-``hypothesis`` shim for the test suite.

The property tests in this repo are written against the hypothesis API
(``@given`` + ``strategies``).  The package is a dev-only dependency
(``requirements-dev.txt``) and is deliberately *not* required to run tier-1:
when it is missing, this module provides a deterministic fallback that draws
a small fixed-seed example corpus from equivalent strategy objects and runs
the test body once per example.  Shrinking/replay niceties are lost, but the
suite collects and the invariants still get exercised.

Usage (in test modules)::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import itertools

import numpy as np

try:  # pragma: no cover - trivial re-export when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_SEED = 0xC0FFEE
    _FALLBACK_EXAMPLES = 10  # per test; settings(max_examples=n) lowers this

    class _Strategy:
        """A draw-only stand-in for a hypothesis strategy."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: np.random.Generator):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(options):
            seq = list(options)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _Strategies()

    def given(**strategies):
        """Run the test once per example of a fixed-seed corpus."""

        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _FALLBACK_EXAMPLES)
                rng = np.random.default_rng(_FALLBACK_SEED)
                for i in itertools.count():
                    if i >= n:
                        break
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise w/ context
                        raise AssertionError(
                            f"falsifying example (compat corpus #{i}): {drawn}"
                        ) from e

            wrapper._compat_max_examples = _FALLBACK_EXAMPLES
            # Hide the drawn parameters from pytest's fixture resolution:
            # only non-strategy parameters (real fixtures) stay visible.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items()
                    if name not in strategies
                ]
            )
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper

        return decorate

    def settings(max_examples=None, deadline=None, **_ignored):
        """Subset of hypothesis.settings: only max_examples matters here."""

        def decorate(fn):
            if max_examples is not None and hasattr(
                fn, "_compat_max_examples"
            ):
                fn._compat_max_examples = min(
                    fn._compat_max_examples, int(max_examples)
                )
            return fn

        return decorate


# Alias so either import style works.
strategies = st

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
