"""Shared test config: sibling-fixture imports, the ``slow`` marker gate,
and a SIGALRM fallback for ``@pytest.mark.timeout``.

Tier-1 (`PYTHONPATH=src python -m pytest -q`) runs the fast suite; cases
marked ``@pytest.mark.slow`` (full per-architecture sweeps, long-prefix
decode equivalence, long optimizer convergence) are skipped unless
``--runslow`` is passed.

The threaded worker-pool tests carry ``@pytest.mark.timeout(N)`` so a
pool deadlock fails the test instead of hanging the whole suite.  CI
installs ``pytest-timeout`` (requirements-dev.txt), which honors the
marker natively; when the plugin is absent (bare local env) a SIGALRM
hookwrapper enforces it on POSIX mains threads, and elsewhere the marker
is inert (worker threads are daemons, so an interpreter exit is never
blocked either way).
"""

import os
import signal
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="also run tests marked @pytest.mark.slow",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: expensive case, skipped unless --runslow is given"
    )
    if not config.pluginmanager.hasplugin("timeout"):
        # pytest-timeout absent: register the marker ourselves so it does
        # not warn, and enforce it via SIGALRM below
        config.addinivalue_line(
            "markers",
            "timeout(seconds): fail the test if it runs longer "
            "(SIGALRM fallback when pytest-timeout is not installed)",
        )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    plugin = item.config.pluginmanager.hasplugin("timeout")
    if marker is None or plugin or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = int(marker.args[0]) if marker.args else 60

    def _alarm(signum, frame):
        raise TimeoutError(
            f"test exceeded {seconds}s (SIGALRM fallback timeout)"
        )

    old = signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
