"""Shared test config: sibling-fixture imports + the ``slow`` marker gate.

Tier-1 (`PYTHONPATH=src python -m pytest -q`) runs the fast suite; cases
marked ``@pytest.mark.slow`` (full per-architecture sweeps, long-prefix
decode equivalence, long optimizer convergence) are skipped unless
``--runslow`` is passed.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))


def pytest_addoption(parser):
    parser.addoption(
        "--runslow",
        action="store_true",
        default=False,
        help="also run tests marked @pytest.mark.slow",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: expensive case, skipped unless --runslow is given"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
