"""Make sibling test fixtures importable regardless of invocation dir."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
