"""MorselScheduler QoS properties, on synthetic sessions with scripted
per-step costs: round-robin regression, bounded starvation gap under equal
weights, weighted-share convergence under uneven morsel costs, EDF drain
order, and the no-banked-credit rule for late joiners.

The fake sessions implement exactly the slice of the QuerySession protocol
the scheduler reads (start/state/step + the per-step cost slots), so every
assertion here is deterministic — no executors, no wall clock beyond the
scripted costs."""

from __future__ import annotations

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.service import MorselScheduler
from repro.service.session import DONE, QUEUED, RUNNING


class FakeSession:
    """Scheduler-protocol stand-in: one scripted cost per remaining step."""

    def __init__(self, ticket, tenant, costs):
        self.ticket = ticket
        self.tenant = tenant
        self._costs = list(costs)
        assert self._costs
        self.state = QUEUED
        self.last_step_wall_s = 0.0
        self.last_step_sim_s = 0.0
        self.steps_taken = 0
        self.active_s = 0.0
        self.sched_cost = 0.0
        self.admit_clock = None
        self.finish_clock = None
        self.deadline = None
        self.deadline_met = None

    def start(self):
        self.state = RUNNING

    def step(self):
        cost = self._costs.pop(0)
        self.last_step_wall_s = cost
        self.last_step_sim_s = 0.0
        self.steps_taken += 1
        self.active_s += cost
        if not self._costs:
            self.state = DONE
            return True
        return False


def test_rr_matches_legacy_ring_order():
    """policy="rr" preserves the original FIFO-ring rotation exactly."""
    sched = MorselScheduler("rr", cost_model="unit")
    sessions = [FakeSession(i, tenant=i, costs=[1.0] * 3) for i in range(3)]
    for s in sessions:
        sched.add(s)
    trace = []
    while sched.running:
        head = sched._ring[0]
        sched.step()
        trace.append(head.ticket)
    assert trace == [0, 1, 2] * 3


@settings(max_examples=8, deadline=None)
@given(n_tenants=st.integers(2, 5), n_steps=st.integers(3, 12))
def test_equal_weights_bounded_starvation_gap(n_tenants, n_steps):
    """Equal weights, unit costs, one session per tenant: no session
    waits more than ``n_tenants`` scheduler steps between its consecutive
    morsels (perfect rotation) — nobody starves."""
    sched = MorselScheduler("wfq", cost_model="unit")
    sessions = [FakeSession(i, tenant=i, costs=[1.0] * n_steps)
                for i in range(n_tenants)]
    for s in sessions:
        sched.add(s)
    step_of = {s.ticket: [] for s in sessions}
    i = 0
    while sched.running:
        counts = {s.ticket: s.steps_taken for s in sessions}
        sched.step()
        for s in sessions:
            if s.steps_taken != counts[s.ticket]:
                step_of[s.ticket].append(i)
        i += 1
    for ticket, steps in step_of.items():
        assert len(steps) == n_steps
        gaps = [b - a for a, b in zip(steps, steps[1:])]
        assert max(gaps, default=0) <= n_tenants, (
            f"session {ticket} starved: gaps {gaps}"
        )


@settings(max_examples=8, deadline=None)
@given(
    w_a=st.sampled_from([1, 2, 3, 4]),
    w_b=st.sampled_from([1, 2, 3, 4]),
    cost_a=st.floats(0.5, 8.0),
    cost_b=st.floats(0.5, 8.0),
)
def test_weighted_shares_converge_to_weight_ratio(w_a, w_b, cost_a, cost_b):
    """Under active-time charging with uneven per-step morsel costs, each
    tenant's charged-cost share converges to its weight share: tenant A
    burning ``cost_a`` seconds per morsel gets proportionally *fewer*
    morsels, not a free ride."""
    n = 4000
    sched = MorselScheduler(
        "wfq", weights={"A": float(w_a), "B": float(w_b)},
        cost_model="active",
    )
    a = FakeSession(0, "A", costs=[cost_a] * n)
    b = FakeSession(1, "B", costs=[cost_b] * n)
    sched.add(a)
    sched.add(b)
    for _ in range(600):  # neither session finishes: steady state
        sched.step()
    acct = sched.tenant_accounting()
    total = acct["A"]["cost"] + acct["B"]["cost"]
    want_a = w_a / (w_a + w_b)
    got_a = acct["A"]["cost"] / total
    # discretization: one morsel granularity around the ideal share
    tol = max(cost_a, cost_b) / total + 0.02
    assert abs(got_a - want_a) <= tol, (
        f"share {got_a:.3f} vs weight share {want_a:.3f} (tol {tol:.3f})"
    )


def test_deadline_drain_completion_order():
    """EDF: drain() completes sessions in deadline order; sessions with no
    deadline class run last (FIFO among themselves), and deadline_met is
    evaluated against the cost clock."""
    sched = MorselScheduler(
        "deadline",
        deadlines={"tight": 6.0, "loose": 40.0},
        cost_model="unit",
    )
    no_class = [FakeSession(10 + i, f"bg{i}", costs=[1.0] * 4)
                for i in range(2)]
    loose = FakeSession(2, "loose", costs=[1.0] * 4)
    tight = FakeSession(1, "tight", costs=[1.0] * 4)
    # admission order deliberately worst-case: background first
    for s in no_class + [loose, tight]:
        sched.add(s)
    finished = sched.drain()
    assert [s.ticket for s in finished] == [1, 2, 10, 11]
    assert tight.deadline_met is True  # finished at clock 4 <= 6
    assert loose.deadline_met is True
    assert no_class[0].deadline_met is None  # no class, no verdict
    assert sched.running == 0


def test_deadline_miss_is_recorded():
    sched = MorselScheduler("deadline", deadlines={"t": 2.0},
                            cost_model="unit")
    slow = FakeSession(1, "t", costs=[1.0] * 5)
    sched.add(slow)
    sched.drain()
    assert slow.deadline_met is False  # finished at clock 5 > 2


def test_wfq_late_joiner_gets_no_banked_credit():
    """A tenant that idles while another runs joins at the current
    virtual-time floor: it immediately shares ~50/50 but never gets a
    monopolizing catch-up burst."""
    sched = MorselScheduler("wfq", cost_model="unit")
    a = FakeSession(0, "A", costs=[1.0] * 200)
    sched.add(a)
    for _ in range(50):
        sched.step()
    b = FakeSession(1, "B", costs=[1.0] * 200)
    sched.add(b)
    a_before, b_before = a.steps_taken, b.steps_taken
    for _ in range(20):
        sched.step()
    a_gain = a.steps_taken - a_before
    b_gain = b.steps_taken - b_before
    assert abs(a_gain - b_gain) <= 1, (a_gain, b_gain)


def test_wfq_share_independent_of_session_flood():
    """The aggressor scenario in miniature: tenant A floods 6 sessions,
    tenant B has 1.  Round-robin gives A 6/7 of the steps; WFQ pins the
    per-tenant split at the weight ratio (1:1) while both are active."""
    def mk(policy):
        sched = MorselScheduler(policy, cost_model="unit")
        for i in range(6):
            sched.add(FakeSession(i, "A", costs=[1.0] * 50))
        sched.add(FakeSession(99, "B", costs=[1.0] * 50))
        for _ in range(70):  # B still running in both policies
            sched.step()
        acct = sched.tenant_accounting()
        return acct["B"]["steps"] / (acct["A"]["steps"]
                                     + acct["B"]["steps"])
    rr_share = mk("rr")
    wfq_share = mk("wfq")
    assert rr_share == pytest.approx(1 / 7, abs=0.03)
    assert wfq_share == pytest.approx(0.5, abs=0.03)
    assert wfq_share > rr_share


def test_scheduler_validates_knobs():
    with pytest.raises(ValueError, match="policy"):
        MorselScheduler("fifo")
    with pytest.raises(ValueError, match="cost model"):
        MorselScheduler("rr", cost_model="wall")
    with pytest.raises(ValueError, match="weight"):
        MorselScheduler("wfq", weights={"A": 0.0})
    with pytest.raises(ValueError, match="default_weight"):
        MorselScheduler("wfq", default_weight=-1.0)


def test_drain_empty_all_policies():
    for policy in ("rr", "wfq", "deadline"):
        sched = MorselScheduler(policy)
        assert sched.drain() == [] and sched.running == 0
        assert sched.sessions() == []


def test_sessions_listing_all_policies():
    for policy in ("rr", "wfq", "deadline"):
        sched = MorselScheduler(policy, cost_model="unit")
        s1 = FakeSession(1, "A", costs=[1.0] * 2)
        s2 = FakeSession(2, "B", costs=[1.0] * 2)
        sched.add(s1)
        sched.add(s2)
        assert {s.ticket for s in sched.sessions()} == {1, 2}
        assert sched.running == 2
        assert sched.tenant_running("A") == 1
        sched.drain()
        assert sched.tenant_running("A") == 0


def test_clock_advances_by_charged_cost():
    sched = MorselScheduler("rr", cost_model="active")
    s = FakeSession(1, None, costs=[2.0, 3.0, 5.0])
    sched.add(s)
    sched.drain()
    assert sched.clock == pytest.approx(10.0)
    assert s.sched_cost == pytest.approx(10.0)
    assert s.finish_clock == pytest.approx(10.0)
    assert s.admit_clock == 0.0
    assert math.isclose(s.active_s, 10.0)
