"""Observability layer: span tracing, metrics export, impute provenance.

Covers the four contracts of docs/observability.md:

* tracing changes **nothing** — answers and imputation totals bit-identical
  to untraced runs across strategy × policy × workers × exec_impl;
* span trees are **structurally deterministic** under the ``unit`` clock
  (CI asserts counts and nesting, never wall time);
* ``explain`` reports **reconcile exactly** with the recorded execution
  counters (per-operator computed totals sum to ``imputations``);
* the export formats are valid: Chrome trace-event JSON and Prometheus
  text exposition.

Plus the serving-telemetry satellites: ``ServingStats.tenant_summary``
edge cases and the ``QuipService.summary()`` schema pin.
"""

from __future__ import annotations

import json
from collections import Counter

import numpy as np
import pytest

from repro.core.env import env_int
from repro.core.stats import ExecutionCounters, QueryRecord, ServingStats
from repro.obs import (
    NULL_SPAN,
    NULL_TRACER,
    ProvenanceRecorder,
    Tracer,
    render_explain,
    resolve_explain,
    resolve_tracer,
)
from repro.service import QuipService
from repro.service.server import SUMMARY_KEYS, expected_summary_keys
from test_quip_correctness import GroundTruthImputer, _build_instance
from test_service import WORKLOAD, _instance, _query, _service

UNIT = dict(enabled=True, clock="unit")


def _traced_service(tables, truth, **kw):
    tracer = Tracer(**UNIT)
    svc = _service(tables, truth, tracer=tracer, explain=True, **kw)
    return svc, tracer


# --------------------------------------------------------------------------- #
# env_int (core/env.py)
# --------------------------------------------------------------------------- #
def test_env_int_parses_and_fails_loud(monkeypatch):
    monkeypatch.delenv("QUIP_TEST_INT", raising=False)
    assert env_int("QUIP_TEST_INT") is None
    assert env_int("QUIP_TEST_INT", 7) == 7
    monkeypatch.setenv("QUIP_TEST_INT", " 42 ")
    assert env_int("QUIP_TEST_INT") == 42
    monkeypatch.setenv("QUIP_TEST_INT", "")
    assert env_int("QUIP_TEST_INT", 9) == 9
    monkeypatch.setenv("QUIP_TEST_INT", "not-a-seed")
    with pytest.raises(ValueError):
        env_int("QUIP_TEST_INT")


# --------------------------------------------------------------------------- #
# tracer unit behavior
# --------------------------------------------------------------------------- #
def test_disabled_tracer_is_allocation_free():
    tr = Tracer(enabled=False)
    # the same shared singleton every call — the zero-allocation contract
    assert tr.span("x", foo=1) is NULL_SPAN
    assert tr.span("y") is NULL_SPAN
    assert NULL_TRACER.span("z") is NULL_SPAN
    assert tr.begin("q") is None
    tr.end(None)  # no-op, no raise
    tr.instant("evt")
    with tr.span("x") as sp:
        assert sp.set(a=1) is sp
    assert tr.spans() == []


def test_unit_clock_nesting_and_ticket_inheritance():
    tr = Tracer(**UNIT)
    with tr.span("outer", ticket=5):
        with tr.span("inner") as sp:
            sp.set(rows=3)
        tr.instant("evt")
    spans = tr.spans(ticket=5)
    by_name = {s.name: s for s in spans}
    assert set(by_name) == {"outer", "inner", "evt"}
    # nested spans inherit ticket + parent from the thread-local stack
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["evt"].parent_id == by_name["outer"].span_id
    assert all(s.ticket == 5 for s in spans)
    assert by_name["inner"].args == {"rows": 3}
    assert tr.span_tree(5) == [
        {"name": "outer", "children": [
            {"name": "inner", "children": []},
            {"name": "evt", "children": []},
        ]},
    ]
    # unit clock: bare monotone ticks, no wall time anywhere
    ticks = sorted(t for s in spans for t in (s.t0, s.t1))
    assert all(float(t).is_integer() for t in ticks)
    assert by_name["outer"].t0 < by_name["inner"].t0 < by_name["outer"].t1


def test_begin_end_cross_thread_span():
    tr = Tracer(**UNIT)
    sid = tr.begin("query", ticket=1, tenant=0)
    with tr.span("step", ticket=1, parent=sid):
        pass
    tr.end(sid, state="done")
    q = tr.spans(name="query")[0]
    assert q.parent_id is None and q.args == {"tenant": 0, "state": "done"}
    assert tr.spans(name="step")[0].parent_id == sid
    tr.end(sid)  # double-end is a no-op
    assert len(tr.spans(name="query")) == 1


def test_span_records_exception_and_propagates():
    tr = Tracer(**UNIT)
    with pytest.raises(KeyError):
        with tr.span("boom"):
            raise KeyError("x")
    assert tr.spans(name="boom")[0].args["error"] == "KeyError"


def test_chrome_trace_schema():
    tr = Tracer(**UNIT)
    sid = tr.begin("query", ticket=3)
    with tr.span("op:select", ticket=3, parent=sid, rows=8):
        tr.instant("admitted", cat="sched")
    tr.end(sid)
    doc = tr.chrome_trace()
    assert doc["metadata"]["clock"] == "unit"
    events = doc["traceEvents"]
    json.dumps(doc)  # must be JSON-serializable as-is
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    complete = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in complete} == {"query", "op:select"}
    for e in complete:
        assert e["dur"] >= 0 and e["pid"] == 3 and e["tid"] >= 1
    (instant,) = [e for e in events if e["ph"] == "i"]
    assert instant["s"] == "t" and instant["pid"] == 3


def test_resolve_tracer_precedence(monkeypatch):
    monkeypatch.delenv("QUIP_TRACE", raising=False)
    monkeypatch.delenv("QUIP_TRACE_CLOCK", raising=False)
    assert resolve_tracer() is NULL_TRACER
    explicit = Tracer(**UNIT)
    assert resolve_tracer(explicit) is explicit  # passthrough, env ignored
    assert resolve_tracer(True).enabled
    assert resolve_tracer(False) is NULL_TRACER
    monkeypatch.setenv("QUIP_TRACE", "1")
    monkeypatch.setenv("QUIP_TRACE_CLOCK", "unit")
    tr = resolve_tracer()
    assert tr.enabled and tr.clock == "unit"
    monkeypatch.setenv("QUIP_TRACE_CLOCK", "sundial")
    with pytest.raises(ValueError):
        resolve_tracer()
    monkeypatch.setenv("QUIP_TRACE_CLOCK", "unit")
    monkeypatch.setenv("QUIP_TRACE", "maybe")
    with pytest.raises(ValueError):
        resolve_tracer()


def test_resolve_explain_precedence(monkeypatch):
    monkeypatch.delenv("QUIP_EXPLAIN", raising=False)
    assert resolve_explain() is False
    assert resolve_explain(True) is True
    monkeypatch.setenv("QUIP_EXPLAIN", "1")
    assert resolve_explain() is True
    assert resolve_explain(False) is False  # explicit beats env


# --------------------------------------------------------------------------- #
# tracing changes nothing: traced vs untraced equivalence
# --------------------------------------------------------------------------- #
# compact tier-1 matrix; the full sweep runs under --runslow below
_EQUIV_COMPACT = [
    ("lazy", "rr", 0, "interp"),
    ("adaptive", "wfq", 0, "interp"),
    ("eager", "deadline", 2, "interp"),
    ("eager", "rr", 0, "compiled"),
]
_EQUIV_FULL = [
    (strategy, policy, workers, impl)
    for strategy in ("eager", "lazy", "adaptive")
    for policy in ("rr", "wfq", "deadline")
    for workers in (0, 2)
    for impl in ("interp", "compiled")
    if not (impl == "compiled" and strategy != "eager")
]


def _run_matrix_case(strategy, policy, workers, exec_impl):
    tables, _clean, truth = _instance()
    kw = dict(strategy=strategy, scheduler_policy=policy, workers=workers,
              cost_model="unit", exec_impl=exec_impl)
    if exec_impl == "compiled":
        # compiled lowering requires the eager/no-VF/no-minmax regime
        kw.update(use_vf=False, minmax_opt=False, compile_after_hits=1)

    def _run(**obs_kw):
        svc = _service(tables, truth, **kw, **obs_kw)
        tenants = [i % 2 for i in range(len(WORKLOAD))]
        tickets = [svc.submit(q, tenant=t)
                   for q, t in zip(WORKLOAD, tenants)]
        svc.run_until_idle()
        answers = [Counter(svc.answers(t)) for t in tickets]
        total = svc.serving.total_counters()
        svc.close()
        return answers, total.imputations, svc.summary()["morsel_steps"]

    base = _run()
    traced = _run(tracer=Tracer(**UNIT), explain=True)
    assert traced == base, (
        f"tracing changed execution under {strategy}/{policy}/"
        f"workers={workers}/{exec_impl}"
    )


@pytest.mark.parametrize("strategy,policy,workers,exec_impl", _EQUIV_COMPACT)
@pytest.mark.timeout(60)
def test_traced_equals_untraced(strategy, policy, workers, exec_impl):
    """With tracing + explain on, answers, imputation totals and morsel
    steps are bit-identical to an untraced service."""
    _run_matrix_case(strategy, policy, workers, exec_impl)


@pytest.mark.slow
@pytest.mark.parametrize("strategy,policy,workers,exec_impl", _EQUIV_FULL)
@pytest.mark.timeout(120)
def test_traced_equals_untraced_full(strategy, policy, workers, exec_impl):
    _run_matrix_case(strategy, policy, workers, exec_impl)


# --------------------------------------------------------------------------- #
# span structure: determinism + expected shape
# --------------------------------------------------------------------------- #
def _traced_run(**kw):
    tables, _clean, truth = _instance()
    svc, tracer = _traced_service(tables, truth, cost_model="unit", **kw)
    tickets = [svc.submit(q) for q in WORKLOAD]
    return svc, tracer, tickets


def test_span_structure_deterministic():
    """Two identical serial runs under the unit clock produce identical
    span counts and identical nesting, per ticket."""
    runs = []
    for _ in range(2):
        svc, tracer, tickets = _traced_run()
        svc.run_until_idle()
        runs.append([
            (tracer.span_counts(t), tracer.span_tree(t)) for t in tickets
        ])
        svc.close()
    assert runs[0] == runs[1]


def test_span_tree_shape_matches_execution():
    """The span tree carries the documented chain: one query root per
    ticket, one morsel_step per scheduler-granted step, operator and
    kernel spans nested under the steps, scheduler instants throughout."""
    svc, tracer, tickets = _traced_run()
    svc.run_until_idle()
    for ticket in tickets:
        counts = tracer.span_counts(ticket)
        assert counts["query"] == 1
        record = next(r for r in svc.serving.records if r.ticket == ticket)
        assert counts["morsel_step"] == record.steps
        assert counts["sched_checkout"] == counts["sched_checkin"]
        assert counts["admitted"] == 1
        assert counts["op:select"] >= 1  # WORKLOAD always selects on R0.v
        assert counts["op:join_build"] >= 1
        # every span of the tree hangs under the single query root
        (root,) = tracer.span_tree(ticket)
        assert root["name"] == "query"
    # one trace export covers all tickets; per-ticket filtering partitions
    doc_all = tracer.chrome_trace()
    per = sum(
        sum(1 for e in tracer.chrome_trace(ticket=t)["traceEvents"]
            if e["ph"] != "M")
        for t in tickets
    )
    assert per == sum(1 for e in doc_all["traceEvents"] if e["ph"] != "M")
    svc.close()


def test_compiled_run_emits_compiled_spans():
    tables, _clean, truth = _instance()
    svc, tracer = _traced_service(
        tables, truth, strategy="eager", exec_impl="compiled",
        compile_after_hits=1, use_vf=False, minmax_opt=False,
        cost_model="unit",
    )
    hot = WORKLOAD[0]
    tickets = [svc.submit(hot) for _ in range(3)]
    svc.run_until_idle()
    assert svc.summary()["compiled_hits"] > 0
    compiled_tickets = [
        t for t in tickets if "compiled_exec" in tracer.span_counts(t)
    ]
    assert compiled_tickets, "no compiled execution was traced"
    counts = tracer.span_counts(compiled_tickets[-1])
    assert counts["morsel_step"] == 1  # one straight-line vectorized pass
    assert "kernel:multi_match" in counts
    svc.close()


# --------------------------------------------------------------------------- #
# explain: provenance reconciliation
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["eager", "lazy", "adaptive"])
def test_explain_reconciles_with_counters(strategy):
    """totals['imputed_cells'] equals the query's ExecutionCounters
    .imputations exactly, and the per-operator rollup sums to it."""
    tables, _clean, truth = _instance()
    svc, _tracer = _traced_service(tables, truth, strategy=strategy)
    tickets = [svc.submit(q) for q in WORKLOAD]
    svc.run_until_idle()
    for ticket in tickets:
        record = next(r for r in svc.serving.records if r.ticket == ticket)
        report = svc.explain(ticket)
        totals = report["totals"]
        assert totals["imputed_cells"] == record.counters.imputations
        assert sum(report["per_op_imputed"].values()) \
            == totals["imputed_cells"]
        assert sum(s["computed"] for s in report["sites"]) \
            == totals["imputed_cells"]
        for site in report["sites"]:
            # requested counts pre-dedup queued tids; computed + hits
            # covers the unique ones
            assert site["computed"] + site["cache_hits"] \
                <= site["requested"]
            assert site["computed"] + site["cache_hits"] > 0
        text = svc.explain_text(ticket)
        assert text.startswith(f"explain ticket={ticket}")
    svc.close()


def test_explain_decision_log_adaptive_costs():
    """Adaptive runs log every decision-function evaluation with the §9.2
    expected costs; eager/obligated verdicts carry reasons, not costs."""
    tables, _clean, truth = _instance()
    svc, _tracer = _traced_service(tables, truth, strategy="adaptive")
    ticket = svc.submit(_query(4))
    svc.run_until_idle()
    decisions = svc.explain(ticket)["decisions"]
    assert decisions, "adaptive run logged no decisions"
    reasons = {d["reason"] for d in decisions}
    assert reasons <= {"obligated", "cost:impute", "cost:delay"}
    for d in decisions:
        if d["reason"].startswith("cost:"):
            assert {"est_imp_impute", "est_imp_delay",
                    "est_qp_impute", "est_qp_delay"} <= set(d)
            expect = ((d["est_imp_impute"] - d["est_imp_delay"])
                      + (d["est_qp_impute"] - d["est_qp_delay"])) < 0.0
            assert d["impute"] == expect
        else:
            assert d["impute"] and "est_imp_impute" not in d
    assert "decision-function log" in svc.explain_text(ticket)
    svc.close()


def test_explain_result_cache_hit_and_errors():
    tables, _clean, truth = _instance()
    svc, _tracer = _traced_service(tables, truth, result_cache_size=8)
    q = _query(2)
    first = svc.submit(q)
    svc.run_until_idle()
    second = svc.submit(q)  # result-cache hit: born DONE
    assert svc.explain(second)["result_cache_hit"] is True
    assert "result-cache hit" in svc.explain_text(second)
    with pytest.raises(KeyError):
        svc.explain(10_000)
    svc.release(first)
    with pytest.raises(KeyError):  # reports die with release()
        svc.explain(first)
    svc.close()

    plain = _service(tables, truth)
    t = plain.submit(q)
    plain.run_until_idle()
    with pytest.raises(RuntimeError):
        plain.explain(t)
    plain.close()


def test_provenance_unattributed_fallback():
    prov = ProvenanceRecorder()
    prov.on_flush("R0", "R0.v", 4, 3, 1, 0, 0.25)
    with prov.at("select", 7):
        prov.on_flush("R0", "R0.v", 2, 2, 0, 0, 0.5)
    report = prov.report()
    assert report["totals"]["imputed_cells"] == 5
    assert report["per_op_imputed"] == {"select": 2, "unattributed": 3}
    assert "unattributed" in render_explain(report)


# --------------------------------------------------------------------------- #
# metrics: snapshot + Prometheus exposition
# --------------------------------------------------------------------------- #
def test_metrics_snapshot_tracks_serving_state():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, cost_model="unit")
    tickets = [svc.submit(q, tenant=i % 2)
               for i, q in enumerate(WORKLOAD)]
    svc.run_until_idle()
    snap = svc.metrics()
    summary = svc.summary()
    assert snap["quip_queries_total"]["value"] == len(WORKLOAD)
    assert snap["quip_morsel_steps_total"]["value"] \
        == summary["morsel_steps"]
    assert snap["quip_imputations_total"]["value"] == summary["imputations"]
    assert snap["quip_inflight"]["value"] == 0
    hist = snap["quip_query_latency_seconds"]
    assert hist["type"] == "histogram"
    assert hist["count"] == len(WORKLOAD)
    per_tenant = snap["quip_tenant_queries_total"]
    assert per_tenant["label"] == "tenant"
    assert sum(per_tenant["values"].values()) == len(WORKLOAD)
    json.dumps(snap)  # JSON-able end to end
    del tickets
    svc.close()


def _parse_prometheus(text):
    """Minimal exposition-format validator: returns {name: type}."""
    types = {}
    helped = set()
    for line in text.strip().splitlines():
        assert line, "blank line inside exposition"
        if line.startswith("# HELP "):
            helped.add(line.split()[2])
        elif line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            assert name in helped, f"# TYPE before # HELP for {name}"
            assert kind in ("counter", "gauge", "histogram")
            types[name] = kind
        else:
            sample = line.split()[0].split("{")[0]
            base = sample
            for suffix in ("_bucket", "_sum", "_count"):
                if sample.endswith(suffix) \
                        and sample[: -len(suffix)] in types:
                    base = sample[: -len(suffix)]
            assert base in types, f"sample {sample} missing # TYPE"
            float(line.rsplit(" ", 1)[1].replace("+Inf", "inf"))
    return types


def test_metrics_prometheus_exposition():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth, shared=True, cost_model="unit")
    for q in WORKLOAD:
        svc.submit(q)
    svc.run_until_idle()
    text = svc.metrics(fmt="prometheus")
    types = _parse_prometheus(text)
    assert types["quip_queries_total"] == "counter"
    assert types["quip_query_latency_seconds"] == "histogram"
    assert types["quip_store_filled_cells"] == "gauge"  # shared store on
    assert 'quip_query_latency_seconds_bucket{le="+Inf"}' in text
    with pytest.raises(ValueError):
        svc.metrics(fmt="xml")
    svc.close()


def test_metrics_names_unique_and_cheap_when_idle():
    tables, _clean, truth = _instance()
    svc = _service(tables, truth)
    names = svc._metrics.names()
    assert len(names) == len(set(names))
    assert all(n.startswith("quip_") for n in names)
    snap = svc.metrics()  # zero queries: everything renders at 0
    assert snap["quip_queries_total"]["value"] == 0
    assert snap["quip_query_latency_seconds"]["count"] == 0
    svc.close()


# --------------------------------------------------------------------------- #
# export_trace
# --------------------------------------------------------------------------- #
def test_export_trace_writes_loadable_json(tmp_path):
    tables, _clean, truth = _instance()
    svc, _tracer = _traced_service(tables, truth)
    ticket = svc.submit(_query(2))
    svc.run_until_idle()
    path = tmp_path / "trace.json"
    doc = svc.export_trace(str(path), ticket=ticket)
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(doc, default=str))
    assert on_disk["metadata"]["clock"] == "unit"
    assert any(e["name"] == "query" for e in on_disk["traceEvents"])
    svc.close()


# --------------------------------------------------------------------------- #
# satellite: ServingStats.tenant_summary edge cases
# --------------------------------------------------------------------------- #
def _record(ticket, tenant, *, failed=False, steps=3, cost=3.0,
            admit=0.0, finish=3.0, deadline_met=None, latency=0.01):
    return QueryRecord(
        ticket=ticket, tenant=tenant, strategy="lazy",
        queue_wait_s=0.0, latency_s=latency, plan_cache_hit=False,
        counters=ExecutionCounters(), failed=failed, steps=steps,
        sched_cost=cost, admit_clock=admit, finish_clock=finish,
        deadline_met=deadline_met,
    )


def test_tenant_summary_zero_finished_queries():
    stats = ServingStats()
    assert stats.tenant_summary() == {}
    assert stats.latency_quantile(0.95) == 0.0
    summary = stats.summary()
    assert summary["queries"] == 0 and summary["imputations"] == 0


def test_tenant_summary_all_failed_tenant():
    stats = ServingStats()
    for i in range(3):
        stats.record_query(_record(i, tenant=7, failed=True))
    out = stats.tenant_summary()[7]
    assert out["queries"] == 3 and out["failed"] == 3
    assert out["deadline_hit_rate"] is None  # no deadline class anywhere
    assert out["cost_share"] == 1.0  # sole tenant carries all charged cost


def test_tenant_summary_unadmitted_excluded_from_turnaround():
    """A cancelled-in-queue record (admit_clock None, steps 0) must not
    drag the turnaround quantile toward zero."""
    stats = ServingStats()
    stats.record_query(_record(1, tenant=0, admit=0.0, finish=10.0,
                               steps=10, cost=10.0))
    stats.record_query(_record(2, tenant=0, failed=True, steps=0,
                               cost=0.0, admit=None, finish=None))
    out = stats.tenant_summary()[0]
    assert out["queries"] == 2
    assert out["p95_turnaround_cost"] == 10.0  # only the admitted record
    assert _record(2, 0, admit=None, finish=None).turnaround_cost is None


def test_tenant_summary_mixed_deadline_classes():
    stats = ServingStats()
    stats.record_query(_record(1, tenant=0, deadline_met=True))
    stats.record_query(_record(2, tenant=0, deadline_met=False))
    stats.record_query(_record(3, tenant=0, deadline_met=None))  # no class
    stats.record_query(_record(4, tenant=1, deadline_met=None))
    out = stats.tenant_summary()
    # hit rate aggregates only records that carried a deadline class
    assert out[0]["deadline_hit_rate"] == pytest.approx(0.5)
    assert out[1]["deadline_hit_rate"] is None
    total = sum(out[t]["cost_share"] for t in out)
    assert total == pytest.approx(1.0)


# --------------------------------------------------------------------------- #
# satellite: summary() schema pin
# --------------------------------------------------------------------------- #
def test_summary_keys_documented_and_pinned():
    """Every key summary() can emit is documented in SUMMARY_KEYS, and the
    emitted key set matches expected_summary_keys() for each config."""
    assert all(isinstance(v, str) and v for v in SUMMARY_KEYS.values())
    tables, _clean, truth = _instance()
    configs = [
        (dict(), dict(result_cache=True, shared_store=False)),
        (dict(result_cache_size=0), dict(result_cache=False,
                                         shared_store=False)),
        (dict(shared=True), dict(result_cache=True, shared_store=True)),
        (dict(result_cache_size=0, shared=True),
         dict(result_cache=False, shared_store=True)),
    ]
    for svc_kw, expect_kw in configs:
        svc = _service(tables, truth, **svc_kw)
        svc.submit(_query(2))
        svc.run_until_idle()
        got = set(svc.summary())
        assert got == expected_summary_keys(**expect_kw), (
            f"summary schema drifted under {svc_kw}: "
            f"extra={got - expected_summary_keys(**expect_kw)} "
            f"missing={expected_summary_keys(**expect_kw) - got}"
        )
        svc.close()
    assert expected_summary_keys() < set(SUMMARY_KEYS) | set()
    assert expected_summary_keys(result_cache=False,
                                 shared_store=True) <= set(SUMMARY_KEYS)


# --------------------------------------------------------------------------- #
# tracing with worker pool: counts still reconcile (structure is
# thread-interleaved, so only aggregate invariants are asserted)
# --------------------------------------------------------------------------- #
@pytest.mark.timeout(60)
def test_traced_worker_pool_counts_reconcile():
    rng = np.random.default_rng(3)
    tables, _clean, truth = _build_instance(rng, 2, 48, 0.3, 5)
    svc, tracer = _traced_service(tables, truth, workers=2,
                                  cost_model="unit")
    tickets = [svc.submit(q) for q in WORKLOAD]
    svc.run_until_idle()
    for ticket in tickets:
        record = next(r for r in svc.serving.records if r.ticket == ticket)
        counts = tracer.span_counts(ticket)
        assert counts["query"] == 1
        assert counts["morsel_step"] == record.steps
        assert svc.explain(ticket)["totals"]["imputed_cells"] \
            == record.counters.imputations
    assert GroundTruthImputer is not None
    svc.close()
