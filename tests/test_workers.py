"""Worker-pool serving tests + the serving-layer bugfix sweep.

Tentpole: ``QuipService(workers=N)`` runs N threads pulling morsel steps
through the scheduler's checkout/checkin split.  The invariant under
test is the same as the serial serving fuzzer's — every answer is
**bit-identical to a cold serial replay** on the admission snapshot —
now under real threads, for every scheduler policy × sharing mode, with
intra-query sibling-morsel fan-out in the mix.

Also here: regression tests for the bugfixes that rode along in this
change (compound tickets polling ``running`` forever after result-cache
hits, ``TableRegistry._commit`` skipping later after-hooks when one
raises, ``LruCache`` capacity validation vanishing under ``python -O``,
and never-admitted sessions masquerading as ``admit_clock=0``).

Threaded tests carry ``@pytest.mark.timeout`` so a pool deadlock fails
fast instead of hanging the suite (see conftest for the SIGALRM
fallback when pytest-timeout is not installed).
"""

from __future__ import annotations

import threading
from collections import Counter

import numpy as np
import pytest

from repro.analysis import lockcheck
from repro.core.stats import QueryRecord, ServingStats
from repro.service import QuipService, TableRegistry
from repro.service.lru import LruCache
from test_quip_correctness import GroundTruthImputer, _build_instance
from test_serving_fuzz import MORSEL_ROWS, _rand_mutation, _rand_query, _replay

STRATEGIES = ("offline", "eager", "lazy", "adaptive")


@pytest.fixture(autouse=True)
def _lock_sanitizer(monkeypatch):
    """Run every worker-pool test under the lock-order sanitizer: services
    built in the test use instrumented locks, and teardown asserts the
    acquisition-order graph stayed acyclic (docs/analysis.md).  Answers are
    unaffected — the bit-identical replay asserts below double as the
    sanitizer-transparency check."""
    monkeypatch.setenv("QUIP_SANITIZE", "locks")
    lockcheck.reset()
    yield
    lockcheck.assert_acyclic()


def _instance(seed: int, rows: int = 48):
    tables, _clean, truth = _build_instance(
        np.random.default_rng(seed), 2, rows, 0.3, 6
    )
    return tables, truth


def _service(tables, truth, **kw):
    kw.setdefault("morsel_rows", MORSEL_ROWS)
    kw.setdefault("cost_model", "unit")
    return QuipService(
        {t: r.copy() for t, r in tables.items()},
        lambda: GroundTruthImputer(truth),
        **kw,
    )


# --------------------------------------------------------------------------- #
# tentpole: pool answers == serial answers, every policy × sharing mode
# --------------------------------------------------------------------------- #
@pytest.mark.timeout(120)
@pytest.mark.parametrize("policy", ["rr", "wfq", "deadline"])
@pytest.mark.parametrize("shared", [False, True])
def test_pool_matches_serial(policy, shared):
    tables, truth = _instance(11)
    rng = np.random.default_rng(42)
    cases = [
        (_rand_query(rng), STRATEGIES[int(rng.integers(0, 4))])
        for _ in range(10)
    ]

    serial = _service(tables, truth, result_cache_size=0)
    want = []
    for query, strategy in cases:
        want.append(Counter(serial.answers(
            serial.submit(query, strategy=strategy)
        )))
    serial.close()

    svc = _service(tables, truth, result_cache_size=0, workers=3,
                   scheduler_policy=policy, shared_impute=shared,
                   tenant_weights={0: 2.0}, tenant_deadlines={1: 64.0})
    tickets = [
        svc.submit(query, strategy=strategy, tenant=i % 3)
        for i, (query, strategy) in enumerate(cases)
    ]
    svc.run_until_idle()
    for ticket, reference in zip(tickets, want):
        assert svc.poll(ticket) == "done"
        assert Counter(svc.answers(ticket)) == reference
    assert svc.summary()["failed"] == 0
    svc.close()


@pytest.mark.timeout(120)
def test_pool_scales_from_one_worker():
    """workers=1 is a degenerate-but-valid pool: same answers, and the
    intra-query runner falls back to inline execution (size <= 1)."""
    tables, truth = _instance(5)
    rng = np.random.default_rng(7)
    cases = [(_rand_query(rng), "lazy") for _ in range(6)]
    reference = []
    serial = _service(tables, truth, result_cache_size=0)
    for query, strategy in cases:
        reference.append(
            Counter(serial.answers(serial.submit(query, strategy=strategy)))
        )
    serial.close()
    for workers in (1, 2, 4):
        svc = _service(tables, truth, result_cache_size=0, workers=workers)
        tickets = [svc.submit(q, strategy=s) for q, s in cases]
        svc.run_until_idle()
        got = [Counter(svc.answers(t)) for t in tickets]
        assert got == reference, f"workers={workers} diverged"
        svc.close()


@pytest.mark.timeout(120)
def test_pool_result_blocks_and_caches():
    """Pool-mode result() waits on the workers; a repeated signature on
    unmutated tables is a result-cache hit even across threads."""
    tables, truth = _instance(3)
    rng = np.random.default_rng(1)
    query = _rand_query(rng)
    svc = _service(tables, truth, workers=2, result_cache_size=8)
    t1 = svc.submit(query, strategy="lazy")
    first = Counter(svc.result(t1).answer_tuples())
    t2 = svc.submit(query, strategy="lazy")
    assert Counter(svc.result(t2).answer_tuples()) == first
    svc.run_until_idle()
    hits = [r.result_cache_hit for r in svc.serving.records]
    assert hits.count(True) >= 1
    svc.close()


@pytest.mark.timeout(120)
def test_pool_compounds_and_failures():
    """Compounds resolve under the pool, and a failing branch surfaces
    through result() without wedging the workers."""
    tables, truth = _instance(9)
    rng = np.random.default_rng(2)
    left, right = _rand_query(rng), _rand_query(rng)
    serial = _service(tables, truth, result_cache_size=0)
    want, _stats = serial.result(serial.submit_union(left, right))
    serial.close()

    svc = _service(tables, truth, workers=2, result_cache_size=0)
    ticket = svc.submit_union(left, right)
    answers, _stats = svc.result(ticket)
    assert Counter(answers) == Counter(want)

    from repro.core.plan import Query
    bad = Query(("NoSuchTable",), (), (), ("NoSuchTable.v",))
    bad_ticket = svc.submit(bad)
    with pytest.raises(KeyError):
        svc.result(bad_ticket)
    assert svc.poll(bad_ticket) == "failed"
    # the pool survives the failure and keeps serving
    again = svc.submit(left, strategy="lazy")
    svc.result(again)
    svc.run_until_idle()
    svc.close()


@pytest.mark.timeout(60)
def test_pool_disables_inline_step():
    tables, truth = _instance(4)
    svc = _service(tables, truth, workers=2)
    ticket = svc.submit(_rand_query(np.random.default_rng(0)))
    with pytest.raises(RuntimeError, match="worker pool"):
        svc.step()
    svc.run_until_idle()
    assert svc.poll(ticket) == "done"
    svc.close()
    # close() detaches the pool: inline stepping is legal again
    assert svc.step() is False


# --------------------------------------------------------------------------- #
# tentpole: threaded serving fuzzer — concurrent submit/poll/result under
# real threads, mutations between quiesced rounds, replay-verified answers
# --------------------------------------------------------------------------- #
def _threaded_fuzz(seed: int, policy: str, shared: bool, *, workers: int,
                   rounds: int, submitters: int, per_thread: int,
                   rows: int = 48, mutations: bool = True) -> None:
    ctx = (f"[threaded-fuzz] seed={seed} policy={policy} shared={shared} "
           f"workers={workers} rounds={rounds} submitters={submitters} "
           f"per_thread={per_thread} mutations={mutations}")
    print(ctx)  # reproducibility: shown on failure
    tables, _clean, truth = _build_instance(
        np.random.default_rng(seed + 1000), 2, rows, 0.3, 6
    )
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    factory = lambda: GroundTruthImputer(truth)  # noqa: E731
    svc = QuipService(
        reg, factory, shared_impute=shared, max_inflight=3,
        morsel_rows=MORSEL_ROWS, scheduler_policy=policy, cost_model="unit",
        tenant_weights={0: 2.0}, tenant_deadlines={1: 64.0},
        tenant_quotas={2: 1}, result_cache_size=8, workers=workers,
    )
    submitted = []  # (ticket, query, strategy, round snapshot)
    mut_rng = np.random.default_rng(seed + 2000)

    for rnd in range(rounds):
        # mutations only land on a quiesced service (the shared store's
        # veto requires it), so the round snapshot is the exact admission
        # state for every query submitted this round
        snapshot = {t: reg[t].copy() for t in reg}
        collected = [None] * submitters
        stop_polling = threading.Event()

        def submit_some(slot: int) -> None:
            rng = np.random.default_rng(seed + 10_000 * (rnd + 1) + slot)
            mine = []
            for _ in range(per_thread):
                query = _rand_query(rng)
                strategy = STRATEGIES[int(rng.integers(0, len(STRATEGIES)))]
                ticket = svc.submit(query, strategy=strategy,
                                    tenant=int(rng.integers(0, 3)))
                mine.append((ticket, query, strategy))
            collected[slot] = mine

        def poll_some() -> None:
            rng = np.random.default_rng(seed + 77)
            while not stop_polling.is_set():
                if submitted:
                    t = submitted[int(rng.integers(0, len(submitted)))][0]
                    assert svc.poll(t) in {
                        "queued", "running", "done", "failed"
                    }, ctx

        threads = [
            threading.Thread(target=submit_some, args=(slot,), daemon=True)
            for slot in range(submitters)
        ]
        threads.append(threading.Thread(target=poll_some, daemon=True))
        for t in threads:
            t.start()
        for t in threads[:-1]:
            t.join(timeout=60)
            assert not t.is_alive(), f"{ctx} submitter wedged"
        stop_polling.set()
        threads[-1].join(timeout=60)
        assert not threads[-1].is_alive(), f"{ctx} poller wedged"
        for mine in collected:
            assert mine is not None, f"{ctx} submitter died"
            submitted.extend(
                (ticket, query, strategy, snapshot)
                for ticket, query, strategy in mine
            )
        svc.run_until_idle()
        if mutations:
            _rand_mutation(mut_rng, reg)

    svc.run_until_idle()
    summary = svc.summary()
    assert summary["queries"] == len(submitted), ctx
    assert summary["failed"] == 0, ctx
    for ticket, query, strategy, snapshot in submitted:
        assert svc.poll(ticket) == "done", f"{ctx} ticket {ticket} not done"
        got = Counter(svc.answers(ticket))
        want = Counter(
            _replay(query, strategy, snapshot, factory).answer_tuples()
        )
        assert got == want, (
            f"{ctx} ticket {ticket} strategy={strategy} diverged from "
            f"cold serial replay"
        )
    svc.close()


@pytest.mark.timeout(300)
@pytest.mark.parametrize("seed,policy,shared", [
    (0, "rr", False),
    (1, "wfq", True),
])
def test_threaded_fuzz_fast(seed, policy, shared):
    _threaded_fuzz(seed, policy, shared, workers=3, rounds=2,
                   submitters=3, per_thread=4)


@pytest.mark.slow
@pytest.mark.timeout(600)
@pytest.mark.parametrize("seed", range(2, 6))
@pytest.mark.parametrize("policy", ["rr", "wfq", "deadline"])
@pytest.mark.parametrize("shared", [False, True])
def test_threaded_fuzz_deep(seed, policy, shared):
    _threaded_fuzz(seed, policy, shared, workers=4, rounds=3,
                   submitters=4, per_thread=5, rows=56)


# --------------------------------------------------------------------------- #
# bugfix sweep regressions
# --------------------------------------------------------------------------- #
def test_compound_poll_truthful_on_cache_hits():
    """A compound whose branches all hit the result cache must poll
    ``done`` immediately — previously it reported ``running`` forever
    because resolution only happened inside step()."""
    tables, truth = _instance(6)
    rng = np.random.default_rng(3)
    left, right = _rand_query(rng), _rand_query(rng)
    svc = _service(tables, truth, result_cache_size=16)
    # warm the cache
    svc.result(svc.submit(left, strategy="lazy"))
    svc.result(svc.submit(right, strategy="lazy"))
    ticket = svc.submit_union(left, right, strategy="lazy")
    assert svc.poll(ticket) == "done"  # no step() in between
    answers, _stats = svc.result(ticket)
    assert Counter(answers) == Counter(
        svc.result(svc.submit_union(left, right, strategy="lazy"))[0]
    )
    svc.close()


def test_nested_compound_resolves_at_submit_via_cache():
    tables, truth = _instance(6)
    rng = np.random.default_rng(8)
    outer, sub = _rand_query(rng), _rand_query(rng)
    svc = _service(tables, truth, result_cache_size=16)
    first = svc.submit_nested(outer, f"{outer.tables[0]}.v", sub,
                              strategy="lazy")
    want, _stats = svc.result(first)
    # sub AND the rewritten outer are now cached: submit-time resolution
    # must land the repeat compound DONE with zero scheduler steps
    again = svc.submit_nested(outer, f"{outer.tables[0]}.v", sub,
                              strategy="lazy")
    assert svc.poll(again) == "done"
    assert Counter(svc.result(again)[0]) == Counter(want)
    svc.close()


def test_registry_commit_runs_all_after_hooks():
    """One raising after-hook must not starve later subscribers — the
    epoch has advanced, so every cache must still observe the mutation."""
    tables, truth = _instance(2)
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    seen = []

    def bad(table):
        seen.append(("bad", table))
        raise ValueError("subscriber exploded")

    def good(table):
        seen.append(("good", table))

    reg.subscribe(bad)
    reg.subscribe(good)
    before = reg.epoch("R0")
    with pytest.raises(ValueError, match="subscriber exploded"):
        reg.update_rows("R0", np.array([0]), {"R0.v": np.array([1])})
    assert ("good", "R0") in seen, "later subscriber was skipped"
    assert reg.epoch("R0") == before + 1


def test_registry_commit_aggregates_multiple_hook_errors():
    tables, truth = _instance(2)
    reg = TableRegistry({t: r.copy() for t, r in tables.items()})
    reg.subscribe(lambda t: (_ for _ in ()).throw(ValueError("first")))
    reg.subscribe(lambda t: (_ for _ in ()).throw(KeyError("second")))
    with pytest.raises(RuntimeError, match="2 post-commit subscribers"):
        reg.update_rows("R0", np.array([0]), {"R0.v": np.array([1])})
    try:
        reg.update_rows("R0", np.array([0]), {"R0.v": np.array([1])})
    except RuntimeError as e:
        assert isinstance(e.__cause__, ValueError)  # first error chained


def test_lru_capacity_validation_survives_optimized_mode():
    """`assert` would vanish under ``python -O``; the ValueError must not."""
    with pytest.raises(ValueError, match="capacity"):
        LruCache(-1)
    # capacity 0 uniformly disables: inserts are dropped, lookups miss
    cache = LruCache(0)
    cache.insert("k", "v")
    assert cache.lookup("k") is None


def test_unadmitted_sessions_excluded_from_turnaround():
    """A session cancelled before admission must record
    ``admit_clock=None`` (not clock-0) and stay out of the turnaround
    quantiles."""
    tables, truth = _instance(2)
    rng = np.random.default_rng(4)
    svc = _service(tables, truth, max_inflight=1, result_cache_size=0)
    ran = svc.submit(_rand_query(rng), tenant=0)
    queued = svc.submit(_rand_query(rng), tenant=0)  # blocked behind ran
    svc.result(ran)
    # re-fill the single slot so the next close() cancels something
    svc.submit(_rand_query(rng), tenant=0)
    stuck = svc.submit(_rand_query(rng), tenant=0)
    assert svc.poll(stuck) == "queued"
    svc.close()
    by_ticket = {r.ticket: r for r in svc.serving.records}
    assert by_ticket[stuck].failed
    assert by_ticket[stuck].admit_clock is None
    assert by_ticket[stuck].finish_clock is None
    assert by_ticket[stuck].turnaround_cost is None
    assert by_ticket[ran].turnaround_cost is not None
    # quantiles come only from admitted-and-stepped sessions — the record
    # with admit_clock=None must not drag p95 toward zero or crash
    summary = svc.tenant_summary()
    assert 0 in summary
    _ = queued  # admitted once `ran` finished; just part of the traffic


def test_query_record_turnaround_none_semantics():
    rec = QueryRecord(ticket=1, tenant=None, strategy="lazy",
                      queue_wait_s=0.0, latency_s=0.0, plan_cache_hit=False,
                      counters=None, admit_clock=None, finish_clock=None)
    assert rec.turnaround_cost is None
    stats = ServingStats()
    assert stats is not None
