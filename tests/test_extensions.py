"""§9.3 extension tests: union / set-minus / nested queries match the
clean-oracle evaluation (ground-truth imputer)."""

from __future__ import annotations

import numpy as np
import pytest
from collections import Counter

from repro.core.executor import evaluate_clean
from repro.core.extensions import execute_minus, execute_nested, execute_union
from repro.core.plan import Query
from repro.core.predicates import JoinPredicate, SelectionPredicate
from repro.imputers.base import ImputationEngine
from test_quip_correctness import GroundTruthImputer, _build_instance


@pytest.fixture
def inst():
    rng = np.random.default_rng(77)
    tables, clean, truth = _build_instance(rng, 2, 40, 0.3, 6)
    factory = lambda: ImputationEngine(
        {t: tables[t].copy() for t in tables},
        default=lambda: GroundTruthImputer(truth),
    )
    return tables, clean, factory


def _q(sel_value: int) -> Query:
    return Query(
        tables=("R0", "R1"),
        selections=(SelectionPredicate("R0.v", "<=", sel_value),),
        joins=(JoinPredicate("R0.k1", "R1.k1"),),
        projection=("R0.v", "R1.v"),
    )


def test_union_matches_clean(inst):
    tables, clean, factory = inst
    l, r = _q(2), _q(4)
    got, stats = execute_union(l, r, tables, factory)
    want = (evaluate_clean(l, clean).to_sorted_tuples()
            + evaluate_clean(r, clean).to_sorted_tuples())
    assert Counter(got) == Counter(want)
    assert stats["imputations"] > 0


def test_minus_matches_clean(inst):
    tables, clean, factory = inst
    l, r = _q(4), _q(2)
    got, _ = execute_minus(l, r, tables, factory)
    want = sorted((
        Counter(evaluate_clean(l, clean).to_sorted_tuples())
        - Counter(evaluate_clean(r, clean).to_sorted_tuples())
    ).elements())
    assert got == want


def test_union_stats_propagate_full_counters(inst):
    """Compound queries report the branches' full merged ExecutionCounters,
    not just an imputation total."""
    tables, _clean, factory = inst
    _got, stats = execute_union(_q(2), _q(4), tables, factory)
    for key in ("imputations", "impute_batches", "impute_flushes",
                "join_impl", "wall_seconds", "temp_tuples"):
        assert key in stats, key
    assert stats["imputations"] > 0
    assert stats["impute_batches"] >= 2  # both branches imputed
    assert stats["impute_flushes"] > 0
    assert stats["join_impl"] in ("numpy", "ref", "pallas")


def test_empty_in_set_is_always_false():
    """Satellite regression: an empty IN-set is a proper always-false
    predicate (the old code used a magic sentinel value and would crash on
    an empty frozenset)."""
    pred = SelectionPredicate("R0.v", "in", frozenset())
    vals = np.array([0, 1, -(2 ** 60), 7])
    assert not pred.evaluate_values(vals).any()
    assert pred.evaluate_values(np.array([], dtype=np.int64)).shape == (0,)


def test_nested_empty_subquery_result(inst):
    """Satellite regression: an empty subquery result must yield an empty
    outer answer (via the always-false predicate path, no sentinels)."""
    tables, _clean, factory = inst
    outer = Query(tables=("R0",), selections=(), joins=(),
                  projection=("R0.v",))
    sub = Query(
        tables=("R1",),
        selections=(SelectionPredicate("R1.v", "<=", -(10 ** 9)),),
        joins=(),
        projection=("R1.k1",),
    )
    got, stats = execute_nested(outer, "R0.k1", sub, tables, factory)
    assert got == []
    assert stats["imputations"] >= 0  # merged counters still reported


def test_nested_in_subquery_matches_clean(inst):
    tables, clean, factory = inst
    outer = Query(
        tables=("R0",), selections=(), joins=(), projection=("R0.v",),
    )
    sub = Query(
        tables=("R1",),
        selections=(SelectionPredicate("R1.v", "<=", 2),),
        joins=(),
        projection=("R1.k1",),
    )
    got, _ = execute_nested(outer, "R0.k1", sub, tables, factory)

    sub_clean = evaluate_clean(sub, clean)
    vals = frozenset(int(v) for v in sub_clean.values("R1.k1"))
    outer_clean = Query(
        tables=("R0",),
        selections=(SelectionPredicate("R0.k1", "in",
                                       vals or frozenset({-1})),),
        joins=(), projection=("R0.v",),
    )
    want = evaluate_clean(outer_clean, clean).to_sorted_tuples()
    assert Counter(got) == Counter(want)
