"""Delta-driven cache maintenance (QUIP_IVM): Z-set algebra, registry
deltas + pre-commit validation, the LRU reverse index, and service-level
patch/fallback behaviour.

The correctness contract everywhere: a patched cached answer is
bit-identical to what a cold re-execution over the mutated registry would
produce, and per mutation every dependent cached answer is either patched
or evicted (never silently left stale)."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.delta import (
    TableDelta,
    ZSet,
    delta_for_delete,
    delta_for_insert,
    delta_for_update,
)
from repro.core.plan import Aggregate, Query
from repro.core.predicates import SelectionPredicate
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import ImputationService
from repro.imputers.mean import MeanImputer
from repro.core.executor import execute_quip
from repro.service import QuipService, TableRegistry
from repro.service.ivm import referenced_attrs, resolve_ivm
from repro.service.lru import LruCache


# --------------------------------------------------------------------------- #
# ZSet: abelian-group laws
# --------------------------------------------------------------------------- #
def test_zset_group_laws():
    a = ZSet.from_rows([(1,), (1,), (2,)])
    b = ZSet.from_rows([(2,), (3,)], weight=-1)
    zero = ZSet()
    assert a.add(b) == b.add(a)  # commutative
    c = ZSet.from_rows([(9,)])
    assert a.add(b).add(c) == a.add(b.add(c))  # associative
    assert a.add(zero) == a  # identity
    assert a.add(a.negate()).consolidate() == zero  # inverse
    assert len(a.add(a.negate())) == 0  # consolidated length


def test_zset_weights_and_positivity():
    z = ZSet.from_rows([(1,), (1,), (2,)])
    assert z.weight((1,)) == 2 and z.weight((2,)) == 1
    assert z.weight((3,)) == 0
    assert z.is_positive()
    removed = z.add(ZSet.from_rows([(2,), (2,)], weight=-1))
    assert not removed.consolidate().is_positive()
    assert removed.weight((2,)) == -1


def test_zset_unhashable():
    with pytest.raises(TypeError):
        hash(ZSet())


# --------------------------------------------------------------------------- #
# registry deltas
# --------------------------------------------------------------------------- #
def _table(name="T", n=6):
    schema = Schema(name, [ColumnSpec(f"{name}.k", "int"),
                           ColumnSpec(f"{name}.v", "int")])
    return MaskedRelation.from_columns(
        schema,
        {f"{name}.k": np.arange(n, dtype=np.int64),
         f"{name}.v": np.arange(n, dtype=np.int64) * 10},
        base_table=name,
    )


def _capture(reg):
    seen = []
    reg.subscribe(lambda table, delta: seen.append((table, delta)),
                  delta=True)
    return seen


def test_update_delta_shape():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    reg.update_rows("T", np.array([1, 3]), {"T.v": np.array([111, 333])})
    (table, delta), = seen
    assert table == "T"
    assert delta.removed_rows == 2 and delta.added_rows == 2
    z = delta.to_zset().consolidate()
    # update = remove old + add new, keyed (positional tid, row values)
    assert z.weight((0, (1, 10))) == -1 and z.weight((0, (1, 111))) == 1
    assert z.weight((1, (3, 30))) == -1 and z.weight((1, (3, 333))) == 1


def test_noop_update_cancels_in_zset():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    reg.update_rows("T", np.array([2]), {"T.v": np.array([20])})  # same value
    (_, delta), = seen
    assert delta is not None
    assert delta.to_zset().consolidate() == ZSet()


def test_delete_and_insert_deltas():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    reg.delete_rows("T", np.array([0, 5]))
    reg.insert_rows("T", {"T.k": np.array([7]), "T.v": np.array([70])})
    (_, d_del), (_, d_ins) = seen
    assert d_del.added is None and d_del.removed_rows == 2
    assert d_ins.removed is None and d_ins.added_rows == 1
    assert d_ins.to_zset().weight((0, (7, 70))) == 1


def test_duplicate_update_rows_yield_no_delta():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    reg.update_rows("T", np.array([2, 2]), {"T.v": np.array([5, 6])})
    (_, delta), = seen
    assert delta is None  # inexpressible: later write wins in set_values


def test_replace_table_yields_no_delta():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    reg.replace_table("T", _table(n=3))
    (_, delta), = seen
    assert delta is None


def test_delta_slices_are_canonical_standalone_tables():
    rel = _table()
    d = delta_for_update("T", rel, rel, np.array([4, 2]))
    # slices carry arange tids (valid standalone tables for sub-execution)
    np.testing.assert_array_equal(d.removed.tids["T"], [0, 1])
    assert d.removed.values("T.k").tolist() == [4, 2]
    assert delta_for_delete("T", rel, np.array([3, 1, 3])).removed_rows == 2
    grown = _table(n=8)
    assert delta_for_insert("T", grown, 6).added.values("T.k").tolist() == [6, 7]


# --------------------------------------------------------------------------- #
# satellite: pre-commit mutation validation (nothing committed on failure)
# --------------------------------------------------------------------------- #
def _assert_untouched(reg, seen):
    assert reg.epoch("T") == 0 and reg.global_epoch == 0
    assert seen == []
    assert reg["T"].values("T.v").tolist() == [0, 10, 20, 30, 40, 50]


def test_update_rejects_float_row_ids():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    with pytest.raises(TypeError, match="row ids must be integers"):
        reg.update_rows("T", np.array([0.5]), {"T.v": np.array([1])})
    _assert_untouched(reg, seen)


def test_update_rejects_uncastable_value_dtype():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    with pytest.raises(TypeError, match="not castable"):
        reg.update_rows("T", np.array([0]), {"T.v": np.array([1.5])})
    _assert_untouched(reg, seen)


def test_update_rejects_unknown_attr_and_length_mismatch():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    with pytest.raises(KeyError, match="no column"):
        reg.update_rows("T", np.array([0]), {"T.nope": np.array([1])})
    with pytest.raises(ValueError, match="2 values for 1 rows"):
        reg.update_rows("T", np.array([0]), {"T.v": np.array([1, 2])})
    _assert_untouched(reg, seen)


def test_row_bounds_checked_before_commit():
    reg = TableRegistry({"T": _table()})
    seen = _capture(reg)
    with pytest.raises(IndexError, match="out of range"):
        reg.update_rows("T", np.array([6]), {"T.v": np.array([1])})
    with pytest.raises(IndexError, match="out of range"):
        reg.delete_rows("T", np.array([-1]))
    with pytest.raises(TypeError, match="row ids must be integers"):
        reg.delete_rows("T", np.array([1.0]))
    _assert_untouched(reg, seen)


def test_empty_row_list_is_fine():
    reg = TableRegistry({"T": _table()})
    reg.delete_rows("T", np.array([], dtype=np.int64))
    reg.delete_rows("T", [])  # empty python list: float64 dtype, size 0
    assert reg.epoch("T") == 2 and reg["T"].num_rows == 6


# --------------------------------------------------------------------------- #
# LruCache reverse index
# --------------------------------------------------------------------------- #
class _TableKeyed(LruCache):
    def _key_tables(self, key):
        return key[0]  # key = (tables_tuple, tag)


def test_reverse_index_tracks_inserts_and_removal():
    c = _TableKeyed(8)
    c.insert((("A", "B"), 1), "x")
    c.insert((("B",), 2), "y")
    assert sorted(c.keys_for_table("B")) == [(("A", "B"), 1), (("B",), 2)]
    assert c.keys_for_table("A") == ((("A", "B"), 1),)
    assert c.dependencies((("A", "B"), 1)) == ("A", "B")
    assert c.remove((("A", "B"), 1))
    assert not c.remove((("A", "B"), 1))  # idempotent, silent
    assert c.keys_for_table("A") == ()
    assert c.stats()["invalidations"] == 0  # remove() is not invalidation


def test_reverse_index_widened_dependencies():
    # the compound-leak fix: an entry can depend on tables its key never
    # names; invalidate_table must still find (and purge) it
    c = _TableKeyed(8)
    c.insert((("A",), 1), "x", tables=("A", "S"))
    assert c.keys_for_table("S") == ((("A",), 1),)
    assert c.invalidate_table("S") == 1
    assert len(c) == 0 and c.keys_for_table("A") == ()


def test_eviction_unlinks_reverse_index():
    c = _TableKeyed(2)
    c.insert((("A",), 1), "x")
    c.insert((("B",), 2), "y")
    c.insert((("C",), 3), "z")  # evicts the A entry (LRU)
    assert c.keys_for_table("A") == ()
    assert c.stats()["evictions"] == 1
    # overwrite re-links under the new dependency set
    c.insert((("B",), 2), "y2", tables=("D",))
    assert c.keys_for_table("B") == () and c.keys_for_table("D") != ()


def test_invalidate_key_counts():
    c = _TableKeyed(4)
    c.insert((("A",), 1), "x")
    assert c.invalidate_key((("A",), 1))
    assert not c.invalidate_key((("A",), 1))
    assert c.stats()["invalidations"] == 1


# --------------------------------------------------------------------------- #
# referenced_attrs
# --------------------------------------------------------------------------- #
def test_referenced_attrs_covers_predicates_projection_aggregate():
    q = Query(("R", "S"), (SelectionPredicate("R.v", ">", 1),), (),
              (), Aggregate("sum", "S.v", group_by="S.g"))
    cols = {"R": ["R.k", "R.v"], "S": ["S.k", "S.v", "S.g"]}
    refs = referenced_attrs(q, cols)
    assert refs["R"] == {"R.v"} and refs["S"] == {"S.v", "S.g"}
    # whole-row output (no projection, no aggregate): every column counts
    q2 = Query(("R",), (), (), ())
    assert referenced_attrs(q2, cols)["R"] == {"R.k", "R.v"}


def test_resolve_ivm_env(monkeypatch):
    monkeypatch.delenv("QUIP_IVM", raising=False)
    assert resolve_ivm() is False
    assert resolve_ivm(True) is True
    monkeypatch.setenv("QUIP_IVM", "on")
    assert resolve_ivm() is True
    assert resolve_ivm(False) is False  # explicit argument wins


# --------------------------------------------------------------------------- #
# service-level patching
# --------------------------------------------------------------------------- #
def _mk(name, n, v, missing=None):
    schema = Schema(name, [ColumnSpec(f"{name}.k", "int"),
                           ColumnSpec(f"{name}.v", "int")])
    miss = {f"{name}.v": np.asarray(missing, dtype=bool)} if missing is not None else None
    return MaskedRelation.from_columns(
        schema,
        {f"{name}.k": np.arange(n, dtype=np.int64) % 3,
         f"{name}.v": np.asarray(v, dtype=np.int64)},
        missing=miss, base_table=name,
    )


def _cold(query, reg, strategy="lazy"):
    tables = {t: reg[t].copy() for t in query.tables}
    eng = ImputationService(tables, default=MeanImputer)
    return execute_quip(query, tables, eng, strategy=strategy).answer_tuples()


def test_service_patches_aggregates_and_tuples():
    # duplicates in the projection answer exercise true multiset weights
    reg = TableRegistry({"R": _mk("R", 8, [5, 5, 7, 9, 5, 7, 2, 4])})
    svc = QuipService(reg, MeanImputer, ivm=True, strategy="lazy")
    q_cnt = Query(("R",), (SelectionPredicate("R.v", ">", 4),), (), (),
                  Aggregate("count", None))
    q_avg = Query(("R",), (), (), (), Aggregate("avg", "R.v", group_by="R.k"))
    q_prj = Query(("R",), (SelectionPredicate("R.v", "<=", 7),), (), ("R.v",))
    tickets = [svc.submit(q) for q in (q_cnt, q_avg, q_prj)]
    svc.run_until_idle()
    for t in tickets:
        svc.answers(t)

    reg.update_rows("R", np.array([0, 6]), {"R.v": np.array([100, 5])})
    reg.delete_rows("R", np.array([3]))
    reg.insert_rows("R", {"R.k": np.array([1, 2]),
                          "R.v": np.array([7, 7])})

    s = svc.summary()
    assert s["results_patched"] == 9  # 3 entries × 3 mutations, all patched
    assert s["ivm_fallbacks"] == 0
    assert dict(svc._ivm.fallback_reasons) == {}
    for q in (q_cnt, q_avg, q_prj):
        t = svc.submit(q)
        svc.run_until_idle()
        assert svc.summary()["queries_result_cache_hit"] > 0
        assert svc.answers(t) == _cold(q, reg), q
    # the patched hits really were served from cache (no re-execution)
    assert svc.summary()["queries_result_cache_hit"] == 3


def test_service_minmax_falls_back():
    reg = TableRegistry({"R": _mk("R", 6, [1, 2, 3, 4, 5, 6])})
    svc = QuipService(reg, MeanImputer, ivm=True, strategy="lazy")
    q = Query(("R",), (), (), (), Aggregate("max", "R.v"))
    t = svc.submit(q)
    svc.run_until_idle()
    svc.answers(t)
    reg.update_rows("R", np.array([5]), {"R.v": np.array([0])})
    s = svc.summary()
    assert s["results_patched"] == 0 and s["ivm_fallbacks"] == 1
    assert svc._ivm.fallback_reasons["minmax"] == 1
    t2 = svc.submit(q)
    svc.run_until_idle()
    assert svc.answers(t2) == [(5,)]  # recomputed, not stale


def test_service_imputed_overlap_falls_back():
    # the query's answer depended on imputations over R: refitting on the
    # mutated R could change them, so the entry must not be patched
    reg = TableRegistry({"R": _mk("R", 6, [1, 2, 3, 4, 5, 6],
                                  missing=[0, 1, 0, 1, 0, 0])})
    svc = QuipService(reg, MeanImputer, ivm=True, strategy="lazy")
    q = Query(("R",), (SelectionPredicate("R.v", ">", 2),), (), (),
              Aggregate("count", None))
    t = svc.submit(q)
    svc.run_until_idle()
    svc.answers(t)
    reg.update_rows("R", np.array([0]), {"R.v": np.array([50])})
    assert svc._ivm.fallback_reasons["imputed_overlap"] == 1
    assert svc.summary()["ivm_fallbacks"] == 1
    t2 = svc.submit(q)
    svc.run_until_idle()
    assert svc.answers(t2) == _cold(q, reg)


def test_service_delta_with_missing_referenced_cells_falls_back():
    reg = TableRegistry({"R": _mk("R", 6, [1, 2, 3, 4, 5, 6])})
    svc = QuipService(reg, MeanImputer, ivm=True, strategy="lazy")
    q = Query(("R",), (SelectionPredicate("R.v", ">", 2),), (), (),
              Aggregate("count", None))
    t = svc.submit(q)
    svc.run_until_idle()
    svc.answers(t)
    # insert a row whose referenced attr is missing: imputing it against a
    # mini delta table would use the wrong fit — must evict instead
    reg.insert_rows("R", {"R.k": np.array([0]), "R.v": np.array([0])},
                    missing={"R.v": np.array([True])})
    assert svc._ivm.fallback_reasons["delta_missing"] == 1
    t2 = svc.submit(q)
    svc.run_until_idle()
    assert svc.answers(t2) == _cold(q, reg)


def test_service_replace_table_falls_back():
    reg = TableRegistry({"R": _mk("R", 6, [1, 2, 3, 4, 5, 6])})
    svc = QuipService(reg, MeanImputer, ivm=True, strategy="lazy")
    q = Query(("R",), (), (), (), Aggregate("count", None))
    t = svc.submit(q)
    svc.run_until_idle()
    svc.answers(t)
    reg.replace_table("R", _mk("R", 2, [9, 9]))
    assert svc._ivm.fallback_reasons["no_delta"] == 1
    t2 = svc.submit(q)
    svc.run_until_idle()
    assert svc.answers(t2) == [(2,)]


def test_ivm_off_keeps_plain_invalidation_accounting():
    reg = TableRegistry({"R": _mk("R", 6, [1, 2, 3, 4, 5, 6])})
    svc = QuipService(reg, MeanImputer, ivm=False, strategy="lazy")
    q = Query(("R",), (), (), (), Aggregate("count", None))
    t = svc.submit(q)
    svc.run_until_idle()
    svc.answers(t)
    reg.update_rows("R", np.array([0]), {"R.v": np.array([9])})
    s = svc.summary()
    assert s["results_invalidated"] == 1
    assert s["results_patched"] == 0 and s["ivm_fallbacks"] == 0


# --------------------------------------------------------------------------- #
# the compound-dependency leak (fixed for IVM on AND off)
# --------------------------------------------------------------------------- #
def _nested_setup(ivm):
    reg = TableRegistry({
        "R": _mk("R", 6, [1, 2, 3, 4, 5, 6]),
        "S": _mk("S", 6, [2, 3, 2, 3, 2, 3]),
    })
    svc = QuipService(reg, MeanImputer, ivm=ivm, strategy="lazy")
    outer = Query(("R",), (), (), ("R.v",))
    sub = Query(("S",), (SelectionPredicate("S.v", ">", 2),), (), ("S.v",))
    t = svc.submit_nested(outer, "R.v", sub)
    svc.run_until_idle()
    answers = svc.answers(t)
    return reg, svc, outer, sub, answers


@pytest.mark.parametrize("ivm", [False, True])
def test_compound_entries_die_with_subquery_tables(ivm):
    reg, svc, outer, sub, before = _nested_setup(ivm)
    assert before == [(3,)]  # R.v IN {S.v > 2} = {3}
    # the rewritten outer entry's signature names only R, but it depends on
    # S through the baked-in IN-set: the reverse index must know
    leaked = [k for k in svc.result_cache.keys_for_table("S")
              if "S" not in k[0][1]]
    assert leaked, "outer2 entry not registered under S"
    reg.update_rows("S", np.arange(6), {"S.v": np.full(6, 9)})
    # the outer2 entry the key-derived scan used to leak is gone: IVM may
    # keep *patching* entries that name S in their signature (the plain
    # sub-query answer), but never one depending on S only via the IN-set
    assert all("S" in k[0][1] for k in svc.result_cache.keys_for_table("S"))
    if ivm:
        assert svc._ivm.fallback_reasons["compound_dep"] >= 1
    else:
        assert svc.result_cache.keys_for_table("S") == ()
    # plan-cache entries widen the same way (plans always evict)
    assert svc.plan_cache.keys_for_table("S") == ()
    t2 = svc.submit_nested(outer, "R.v", sub)
    svc.run_until_idle()
    assert svc.answers(t2) == []  # IN-set is now {9}; no R.v matches
