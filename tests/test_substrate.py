"""Distribution-substrate tests: optimizers, checkpoint/restart (incl. torn
checkpoints + failure injection), gradient compression, straggler monitor,
elastic resharding, and the QUIP data pipeline."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress,
    decompress,
    ef_compress_grads,
    init_residual,
    warmup_cosine,
)
from repro.runtime.fault import FaultConfig, FaultTolerantDriver
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.elastic import elastic_remesh_plan


# --------------------------------------------------------------------------- #
# optimizers
# --------------------------------------------------------------------------- #
def _quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array([0.5])}


def _run_quadratic(opt: str, steps: int) -> float:
    """Run ``steps`` optimizer updates on a quadratic; returns loss ratio."""
    params = _quad_params()
    init = adamw_init if opt == "adamw" else adafactor_init
    update = adamw_update if opt == "adamw" else adafactor_update
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(steps):
        grads = jax.grad(loss)(params)
        kwargs = {"weight_decay": 0.0} if opt == "adamw" else {}
        params, state = update(params, grads, state, jnp.float32(0.05),
                               **kwargs)
    return float(loss(params)) / l0


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_descend_quadratic(opt):
    assert _run_quadratic(opt, steps=12) < 1.0


@pytest.mark.slow
@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_reduce_quadratic(opt):
    assert _run_quadratic(opt, steps=60) < 0.25


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 32))}
    st = adafactor_init(p)
    leaves = jax.tree_util.tree_leaves(st["stats"])
    total = sum(l.size for l in leaves)
    assert total == 64 + 32  # row + col, not 64*32


def test_clip_and_schedule():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(sum(jnp.sum(l ** 2)
                         for l in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    lrs = [float(warmup_cosine(jnp.int32(s), 1e-3, 10, 100)) for s in
           (0, 5, 10, 50, 100)]
    assert 0 < lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] >= lrs[3] >= lrs[4] > 0


# --------------------------------------------------------------------------- #
# gradient compression with error feedback
# --------------------------------------------------------------------------- #
def test_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32))
    q, s = compress(x)
    err = jnp.max(jnp.abs(decompress(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """Sum of EF-compressed grads converges to the true sum (residual
    carries the quantization error)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, dtype=np.float32)
    ef_sum = np.zeros(64, dtype=np.float32)
    grads_like = {"g": jnp.zeros(64)}
    residual = init_residual(grads_like)
    for _ in range(200):
        g = rng.normal(0, 1e-3, 64).astype(np.float32)
        true_sum += g
        deq, residual = ef_compress_grads({"g": jnp.asarray(g)}, residual)
        ef_sum += np.asarray(deq["g"])
    resid = np.asarray(residual["g"])
    np.testing.assert_allclose(ef_sum + resid, true_sum, atol=1e-4)


# --------------------------------------------------------------------------- #
# checkpoint / restart
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_digest(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), dtype=np.int32)}}
    save_checkpoint(str(tmp_path), 7, tree)
    out, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_torn_checkpoint_ignored(tmp_path):
    tree = {"a": np.zeros(4)}
    save_checkpoint(str(tmp_path), 10, tree)
    # torn write: step_20 without COMMIT
    torn = tmp_path / "step_000020"
    torn.mkdir()
    (torn / "MANIFEST.json").write_text("{}")
    assert latest_step(str(tmp_path)) == 10


def test_fault_tolerant_driver_replays(tmp_path):
    """Failure injection mid-run: training completes and matches the
    uninterrupted run exactly (pure step function + checkpoint/restart)."""

    def train_step(state, batch):
        new = {"w": state["w"] + batch, "n": state["n"] + 1}
        return new, {"loss": float(jnp.sum(new["w"]))}

    def batch_fn(step):
        return jnp.float32(step + 1)

    init = {"w": jnp.zeros(()), "n": jnp.zeros((), jnp.int32)}
    # uninterrupted reference
    ref = init
    for s in range(20):
        ref, _ = train_step(ref, batch_fn(s))

    cfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                      fail_at_steps=(7, 13))
    driver = FaultTolerantDriver(cfg)
    out = driver.run(train_step, init, batch_fn, 20, state_like=init)
    assert driver.restarts == 2
    np.testing.assert_allclose(float(out["w"]), float(ref["w"]))
    assert int(out["n"]) == 20


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (5, 10, 15):
        ck.save(s, {"x": np.full(3, s)})
    ck.wait()
    assert latest_step(str(tmp_path)) == 15
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path)
        if n.startswith("step_")
    )
    assert steps == [10, 15]  # gc kept last 2


# --------------------------------------------------------------------------- #
# straggler + elastic
# --------------------------------------------------------------------------- #
def test_straggler_detection():
    mon = StragglerMonitor(n_ranks=8, threshold=1.5, patience=2)
    rng = np.random.default_rng(0)
    fired_total = []
    for step in range(10):
        times = rng.normal(1.0, 0.02, 8)
        times[3] = 2.5  # persistent straggler
        fired_total += mon.observe(step, times)
    assert 3 in fired_total
    assert all(r == 3 for r in fired_total)


def test_elastic_remesh_plan():
    dp, mp = elastic_remesh_plan(512, 256, model_parallel=16)
    assert (dp, mp) == (16, 16)
    with pytest.raises(AssertionError):
        elastic_remesh_plan(512, 100, model_parallel=16)


def test_elastic_reshard_roundtrip():
    from repro.runtime.elastic import reshard_state

    state = {"wq": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = reshard_state(state, mesh)
    np.testing.assert_array_equal(np.asarray(out["wq"]),
                                  np.asarray(state["wq"]))


# --------------------------------------------------------------------------- #
# QUIP data pipeline (paper-technique → trainer integration)
# --------------------------------------------------------------------------- #
def test_quip_pipeline_produces_batches():
    from repro.data.pipeline import QuipCleanStage
    from repro.data.queries import workload
    from repro.data.synthetic import wifi_dataset

    tables, _ = wifi_dataset(n_users=60, n_wifi=500, n_occ=300)
    queries = workload("wifi", tables, kind="random", n_queries=3, seed=5)
    stage = QuipCleanStage(
        tables=tables, queries=queries, vocab=256, seq_len=16,
        global_batch=4,
    )
    it = stage.batches()
    batch = next(it)
    assert batch["tokens"].shape == (4, 16)
    assert batch["labels"].shape == (4, 16)
    assert batch["tokens"].min() >= 0 and batch["tokens"].max() < 256
