"""TableRegistry epoch/mutation semantics and the invalidation surface it
drives: PlanCache.invalidate_table, ImputeStore.invalidate, ResultCache
epoch keying, and the shared env_flag parser."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.env import env_flag
from repro.core.relation import MaskedRelation
from repro.core.schema import ColumnSpec, Schema
from repro.imputers.base import ImputationService, Imputer, ImputeStore
from repro.service import PlanCache, ResultCache, TableRegistry
from repro.service.plan_cache import query_signature
from test_quip_correctness import _build_instance
from test_service import _query


def _registry(seed=11, rows=64):
    rng = np.random.default_rng(seed)
    tables, _clean, truth = _build_instance(rng, 2, rows, 0.3, 6)
    return TableRegistry({t: r.copy() for t, r in tables.items()}), truth


# --------------------------------------------------------------------------- #
# Mapping interface + epochs
# --------------------------------------------------------------------------- #
def test_registry_is_a_mapping():
    reg, _ = _registry()
    assert set(reg) == {"R0", "R1"}
    assert len(reg) == 2 and "R0" in reg
    assert isinstance(reg["R0"], MaskedRelation)
    assert {t: r.num_rows for t, r in reg.items()} == {"R0": 64, "R1": 64}
    # a drop-in for the plain dict every engine call site takes
    assert dict(reg) == {t: reg[t] for t in reg}


def test_epochs_bump_per_table_and_globally():
    reg, _ = _registry()
    assert reg.global_epoch == 0 and reg.epochs(("R0", "R1")) == (0, 0)
    reg.update_rows("R0", np.array([0]), {"R0.v": np.array([3])})
    assert reg.epoch("R0") == 1 and reg.epoch("R1") == 0
    assert reg.global_epoch == 1
    reg.delete_rows("R1", np.array([5]))
    assert reg.epochs(("R0", "R1")) == (1, 1) and reg.global_epoch == 2


# --------------------------------------------------------------------------- #
# mutation semantics
# --------------------------------------------------------------------------- #
def test_update_rows_is_copy_on_write_and_clears_missing():
    reg, _ = _registry()
    snapshot = reg["R0"]
    before = snapshot.values("R0.v").copy()
    rows = np.nonzero(snapshot.is_missing("R0.v"))[0][:2]
    reg.update_rows("R0", rows, {"R0.v": np.array([7, 8])})
    # the snapshot an in-flight session holds is untouched
    assert snapshot is not reg["R0"]
    np.testing.assert_array_equal(snapshot.values("R0.v"), before)
    # the registry's table has the new values, and they are known now
    np.testing.assert_array_equal(reg["R0"].values("R0.v")[rows], [7, 8])
    assert not reg["R0"].is_missing("R0.v")[rows].any()


def test_delete_rows_rebuilds_canonically():
    reg, _ = _registry()
    reg.delete_rows("R0", np.array([0, 3, 63]))
    rel = reg["R0"]
    assert rel.num_rows == 61
    # tids re-indexed: dense imputation caches line up at the new size
    np.testing.assert_array_equal(rel.tids["R0"], np.arange(61))


def test_insert_rows_appends_with_missing_marks():
    reg, _ = _registry()
    cols = {a: np.zeros(3, dtype=np.int64) for a in reg["R0"].column_names()}
    reg.insert_rows("R0", cols, missing={"R0.v": np.array([True, False,
                                                           True])})
    rel = reg["R0"]
    assert rel.num_rows == 67
    np.testing.assert_array_equal(rel.is_missing("R0.v")[64:],
                                  [True, False, True])
    np.testing.assert_array_equal(rel.tids["R0"], np.arange(67))


def test_replace_table_swaps_whole_relation():
    reg, _ = _registry()
    schema = reg["R1"].schema
    tiny = MaskedRelation.from_columns(
        schema, {c.name: np.zeros(2, dtype=np.int64) for c in schema.columns},
        base_table="R1",
    )
    reg.replace_table("R1", tiny)
    assert reg["R1"].num_rows == 2 and reg.epoch("R1") == 1


def test_invalid_mutations_fail_loud_without_bumping_epochs():
    reg, _ = _registry()
    with pytest.raises(KeyError):
        reg.update_rows("NOPE", np.array([0]), {"x": np.array([1])})
    with pytest.raises(IndexError):
        reg.delete_rows("R0", np.array([64]))
    with pytest.raises(ValueError):
        reg.update_rows("R0", np.array([0, 1]), {"R0.v": np.array([1])})
    with pytest.raises(ValueError):  # ragged / missing-column inserts
        reg.insert_rows("R0", {"R0.v": np.array([1])})
    with pytest.raises(ValueError, match="missing mask"):  # mis-sized mask
        reg.insert_rows(
            "R0",
            {a: np.zeros(3, dtype=np.int64)
             for a in reg["R0"].column_names()},
            missing={"R0.v": np.array([True])},
        )
    assert reg.global_epoch == 0  # nothing committed


def test_subscriber_before_hook_vetoes_pre_commit():
    reg, _ = _registry()
    seen = []

    def veto(table):
        raise RuntimeError("busy")

    reg.subscribe(seen.append, before=veto)
    with pytest.raises(RuntimeError, match="busy"):
        reg.delete_rows("R0", np.array([0]))
    assert reg.global_epoch == 0 and reg["R0"].num_rows == 64
    assert seen == []  # post-commit hook never ran


def test_subscriber_observes_committed_state():
    reg, _ = _registry()
    observed = []
    reg.subscribe(
        lambda table: observed.append((table, reg.epoch(table),
                                       reg[table].num_rows))
    )
    reg.delete_rows("R0", np.array([0, 1]))
    assert observed == [("R0", 1, 62)]


def test_unsubscribe_detaches_hooks():
    """A service discarded while the registry lives on must be able to
    detach (QuipService.close) — its hooks, including the shared-impute
    veto, stop firing."""
    from test_quip_correctness import GroundTruthImputer
    from repro.service import QuipService

    reg, truth = _registry()
    svc = QuipService(reg, lambda: GroundTruthImputer(truth),
                      shared_impute=True, morsel_rows=8)
    events = []
    reg.subscribe(lambda table: events.append(table))
    svc.close()
    # with the dead service detached, its in-flight veto no longer applies
    # and its invalidation hook no longer fires
    reg.delete_rows("R0", np.array([0]))
    assert events == ["R0"]
    assert svc.serving.invalidation_events == 0
    assert reg.global_epoch == 1


# --------------------------------------------------------------------------- #
# PlanCache invalidation
# --------------------------------------------------------------------------- #
def test_plan_cache_invalidate_table_is_selective():
    reg, _ = _registry()
    cache = PlanCache()
    from repro.core.plan import Query

    q_join = _query(2)  # reads R0 and R1
    q_r1 = Query(("R1",), (), (), ("R1.v",))
    cache.get(q_join, reg)
    cache.get(q_r1, reg)
    assert len(cache) == 2
    assert cache.invalidate_table("R0") == 1  # only the join plan depends
    assert len(cache) == 1
    _plan, hit = cache.get(q_r1, reg)
    assert hit  # the R1-only plan survived
    _plan, hit = cache.get(q_join, reg)
    assert not hit  # the dependent plan was evicted → re-planned
    assert cache.stats()["invalidations"] == 1


# --------------------------------------------------------------------------- #
# ImputeStore invalidation
# --------------------------------------------------------------------------- #
class CountingImputer(Imputer):
    blocking = True

    def __init__(self):
        self.fits = 0

    def fit(self, table):
        self.fits += 1

    def impute_attr(self, table, attr, tids):
        return np.zeros(len(tids))


def test_impute_store_invalidate_drops_cells_and_models():
    reg, truth = _registry()
    store = ImputeStore(reg)
    svc = ImputationService(reg, default=CountingImputer, store=store)
    svc.impute("R0", "R0.v", np.array([0, 1, 2]))
    svc.impute("R1", "R1.v", np.array([4]))
    assert store.filled_cells() == 4
    dropped = store.invalidate("R0")
    assert dropped == 3
    assert store.filled_cells() == 1  # R1 cells untouched
    # caches rebuild at the mutated table's new row count
    reg.delete_rows("R0", np.arange(10))
    values, filled = store.column_cache("R0", "R0.v")
    assert len(values) == 54 and not filled.any()
    # the model was dropped too: next impute refits on the new table
    before = svc.counters.imputations
    svc.impute("R0", "R0.v", np.array([0]))
    assert svc.counters.imputations == before + 1


def test_invalidate_unrelated_table_is_a_noop():
    reg, _ = _registry()
    store = ImputeStore(reg)
    svc = ImputationService(reg, default=CountingImputer, store=store)
    svc.impute("R0", "R0.v", np.array([0, 1]))
    assert store.invalidate("R1") == 0
    assert store.filled_cells() == 2


# --------------------------------------------------------------------------- #
# ResultCache
# --------------------------------------------------------------------------- #
def _key(query, epochs, planner="imputedb"):
    return (query_signature(query, planner), ("adaptive",), tuple(epochs))


def test_result_cache_epoch_keying_and_lru():
    cache = ResultCache(capacity=2)
    qa, qb, qc = _query(1), _query(2), _query(3)
    assert cache.get(_key(qa, (0, 0))) is None  # miss
    cache.put(_key(qa, (0, 0)), "ans-a")
    cache.put(_key(qb, (0, 0)), "ans-b")
    assert cache.get(_key(qa, (0, 0))) == "ans-a"
    # same signature at a bumped epoch is a different key → miss
    assert cache.get(_key(qa, (1, 0))) is None
    cache.put(_key(qc, (0, 0)), "ans-c")  # evicts LRU (qb)
    assert cache.evictions == 1
    assert cache.get(_key(qb, (0, 0))) is None
    assert cache.stats()["size"] == 2


def test_result_cache_invalidate_table_purges_dependents():
    cache = ResultCache()
    from repro.core.plan import Query

    q_join = _query(2)  # reads R0, R1
    q_r1 = Query(("R1",), (), (), ("R1.v",))
    cache.put(_key(q_join, (0, 0)), "join")
    cache.put((query_signature(q_r1), ("adaptive",), (0,)), "r1-only")
    assert cache.invalidate_table("R0") == 1
    assert len(cache) == 1
    assert cache.get((query_signature(q_r1), ("adaptive",), (0,))) \
        == "r1-only"


# --------------------------------------------------------------------------- #
# env_flag (shared gate parser)
# --------------------------------------------------------------------------- #
def test_env_flag_spellings(monkeypatch):
    for raw in ("1", "true", "Yes", "ON", " true "):
        monkeypatch.setenv("QUIP_TEST_FLAG", raw)
        assert env_flag("QUIP_TEST_FLAG", False) is True
    for raw in ("0", "false", "No", "OFF"):
        monkeypatch.setenv("QUIP_TEST_FLAG", raw)
        assert env_flag("QUIP_TEST_FLAG", True) is False
    monkeypatch.delenv("QUIP_TEST_FLAG", raising=False)
    assert env_flag("QUIP_TEST_FLAG", True) is True
    monkeypatch.setenv("QUIP_TEST_FLAG", "")
    assert env_flag("QUIP_TEST_FLAG", False) is False  # empty = unset
    monkeypatch.setenv("QUIP_TEST_FLAG", "maybe")
    with pytest.raises(ValueError, match="QUIP_TEST_FLAG"):
        env_flag("QUIP_TEST_FLAG", False)
